"""Paper Fig 3 analogue: Graph500 BFS TEPS, EDAT vs BSP reference, over
rank counts.  (Container has one physical core, so absolute TEPS are not
the paper's Cray numbers; the deliverable is the EDAT-vs-reference
comparison and the crossover trend as rank count grows.)"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.graph import (EdatBFS, ReferenceBFS, build_csr, kronecker_edges,
                         validate_bfs_tree)


def run(scale: int = 13, edgefactor: int = 16, ranks=(1, 2, 4, 8),
        roots: int = 4, validate: bool = True, out: str = None):
    edges = kronecker_edges(scale, edgefactor)
    n = 1 << scale
    rng = np.random.default_rng(7)
    # sample roots with degree > 0 (graph500 rule)
    deg = np.bincount(np.concatenate([edges[0], edges[1]]), minlength=n)
    cand = np.where(deg > 0)[0]
    root_set = [int(r) for r in rng.choice(cand, size=roots, replace=False)]

    rows = []
    for nr in ranks:
        csr = build_csr(edges, n, nr)
        for impl_name, mk in (("edat", lambda: EdatBFS(csr)),
                              ("reference", lambda: ReferenceBFS(csr))):
            teps_list = []
            for root in root_set:
                bfs = mk()
                t0 = time.monotonic()
                parent = bfs.run(root)
                dt = time.monotonic() - t0
                traversed = sum(bfs.traversed)
                teps_list.append(traversed / max(dt, 1e-9))
                if validate:
                    assert validate_bfs_tree(edges, parent, root), \
                        (impl_name, nr, root)
            rows.append({"impl": impl_name, "ranks": nr,
                         "teps_mean": float(np.mean(teps_list)),
                         "teps_max": float(np.max(teps_list))})
            print(f"  bfs scale={scale} ranks={nr:2d} {impl_name:9s} "
                  f"TEPS={np.mean(teps_list):.3e}")
    result = {"scale": scale, "edgefactor": edgefactor, "rows": rows}
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    run()
