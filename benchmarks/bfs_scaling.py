"""Paper Fig 3 analogue: Graph500 BFS TEPS, EDAT vs BSP reference, over
rank counts.  (Container has one physical core, so absolute TEPS are not
the paper's Cray numbers; the deliverable is the EDAT-vs-reference
comparison and the crossover trend as rank count grows.)

``--transport socket`` runs the *same* event-driven BFS with one OS
process per rank over ``repro.net``'s coalescing SocketTransport
(spawned via the v2 ``edat.Session``); each row then also records
``events_per_s`` (user events fired per second of in-child run time,
summed over all ranks — includes each rank's SELF loopback fires, which
stay in-process) alongside TEPS, and every parent array is validated
against the in-proc BSP reference.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.graph import (EdatBFS, ReferenceBFS, build_csr,
                         kronecker_edges, validate_bfs_tree)
# the Session-backed distributed run (the deprecated shim minus the
# warning), so the bench and the v1 compat path can never drift apart
from repro.graph.bfs import _distributed_bfs


def run(scale: int = 13, edgefactor: int = 16, ranks=(1, 2, 4, 8),
        roots: int = 4, validate: bool = True, out: str = None,
        transport: str = "inproc", seed: int = 20):
    assert transport in ("inproc", "socket")
    edges = kronecker_edges(scale, edgefactor, seed)
    n = 1 << scale
    rng = np.random.default_rng(7)
    # sample roots with degree > 0 (graph500 rule)
    deg = np.bincount(np.concatenate([edges[0], edges[1]]), minlength=n)
    cand = np.where(deg > 0)[0]
    root_set = [int(r) for r in rng.choice(cand, size=roots, replace=False)]

    rows = []
    for nr in ranks:
        if transport == "socket":
            # the spawned children each build their own CSR; the parent
            # only needs one for reference validation
            csr = build_csr(edges, n, nr) if validate else None
            teps_list, evs_list = [], []
            for root in root_set:
                parent, info = _distributed_bfs(nr, scale, edgefactor,
                                                seed, root)
                teps_list.append(info["teps"])
                evs_list.append(info["events_per_s"])
                if validate:
                    ref = ReferenceBFS(csr).run(root)
                    assert np.array_equal(parent, ref), \
                        ("socket", nr, root)
            rows.append({"impl": "edat-socket", "ranks": nr,
                         "teps_mean": float(np.mean(teps_list)),
                         "teps_max": float(np.max(teps_list)),
                         "events_per_s": float(np.mean(evs_list))})
            print(f"  bfs scale={scale} ranks={nr:2d} edat-sock "
                  f"TEPS={np.mean(teps_list):.3e} "
                  f"ev/s={np.mean(evs_list):.0f}")
            continue
        csr = build_csr(edges, n, nr)
        for impl_name, mk in (("edat", lambda: EdatBFS(csr)),
                              ("reference", lambda: ReferenceBFS(csr))):
            teps_list = []
            for root in root_set:
                bfs = mk()
                t0 = time.monotonic()
                parent = bfs.run(root)
                dt = time.monotonic() - t0
                traversed = sum(bfs.traversed)
                teps_list.append(traversed / max(dt, 1e-9))
                if validate:
                    assert validate_bfs_tree(edges, parent, root), \
                        (impl_name, nr, root)
            rows.append({"impl": impl_name, "ranks": nr,
                         "teps_mean": float(np.mean(teps_list)),
                         "teps_max": float(np.max(teps_list))})
            print(f"  bfs scale={scale} ranks={nr:2d} {impl_name:9s} "
                  f"TEPS={np.mean(teps_list):.3e}")
    result = {"scale": scale, "edgefactor": edgefactor,
              "transport": transport, "rows": rows}
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out", nargs="?", default=None,
                    help="optional path for the bench JSON")
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc",
                    help="threads-as-ranks in one process, or one OS "
                         "process per rank over SocketTransport")
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--ranks", type=int, nargs="+", default=None,
                    help="rank counts to sweep (default 1 2 4 8; socket "
                         "default 2 4)")
    ap.add_argument("--roots", type=int, default=4)
    ap.add_argument("--no-validate", action="store_true")
    a = ap.parse_args()
    ranks = tuple(a.ranks) if a.ranks else (
        (2, 4) if a.transport == "socket" else (1, 2, 4, 8))
    run(scale=a.scale, edgefactor=a.edgefactor, ranks=ranks, roots=a.roots,
        validate=not a.no_validate, out=a.out, transport=a.transport)
