"""Benchmark orchestrator: one benchmark per paper table/figure + roofline.

  python -m benchmarks.run            # small defaults (CI-sized)
  python -m benchmarks.run --full     # paper-shaped sweeps (slow on 1 core)
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--outdir", default="experiments/bench")
    args = ap.parse_args(argv)
    os.makedirs(args.outdir, exist_ok=True)

    print("== runtime micro-overheads (paper §V overhead discussion) ==")
    from benchmarks import runtime_micro
    runtime_micro.run(out=os.path.join(args.outdir, "runtime_micro.json"),
                      transport="both", durable=True)

    print("== Graph500 BFS: EDAT vs BSP reference (paper Fig 3) ==")
    from benchmarks import bfs_scaling
    if args.full:
        bfs_scaling.run(scale=16, ranks=(1, 2, 4, 8, 16), roots=8,
                        out=os.path.join(args.outdir, "bfs.json"))
    else:
        bfs_scaling.run(scale=12, ranks=(1, 2, 4), roots=2,
                        out=os.path.join(args.outdir, "bfs.json"))
    print("== Graph500 BFS across OS processes (SocketTransport) ==")
    bfs_scaling.run(scale=11 if not args.full else 14, ranks=(2, 4),
                    roots=2, transport="socket",
                    out=os.path.join(args.outdir, "bfs_socket.json"))

    print("== In-situ analytics: EDAT vs bespoke (paper Fig 5) ==")
    from benchmarks import insitu
    if args.full:
        insitu.run(analytics=(1, 2, 4, 8, 16), items=128,
                   out=os.path.join(args.outdir, "insitu.json"))
    else:
        insitu.run(analytics=(1, 2, 4), items=32,
                   out=os.path.join(args.outdir, "insitu.json"))
    print("== In-situ analytics across OS processes (SocketTransport) ==")
    insitu.run(analytics=(1, 2), items=32, transport="socket",
               out=os.path.join(args.outdir, "insitu_socket.json"))

    print("== elastic trainer: in-proc vs distributed (steps/s) ==")
    from benchmarks import trainer_scaling
    trainer_scaling.run(steps=8 if not args.full else 20, ranks=(1, 2),
                        out=os.path.join(args.outdir, "trainer.json"))
    trainer_scaling.run(steps=8 if not args.full else 20, ranks=(2, 4),
                        transport="socket",
                        out=os.path.join(args.outdir,
                                         "trainer_socket.json"))

    print("== LM serving under open-loop load (event-driven vs "
          "sequential) ==")
    from benchmarks import serve_load
    if args.full:
        serve_load.run(rps=(4.0, 8.0, 16.0), requests=32,
                       transports=("inproc", "socket"), insights=True,
                       out=os.path.join(args.outdir, "serve_load.json"))
    else:
        serve_load.run(rps=(8.0,), requests=12, transports=("inproc",),
                       insights=True,
                       out=os.path.join(args.outdir, "serve_load.json"))

    print("== roofline (from dry-run artifacts, if present) ==")
    from benchmarks import roofline
    for mesh in ("pod16x16", "pod2x16x16"):
        d = os.path.join("experiments", "dryrun", mesh)
        if os.path.isdir(d) and os.listdir(d):
            print(f"-- mesh {mesh} --")
            roofline.run(d, os.path.join("experiments",
                                         f"roofline_{mesh}.json"))
        else:
            print(f"-- mesh {mesh}: no dry-run artifacts; run "
                  f"`python -m repro.launch.dryrun --all` first --")
    print("benchmarks complete; json in", args.outdir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
