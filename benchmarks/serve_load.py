"""Open-loop serving load: event-driven continuous batching vs the naive
sequential baseline, swept over offered request rates and transports.

Each row replays the same Poisson arrival schedule
(:class:`repro.serve.LoadSpec`) against one server configuration and
reports requests/s, tokens/s, and p50/p99 TTFT / per-token latency.
Latency is measured from the *scheduled* arrival time (coordinated-
omission-honest: a server that falls behind pays for the queueing it
causes).  The ``seq-baseline`` row serves the identical schedule one
request at a time — same jitted steps, same greedy argmax, so the
delta is pure continuous-batching + prefill/decode overlap.

``--insights`` runs :func:`repro.insights.analyze` over each event-driven
row's ``Session.stats()`` and prints the findings — under an offered
rate the slots cannot sustain, the ``admission-backpressure`` rule fires
for the ``request`` channel.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.serve import LoadSpec, all_requests, run_sequential, run_serve


def _print_row(row):
    print(f"  serve {row['impl']:14s} rps={row['rps']:5.1f} "
          f"req/s={row['requests_per_s']:6.2f} "
          f"tok/s={row['tokens_per_s']:7.1f} "
          f"ttft p50={row['ttft_p50_ms']:7.1f}ms "
          f"p99={row['ttft_p99_ms']:7.1f}ms "
          f"tok p50={row['per_token_p50_ms']:5.2f}ms")


def run(rps=(4.0, 16.0), requests: int = 24, clients: int = 2,
        slots: int = 4, max_len: int = 64,
        transports=("inproc", "socket"), procs: int = 2,
        arch: str = "gemma3-1b", queue_bound: int = 8,
        insights: bool = False, out: str = None, seed: int = 0):
    """One result row per (impl, rps); all rows share the arrival
    schedule at a given rps, so columns are directly comparable."""
    from repro.configs import ARCHS, reduce_cfg
    from repro.serve.loadgen import summarize

    cfg = reduce_cfg(ARCHS[arch].cfg)
    rows = []
    all_findings = []
    for r in rps:
        load = LoadSpec(rps=float(r), requests=requests, seed=seed)
        reqs = all_requests(load, clients, cfg.vocab)
        span_reqs = run_sequential(cfg, reqs, max_len=max_len, seed=seed)
        span = (max(x["t_done"] for x in span_reqs)
                - min(x["t_sched"] for x in span_reqs))
        row = {"impl": "seq-baseline", "rps": float(r),
               "transport": "-", "slots": 1,
               **summarize(span_reqs, span)}
        rows.append(row)
        _print_row(row)
        for tr in transports:
            res = run_serve(arch=arch, clients=clients, slots=slots,
                            max_len=max_len, load=load,
                            queue_bound=queue_bound, transport=tr,
                            procs=procs if tr == "socket" else None,
                            seed=seed)
            row = {"impl": f"edat-{tr}", "rps": float(r),
                   "transport": tr, "slots": slots,
                   **res["summary"],
                   "steps": res["result"]["steps"],
                   "tick_execs": res["result"]["tick_execs"],
                   "bp_signals": res["result"]["bp_signals"]}
            rows.append(row)
            _print_row(row)
            if insights:
                from repro.insights import analyze
                found = analyze(res["stats"])
                all_findings.extend(
                    {"impl": row["impl"], "rps": float(r),
                     "rule": f.rule, "message": f.message}
                    for f in found)
                for f in found:
                    print(f"    insight [{f.rule}] {f.message}")

    result = {"requests": requests, "clients": clients, "slots": slots,
              "max_len": max_len, "arch": arch, "rows": rows,
              "findings": all_findings}
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out", nargs="?", default=None,
                    help="optional path for the bench JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: one rate, few requests, inproc only "
                         "unless --transport socket")
    ap.add_argument("--transport", choices=("inproc", "socket", "both"),
                    default="both")
    ap.add_argument("--rps", type=float, nargs="+", default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--queue-bound", type=int, default=8)
    ap.add_argument("--insights", action="store_true",
                    help="run repro.insights over each event-driven row")
    a = ap.parse_args()
    transports = (("inproc", "socket") if a.transport == "both"
                  else (a.transport,))
    if a.smoke:
        rps = tuple(a.rps) if a.rps else (8.0,)
        requests = a.requests or 6
        if a.transport == "both":
            transports = ("inproc",)
    else:
        rps = tuple(a.rps) if a.rps else (4.0, 16.0)
        requests = a.requests or 24
    run(rps=rps, requests=requests, clients=a.clients, slots=a.slots,
        max_len=a.max_len, transports=transports, procs=a.procs,
        queue_bound=a.queue_bound, insights=a.insights, out=a.out)
