"""Markdown report fragments for EXPERIMENTS.md from dry-run artifacts."""
import glob
import json
import os
import sys


def dryrun_table(dirpath: str) -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        r = json.load(open(p))
        if r.get("variant"):
            continue
        a = r.get("analysis", {})
        mem = r.get("memory", {}) or {}
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = mem.get("temp_size_in_bytes", 0) / 1e9
        coll = a.get("collective_wire_total", 0)
        cnts = a.get("collective_counts", {})
        sched = "+".join(f"{k.replace('collective-','c')}:{int(v)}"
                         for k, v in sorted(cnts.items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {'OK' if r['ok'] else 'FAIL'} "
            f"| {r.get('compile_s','-')} | {args_gb:.2f} | {temp_gb:.2f} "
            f"| {a.get('flops',0):.2e} | {coll:.2e} | {sched} |")
    hdr = ("| arch | shape | compile | compile_s | args GB/dev | "
           "temp GB/dev | FLOPs/dev | coll B/dev | collective schedule "
           "(counts) |\n|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def serve_table(path: str) -> str:
    """Markdown table from a ``benchmarks/serve_load.py`` JSON dump:
    one row per (impl, offered rps), plus any insights findings."""
    r = json.load(open(path))
    hdr = ("| impl | rps | req/s | tok/s | TTFT p50 ms | TTFT p99 ms | "
           "tok p50 ms | tok p99 ms |\n|---|---|---|---|---|---|---|---|")
    rows = [
        f"| {x['impl']} | {x['rps']:.0f} | {x['requests_per_s']:.2f} "
        f"| {x['tokens_per_s']:.1f} | {x['ttft_p50_ms']:.1f} "
        f"| {x['ttft_p99_ms']:.1f} | {x['per_token_p50_ms']:.2f} "
        f"| {x['per_token_p99_ms']:.2f} |"
        for x in r["rows"]]
    out = hdr + "\n" + "\n".join(rows)
    if r.get("findings"):
        out += "\n\nInsights:\n" + "".join(
            f"- `{f['impl']}` @ {f['rps']:.0f} rps — **{f['rule']}**: "
            f"{f['message']}\n" for f in r["findings"])
    return out


def micro_table(path: str) -> str:
    """Markdown table from a ``benchmarks/runtime_micro.py`` JSON dump —
    one row per probe.  The overhead A/B probes (always-on metrics,
    durable task log) carry their acceptance bar so a regression reads
    off the report directly."""
    r = json.load(open(path))
    bars = {"metrics_overhead_pct": "<= 5 %",
            "durable_overhead_pct": "<= 5 %"}
    hdr = "| probe | value | bar |\n|---|---|---|"
    rows = [f"| {k} | " + (f"{v:,.1f}" if abs(v) >= 10 else f"{v:.3f}")
            + f" | {bars.get(k, '—')} |"
            for k, v in sorted(r.items())]
    return hdr + "\n" + "\n".join(rows)


def insights_section(stats, title: str = "Runtime insights") -> str:
    """Markdown section running repro.insights over one run's
    ``Session.stats()`` mapping (pass the dict, or a path to a JSON
    dump of it)."""
    from repro.insights import analyze, render
    if isinstance(stats, str):
        stats = json.load(open(stats))
    return f"### {title}\n\n" + render(analyze(stats))


if __name__ == "__main__":
    print(dryrun_table(sys.argv[1] if len(sys.argv) > 1
                       else "experiments/dryrun/pod16x16"))
