"""Runtime micro-overheads (paper §V: "some additional overhead associated
with the scheduling of tasks and managing of dependencies"):

  * task throughput: zero-dependency tasks/second;
  * event throughput: rank-to-rank small-event rate;
  * event latency: ping-pong round-trip / 2;
  * persistent-task dispatch rate;
  * progress-mode comparison (dedicated thread vs idle-worker polling).
"""
from __future__ import annotations

import json
import os
import time

from repro import edat


def _tasks_per_s(n_tasks=2000, workers=2):
    done = []

    def t(ctx, events):
        done.append(None)

    def main(ctx):
        for _ in range(n_tasks):
            ctx.submit(t)

    rt = edat.Runtime(1, workers_per_rank=workers)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(done) == n_tasks
    return n_tasks / dt


def _events_per_s(n_events=2000, progress="thread"):
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            for i in range(n_events):
                ctx.fire(0, "e", i)

    rt = edat.Runtime(2, workers_per_rank=1, progress=progress)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(got) == n_events
    return n_events / dt


def _pingpong_latency(n_iters=500):
    t_hist = []

    def ping(ctx, events):
        if events[0].data < n_iters:
            ctx.fire(1, "ping", events[0].data + 1)

    def pong(ctx, events):
        ctx.fire(0, "pong", events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(ping, deps=[(1, "pong")])
            ctx.fire(1, "ping", 0)
        else:
            ctx.submit_persistent(pong, deps=[(0, "ping")])

    rt = edat.Runtime(2, workers_per_rank=1, unconsumed="ignore")
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    return dt / (2 * n_iters)   # one-way latency


def run(out: str = None):
    res = {
        "tasks_per_s": _tasks_per_s(),
        "events_per_s_thread": _events_per_s(progress="thread"),
        "events_per_s_workerpoll": _events_per_s(progress="worker"),
        "event_latency_us": _pingpong_latency() * 1e6,
    }
    for k, v in res.items():
        print(f"  micro {k} = {v:.1f}")
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run()
