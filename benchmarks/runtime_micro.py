"""Runtime micro-overheads (paper §V: "some additional overhead associated
with the scheduling of tasks and managing of dependencies"):

  * task throughput: zero-dependency tasks/second;
  * event throughput: rank-to-rank small-event rate (single + batched fire);
  * event latency: ping-pong round-trip / 2;
  * persistent-task dispatch rate;
  * progress-mode comparison (dedicated thread vs idle-worker polling);
  * many-consumer routing: N persistent tasks with distinct eids — linear in
    N through the indexed router (was quadratic with the linear scan);
  * --transport axis: the same event-throughput and ping-pong-latency
    probes across OS processes over repro.net's SocketTransport
    (``--transport socket`` or ``both``), so the bench JSON tracks
    cross-process events/s and one-way latency alongside the in-proc
    numbers.  Socket rates use the in-child wall time of ``Runtime.run``
    (spawn + rendezvous excluded; reported separately as overhead).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

from repro import edat


def _tasks_per_s(n_tasks=2000, workers=2):
    done = []

    def t(ctx, events):
        done.append(None)

    def main(ctx):
        for _ in range(n_tasks):
            ctx.submit(t)

    rt = edat.Runtime(1, workers_per_rank=workers)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(done) == n_tasks
    return n_tasks / dt


def _events_per_s(n_events=2000, progress="thread"):
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            for i in range(n_events):
                ctx.fire(0, "e", i)

    rt = edat.Runtime(2, workers_per_rank=1, progress=progress)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(got) == n_events
    return n_events / dt


def _pingpong_latency(n_iters=500):
    t_hist = []

    def ping(ctx, events):
        if events[0].data < n_iters:
            ctx.fire(1, "ping", events[0].data + 1)

    def pong(ctx, events):
        ctx.fire(0, "pong", events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(ping, deps=[(1, "pong")])
            ctx.fire(1, "ping", 0)
        else:
            ctx.submit_persistent(pong, deps=[(0, "ping")])

    rt = edat.Runtime(2, workers_per_rank=1, unconsumed="ignore")
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    return dt / (2 * n_iters)   # one-way latency


def _events_per_s_batch(n_events=2000):
    """Like _events_per_s but the producer uses one fire_batch call."""
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            ctx.fire_batch([(0, "e", i) for i in range(n_events)])

    rt = edat.Runtime(2, workers_per_rank=1)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(got) == n_events
    return n_events / dt


def _routing_events_per_s(n_consumers, events_per=2):
    """N persistent tasks with N distinct eids; every event must be routed
    to exactly one of them.  Per-event cost is O(1) through the indexed
    router; the seed's linear scan made the whole run quadratic in N."""
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            for i in range(n_consumers):
                ctx.submit_persistent(sink, deps=[(1, f"e{i}")])
        else:
            for _ in range(events_per):
                for i in range(n_consumers):
                    ctx.fire(0, f"e{i}", i)

    rt = edat.Runtime(2, workers_per_rank=1)
    t0 = time.monotonic()
    rt.run(main, timeout=240)
    dt = time.monotonic() - t0
    n = n_consumers * events_per
    assert len(got) == n
    return n / dt


# --------------------------------------------- cross-process (SocketTransport)
# mains are module-level: spawned rank processes must be able to import them

def _sock_sink_main(ctx, n_events=2000):
    def sink(c, events):
        pass

    if ctx.rank == 0:
        ctx.submit_persistent(sink, deps=[(1, "e")])
    else:
        for i in range(n_events):
            ctx.fire(0, "e", i)


def _sock_pingpong_main(ctx, n_iters=500):
    def ping(c, events):
        if events[0].data < n_iters:
            c.fire(1, "ping", events[0].data + 1)

    def pong(c, events):
        c.fire(0, "pong", events[0].data)

    if ctx.rank == 0:
        ctx.submit_persistent(ping, deps=[(1, "pong")])
        ctx.fire(1, "ping", 0)
    else:
        ctx.submit_persistent(pong, deps=[(0, "ping")])


def _socket_events_per_s(n_events=2000):
    t0 = time.monotonic()
    stats = edat.launch_processes(
        2, functools.partial(_sock_sink_main, n_events=n_events),
        timeout=120)
    overhead = time.monotonic() - t0 - stats["run_seconds"]
    return n_events / stats["run_seconds"], overhead


def _socket_pingpong_latency(n_iters=500):
    stats = edat.launch_processes(
        2, functools.partial(_sock_pingpong_main, n_iters=n_iters),
        timeout=120, unconsumed="ignore")
    return stats["run_seconds"] / (2 * n_iters)   # one-way latency


def run(out: str = None, transport: str = "inproc"):
    assert transport in ("inproc", "socket", "both")
    res = {}
    if transport in ("inproc", "both"):
        r250 = _routing_events_per_s(250)
        r1000 = _routing_events_per_s(1000)
        res.update({
            "tasks_per_s": _tasks_per_s(),
            "events_per_s_thread": _events_per_s(progress="thread"),
            "events_per_s_workerpoll": _events_per_s(progress="worker"),
            "events_per_s_batch": _events_per_s_batch(),
            "event_latency_us": _pingpong_latency() * 1e6,
            "routing_events_per_s_250": r250,
            "routing_events_per_s_1000": r1000,
            # ~1.0 when routing is linear in consumer count; << 1 quadratic
            "routing_scaling_1000_vs_250": r1000 / r250,
        })
    if transport in ("socket", "both"):
        ev_s, spawn_s = _socket_events_per_s()
        res["events_per_s_socket"] = ev_s
        res["event_latency_us_socket"] = _socket_pingpong_latency() * 1e6
        res["socket_spawn_overhead_s"] = spawn_s
    for k, v in res.items():
        print(f"  micro {k} = {v:.1f}" if v >= 10 else f"  micro {k} = {v:.3f}")
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out", nargs="?", default=None,
                    help="optional path for the bench JSON")
    ap.add_argument("--transport", choices=("inproc", "socket", "both"),
                    default="inproc",
                    help="which transport axis to measure (default inproc)")
    a = ap.parse_args()
    run(out=a.out, transport=a.transport)
