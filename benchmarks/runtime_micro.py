"""Runtime micro-overheads (paper §V: "some additional overhead associated
with the scheduling of tasks and managing of dependencies"):

  * task throughput: zero-dependency tasks/second;
  * event throughput: rank-to-rank small-event rate (single + batched fire);
  * event latency: ping-pong round-trip / 2;
  * persistent-task dispatch rate;
  * progress-mode comparison (dedicated thread vs idle-worker polling);
  * many-consumer routing: N persistent tasks with distinct eids — linear in
    N through the indexed router (was quadratic with the linear scan).
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro import edat


def _tasks_per_s(n_tasks=2000, workers=2):
    done = []

    def t(ctx, events):
        done.append(None)

    def main(ctx):
        for _ in range(n_tasks):
            ctx.submit(t)

    rt = edat.Runtime(1, workers_per_rank=workers)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(done) == n_tasks
    return n_tasks / dt


def _events_per_s(n_events=2000, progress="thread"):
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            for i in range(n_events):
                ctx.fire(0, "e", i)

    rt = edat.Runtime(2, workers_per_rank=1, progress=progress)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(got) == n_events
    return n_events / dt


def _pingpong_latency(n_iters=500):
    t_hist = []

    def ping(ctx, events):
        if events[0].data < n_iters:
            ctx.fire(1, "ping", events[0].data + 1)

    def pong(ctx, events):
        ctx.fire(0, "pong", events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(ping, deps=[(1, "pong")])
            ctx.fire(1, "ping", 0)
        else:
            ctx.submit_persistent(pong, deps=[(0, "ping")])

    rt = edat.Runtime(2, workers_per_rank=1, unconsumed="ignore")
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    return dt / (2 * n_iters)   # one-way latency


def _events_per_s_batch(n_events=2000):
    """Like _events_per_s but the producer uses one fire_batch call."""
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            ctx.fire_batch([(0, "e", i) for i in range(n_events)])

    rt = edat.Runtime(2, workers_per_rank=1)
    t0 = time.monotonic()
    rt.run(main, timeout=120)
    dt = time.monotonic() - t0
    assert len(got) == n_events
    return n_events / dt


def _routing_events_per_s(n_consumers, events_per=2):
    """N persistent tasks with N distinct eids; every event must be routed
    to exactly one of them.  Per-event cost is O(1) through the indexed
    router; the seed's linear scan made the whole run quadratic in N."""
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            for i in range(n_consumers):
                ctx.submit_persistent(sink, deps=[(1, f"e{i}")])
        else:
            for _ in range(events_per):
                for i in range(n_consumers):
                    ctx.fire(0, f"e{i}", i)

    rt = edat.Runtime(2, workers_per_rank=1)
    t0 = time.monotonic()
    rt.run(main, timeout=240)
    dt = time.monotonic() - t0
    n = n_consumers * events_per
    assert len(got) == n
    return n / dt


def run(out: str = None):
    r250 = _routing_events_per_s(250)
    r1000 = _routing_events_per_s(1000)
    res = {
        "tasks_per_s": _tasks_per_s(),
        "events_per_s_thread": _events_per_s(progress="thread"),
        "events_per_s_workerpoll": _events_per_s(progress="worker"),
        "events_per_s_batch": _events_per_s_batch(),
        "event_latency_us": _pingpong_latency() * 1e6,
        "routing_events_per_s_250": r250,
        "routing_events_per_s_1000": r1000,
        # ~1.0 when routing is linear in consumer count; << 1 when quadratic
        "routing_scaling_1000_vs_250": r1000 / r250,
    }
    for k, v in res.items():
        print(f"  micro {k} = {v:.1f}" if v >= 10 else f"  micro {k} = {v:.3f}")
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    run(out=sys.argv[1] if len(sys.argv) > 1 else None)
