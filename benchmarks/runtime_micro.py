"""Runtime micro-overheads (paper §V: "some additional overhead associated
with the scheduling of tasks and managing of dependencies"):

  * task throughput: zero-dependency tasks/second;
  * event throughput: rank-to-rank small-event rate (single + batched fire);
  * event latency: ping-pong round-trip / 2;
  * persistent-task dispatch rate;
  * progress-mode comparison (dedicated thread vs idle-worker polling);
  * many-consumer routing: N persistent tasks with distinct eids — linear in
    N through the indexed router (was quadratic with the linear scan);
  * session overhead: Session construction -> first task running, inproc
    vs socket (the v2 API layer's cost; the socket number includes spawn
    + rendezvous);
  * --transport axis: the same event-throughput and ping-pong-latency
    probes across OS processes over repro.net's SocketTransport
    (``--transport socket`` or ``both``), so the bench JSON tracks
    cross-process events/s and one-way latency alongside the in-proc
    numbers.  Socket rates use the in-child wall time of the session run
    (spawn + rendezvous excluded; reported separately as overhead);
  * --durable axis: A/B of the durable task log (repro.durable) —
    journaling overhead vs plain fires (acceptance bar: <= 5%) plus the
    raw BatchLogger->sqlite append bandwidth.

All probes run through the v2 ``edat.Session`` API, so any regression in
the Session layer itself shows up in every number here.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

from repro import edat


#: most recent stats per transport axis — --insights analyzes these
_LAST = {}


def _inproc_stats(main, *, ranks, workers=1, progress="thread",
                  unconsumed="error", timeout=240, metrics=True,
                  durable=None):
    with edat.Session(ranks, workers_per_rank=workers, progress=progress,
                      unconsumed=unconsumed, timeout=timeout,
                      metrics=metrics, durable=durable) as s:
        s.run(main)
        if metrics:
            _LAST["inproc"] = s.stats
        return s.stats


def _tasks_per_s(n_tasks=2000, workers=2):
    done = []

    def t(ctx, events):
        done.append(None)

    def main(ctx):
        for _ in range(n_tasks):
            ctx.submit(t)

    stats = _inproc_stats(main, ranks=1, workers=workers, timeout=120)
    assert len(done) == n_tasks
    return n_tasks / stats["run_seconds"]


def _events_per_s(n_events=2000, progress="thread", metrics=True,
                  durable=None):
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            for i in range(n_events):
                ctx.fire(0, "e", i)

    stats = _inproc_stats(main, ranks=2, progress=progress, timeout=120,
                          metrics=metrics, durable=durable)
    assert len(got) == n_events
    return n_events / stats["run_seconds"]


def _metrics_overhead_pct(n_events=20000, reps=8):
    """Same-session A/B of the always-on counters, interleaved on/off.

    Throughput interference on a shared box is one-sided — descheduling
    and noisy neighbours only ever make a run *slower* — so the robust
    estimate compares the top half of each side's rates (mean of the
    best ``reps//2``), which keeps the least-interfered runs and is
    stable where a single best-of pair or a per-pair median is not.
    The acceptance bar is <= 5% — the counters stay on by default."""
    # discarded warm-up pair: the first run of a fresh process pays the
    # interpreter/allocator cold start, and it would always be an "on" run
    _events_per_s(n_events, metrics=True)
    _events_per_s(n_events, metrics=False)
    on, off = [], []
    for i in range(reps):
        on.append(_events_per_s(n_events, metrics=True))
        off.append(_events_per_s(n_events, metrics=False))
    k = max(1, reps // 2)
    top_on = sum(sorted(on)[-k:]) / k
    top_off = sum(sorted(off)[-k:]) / k
    return (top_off - top_on) / top_off * 100.0, top_off


def _durable_overhead_pct(n_events=20000, reps=10, trials=3):
    """Same-session A/B of durable journaling (``durable=True``, the
    in-memory log backend).  "On" pays the per-fire idempotency key +
    payload snapshot + queue append; the backend write itself is off the
    hot path (BatchLogger's writer thread).

    Two debiasing measures, both validated with A/A runs on a 1-core
    box:

    * pair order alternates every rep (ABBA) — throughput drifts upward
      over a process's lifetime, so a fixed on-then-off order hands the
      second side a systematic advantage (the unbalanced design read
      several points of phantom "overhead" with durable a no-op);
    * per side, the top-2 mean of the reps is compared — interference
      (GIL scheduling regimes, VM steal time) is one-sided, it only
      ever *slows* a run, so the fastest observations are the best
      estimate of each side's true rate and a mean over all reps mostly
      measures the noise.

    On top of that, the recorded value is the *median* of ``trials``
    independent estimates: single estimates still carry a few points of
    spread from minute-scale regime shifts, and the median rejects a
    trial that lands inside one.

    The acceptance bar is <= 5% — durable stays opt-in, but opting in
    must not change the shape of a program's performance."""
    ests = []
    for _ in range(trials):
        _events_per_s(n_events, durable=True)  # discarded warm-up pair
        _events_per_s(n_events)
        on, off = [], []
        for i in range(reps):
            if i % 2 == 0:
                on.append(_events_per_s(n_events, durable=True))
                off.append(_events_per_s(n_events))
            else:
                off.append(_events_per_s(n_events))
                on.append(_events_per_s(n_events, durable=True))
        k = min(2, reps)
        top_on = sum(sorted(on)[-k:]) / k
        top_off = sum(sorted(off)[-k:]) / k
        ests.append((top_off - top_on) / top_off * 100.0)
    ests.sort()
    return ests[len(ests) // 2]


def _log_appends_per_s(n_records=50000):
    """Raw task-log bandwidth: records/second landed in a sqlite backend
    through the BatchLogger's writer thread (append returns immediately;
    flush blocks until the backend caught up)."""
    import tempfile
    from repro.durable.log import BatchLogger, FIRED, SqliteLog

    with tempfile.TemporaryDirectory(prefix="edat_bench_durable_") as td:
        lg = BatchLogger(SqliteLog(os.path.join(td, "log.sqlite")))
        t0 = time.monotonic()
        for i in range(n_records):
            lg.append(("0>1/e#%d@bench" % i, FIRED, "e", 0, 1, None))
        ok = lg.flush(120.0)
        dt = time.monotonic() - t0
        lg.close()
        assert ok, "task log writer did not drain within 120s"
        return n_records / dt


def _pingpong_latency(n_iters=500):
    def ping(ctx, events):
        if events[0].data < n_iters:
            ctx.fire(1, "ping", events[0].data + 1)

    def pong(ctx, events):
        ctx.fire(0, "pong", events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(ping, deps=[(1, "pong")])
            ctx.fire(1, "ping", 0)
        else:
            ctx.submit_persistent(pong, deps=[(0, "ping")])

    stats = _inproc_stats(main, ranks=2, unconsumed="ignore", timeout=120)
    return stats["run_seconds"] / (2 * n_iters)   # one-way latency


def _events_per_s_batch(n_events=2000):
    """Like _events_per_s but the producer uses one fire_batch call."""
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            ctx.fire_batch([(0, "e", i) for i in range(n_events)])

    stats = _inproc_stats(main, ranks=2, timeout=120)
    assert len(got) == n_events
    return n_events / stats["run_seconds"]


def _routing_events_per_s(n_consumers, events_per=2):
    """N persistent tasks with N distinct eids; every event must be routed
    to exactly one of them.  Per-event cost is O(1) through the indexed
    router; the seed's linear scan made the whole run quadratic in N."""
    got = []

    def sink(ctx, events):
        got.append(None)

    def main(ctx):
        if ctx.rank == 0:
            for i in range(n_consumers):
                ctx.submit_persistent(sink, deps=[(1, f"e{i}")])
        else:
            for _ in range(events_per):
                for i in range(n_consumers):
                    ctx.fire(0, f"e{i}", i)

    stats = _inproc_stats(main, ranks=2, timeout=240)
    n = n_consumers * events_per
    assert len(got) == n
    return n / stats["run_seconds"]


# ------------------------------------------------- session overhead (v2 API)
class _FirstTaskProbe:
    """Program that records the wall-clock time its first task runs
    (CLOCK_MONOTONIC is system-wide on Linux, so the child's stamp is
    comparable with the driver's construction time)."""

    def __init__(self):
        self.t_first = None

    def start(self, ctx):
        if ctx.rank == 0:
            ctx.submit(self._t)

    def _t(self, ctx, events):
        if self.t_first is None:
            self.t_first = time.monotonic()

    def result(self):
        return self.t_first


def _session_overhead_s(transport: str) -> float:
    """Session construct -> first task executing, in seconds."""
    t0 = time.monotonic()
    t_first = edat.run(edat.deferred(_FirstTaskProbe), ranks=1,
                       transport=transport, timeout=120)
    return t_first - t0


# --------------------------------------------- cross-process (SocketTransport)
# mains are module-level: spawned rank processes must be able to import them

def _sock_sink_main(ctx, n_events=2000):
    def sink(c, events):
        pass

    if ctx.rank == 0:
        ctx.submit_persistent(sink, deps=[(1, "e")])
    else:
        for i in range(n_events):
            ctx.fire(0, "e", i)


def _sock_pingpong_main(ctx, n_iters=500):
    def ping(c, events):
        if events[0].data < n_iters:
            c.fire(1, "ping", events[0].data + 1)

    def pong(c, events):
        c.fire(0, "pong", events[0].data)

    if ctx.rank == 0:
        ctx.submit_persistent(ping, deps=[(1, "pong")])
        ctx.fire(1, "ping", 0)
    else:
        ctx.submit_persistent(pong, deps=[(0, "ping")])


def _socket_stats(main, *, unconsumed="error"):
    with edat.Session(2, transport="socket", unconsumed=unconsumed,
                      timeout=120) as s:
        t0 = time.monotonic()
        s.run(main)
        wall = time.monotonic() - t0
        _LAST["socket"] = s.stats
        return s.stats, wall


def _socket_events_per_s(n_events=2000):
    stats, wall = _socket_stats(
        functools.partial(_sock_sink_main, n_events=n_events))
    overhead = wall - stats["run_seconds"]
    return n_events / stats["run_seconds"], overhead


def _socket_pingpong_latency(n_iters=500):
    stats, _ = _socket_stats(
        functools.partial(_sock_pingpong_main, n_iters=n_iters),
        unconsumed="ignore")
    return stats["run_seconds"] / (2 * n_iters)   # one-way latency


def run(out: str = None, transport: str = "inproc", insights: bool = False,
        durable: bool = False):
    assert transport in ("inproc", "socket", "both")
    res = {}
    if durable:
        res.update({
            # A/B vs plain fires (negative = noise; acceptance bar <= 5)
            "durable_overhead_pct": _durable_overhead_pct(),
            "log_appends_per_s": _log_appends_per_s(),
        })
    if transport in ("inproc", "both"):
        r250 = _routing_events_per_s(250)
        r1000 = _routing_events_per_s(1000)
        overhead_pct, _ = _metrics_overhead_pct()
        res.update({
            "tasks_per_s": _tasks_per_s(),
            "events_per_s_thread": _events_per_s(progress="thread"),
            "events_per_s_workerpoll": _events_per_s(progress="worker"),
            "events_per_s_batch": _events_per_s_batch(),
            "event_latency_us": _pingpong_latency() * 1e6,
            "routing_events_per_s_250": r250,
            "routing_events_per_s_1000": r1000,
            # ~1.0 when routing is linear in consumer count; << 1 quadratic
            "routing_scaling_1000_vs_250": r1000 / r250,
            "session_overhead_s_inproc": _session_overhead_s("inproc"),
            # counters A/B (negative = noise; acceptance bar is <= 5)
            "metrics_overhead_pct": overhead_pct,
        })
    if transport in ("socket", "both"):
        ev_s, spawn_s = _socket_events_per_s()
        res["events_per_s_socket"] = ev_s
        res["event_latency_us_socket"] = _socket_pingpong_latency() * 1e6
        res["socket_spawn_overhead_s"] = spawn_s
        res["session_overhead_s_socket"] = _session_overhead_s("socket")
    for k, v in res.items():
        print(f"  micro {k} = {v:.1f}" if v >= 10 else f"  micro {k} = {v:.3f}")
    if insights:
        from repro.insights import analyze
        for axis in sorted(_LAST):
            findings = analyze(_LAST[axis])
            print(f"  insights ({axis}, last run): "
                  + ("none — counters look healthy" if not findings else ""))
            for f in findings:
                print(f"    {f}")
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out", nargs="?", default=None,
                    help="optional path for the bench JSON")
    ap.add_argument("--transport", choices=("inproc", "socket", "both"),
                    default="inproc",
                    help="which transport axis to measure (default inproc)")
    ap.add_argument("--insights", action="store_true",
                    help="run repro.insights.analyze on the last run's "
                         "Session.stats per transport and print findings")
    ap.add_argument("--durable", action="store_true",
                    help="A/B the durable task log: journaling overhead "
                         "vs plain fires (bar: <= 5%%) and raw sqlite "
                         "append bandwidth")
    a = ap.parse_args()
    run(out=a.out, transport=a.transport, insights=a.insights,
        durable=a.durable)
