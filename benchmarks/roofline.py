"""Roofline analysis (§Roofline): three terms per (arch x shape) from the
dry-run's compiled artifacts.

  compute term    = HLO_FLOPs / (chips * 197 TFLOP/s)     [bf16 v5e]
  memory term     = HLO_bytes / (chips * 819 GB/s)
  collective term = collective_bytes / (chips * 50 GB/s)  [ICI per link]

All numerators come from the trip-count-aware HLO roll-up
(repro.launch.hlo_analysis) over the SPMD-partitioned module, whose shapes
are per-device — so numerator/chips is already applied.  MODEL_FLOPS is
6*N*D (dense) or 6*N_active*D (MoE) with D = tokens per step; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.  ``mfu_proxy`` =
ideal model-flop time / dominant term — the roofline fraction we hillclimb
in §Perf.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 / chip (v5e)
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link


def model_flops_per_step(arch: str, shape: str) -> float:
    """6 * N(active) * D analytic model FLOPs (global, per step)."""
    from repro.configs import ARCHS, SHAPES
    import jax
    import numpy as np
    from repro.models import build_model

    spec = ARCHS[arch]
    sh = SHAPES[shape]
    model = build_model(spec.cfg)
    ab = model.abstract_params()
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(ab))
    n_active = total
    if spec.cfg.moe is not None:
        m = spec.cfg.moe
        flat = jax.tree.leaves_with_path(ab)
        routed = sum(int(np.prod(x.shape)) for p, x in flat
                     if any(getattr(k, "key", "") in ("wg", "wu", "wd")
                            for k in p))
        n_active = total - routed * (1.0 - m.top_k / m.n_experts)
    if shape.startswith("train"):
        tokens = sh.seq * sh.global_batch
        return 6.0 * n_active * tokens
    if shape.startswith("prefill"):
        tokens = sh.seq * sh.global_batch
        return 2.0 * n_active * tokens      # forward only
    # decode: one token per sequence
    return 2.0 * n_active * sh.global_batch


def _min_bytes_per_step(arch: str, shape: str, chips: int) -> float:
    """Analytic HBM floor (per device): weights read once (+cache for
    decode, x3 weight traffic for train: read + grad write + opt update)."""
    from repro.configs import ARCHS, SHAPES
    import jax
    import numpy as np
    from repro.models import build_model

    spec = ARCHS[arch]
    sh = SHAPES[shape]
    model = build_model(spec.cfg)
    ab = model.abstract_params()
    pbytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                 for x in jax.tree.leaves(ab))
    if shape.startswith("train"):
        return 3.0 * pbytes / chips
    if shape.startswith("prefill"):
        return pbytes / chips
    cache = 0
    try:
        cab = model.abstract_cache(sh.global_batch, sh.seq)
        cache = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                    for x in jax.tree.leaves(cab))
    except Exception:  # noqa: BLE001
        pass
    return (pbytes + cache) / chips


def roofline_row(res: dict) -> Optional[dict]:
    if not res.get("ok") or "analysis" not in res:
        return None
    a = res["analysis"]
    chips = res["n_devices"]
    compute = a["flops"] / PEAK_FLOPS                 # per-device seconds
    # memory term: 2x outputs-only traffic (each materialised buffer is
    # written once and read ~once by a fused consumer).  The CPU-fused
    # operand+output sum is reported as the pessimistic upper bound.
    mem_out = a.get("mem_bytes_out", a["mem_bytes"] / 3.0)
    memory = 2.0 * mem_out / HBM_BW
    memory_ub = a["mem_bytes"] / HBM_BW
    coll = a["collective_wire_total"] / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", coll), key=lambda kv: kv[1])
    mf = model_flops_per_step(res["arch"], res["shape"])
    hlo_global = a["flops"] * chips
    kind = (res.get("meta") or {}).get("kind", "train")
    ideal_c = mf / chips / PEAK_FLOPS
    ideal_m = _min_bytes_per_step(res["arch"], res["shape"], chips) / HBM_BW
    # the achievable floor is whichever resource the *ideal* program needs
    # more of; the roofline fraction is floor / modelled-bound
    ideal = max(ideal_c, ideal_m) if kind == "decode" else ideal_c
    row = {
        "arch": res["arch"], "shape": res["shape"], "chips": chips,
        "kind": kind,
        "compute_s": compute, "memory_s": memory,
        "memory_ub_s": memory_ub, "collective_s": coll,
        "dominant": dominant[0], "bound_s": dominant[1],
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / max(hlo_global, 1.0),
        "ideal_s": ideal,
        "roofline_frac": ideal / max(dominant[1], 1e-30),
        "mem_per_dev_bytes": (res.get("memory") or {}).get(
            "temp_size_in_bytes"),
    }
    return row


def run(dryrun_dir: str = "experiments/dryrun/pod16x16",
        out: str = "experiments/roofline_pod16x16.json",
        quiet: bool = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        row = roofline_row(res)
        if row:
            rows.append(row)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if not quiet:
        hdr = (f"{'arch':22s} {'shape':11s} {'compute':>9s} {'memory':>9s} "
               f"{'coll':>9s} {'bound':>10s} {'useful':>7s} {'RLfrac':>7s}")
        print(hdr)
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:11s} "
                  f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
                  f"{r['collective_s']:9.4f} {r['dominant']:>10s} "
                  f"{r['useful_ratio']:7.3f} {r['roofline_frac']:7.3f}")
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun/pod16x16"
    o = sys.argv[2] if len(sys.argv) > 2 else \
        "experiments/roofline_pod16x16.json"
    run(d, o)
