"""Elastic-trainer throughput: steps/s and grad-events/s over rank counts,
in-proc (threads-as-ranks) vs distributed (OS processes over the
coalescing SocketTransport, several ranks per process).

``--transport socket`` runs the same trainer program through a socket
``edat.Session`` — SPMD across spawned processes, co-located ranks
exchanging gradients in-process (zero socket frames) and remote ranks
over the wire.  Each row records:

* ``steps_per_s``        — global optimiser steps per second of (in-child)
  run time, first-JIT included (both transports pay it, so A/B holds);
* ``grad_events_per_s``  — gradient events *consumed* per second, summed
  over every rank's quorum collections (``n_grads + n_stale`` per
  recorded step) — the trainer-level event rate the coalescing fast
  path feeds;
* ``loss_first``/``loss_last`` — sanity that the thing actually trains.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _row_from_history(history, steps, wall, label, ranks, procs):
    grads = sum(m["n_grads"] + m["n_stale"] for m in history)
    loss_first = float(np.mean([m["loss"] for m in history
                                if m["step"] <= 2] or [np.nan]))
    loss_last = float(np.mean([m["loss"] for m in history
                               if m["step"] >= steps - 1] or [np.nan]))
    row = {"impl": label, "ranks": ranks, "procs": procs,
           "wall_s": wall, "steps_per_s": steps / max(wall, 1e-9),
           "grad_events_per_s": grads / max(wall, 1e-9),
           "loss_first": loss_first, "loss_last": loss_last}
    print(f"  trainer {label:12s} ranks={ranks} procs={procs} "
          f"steps/s={row['steps_per_s']:7.2f} "
          f"grad-ev/s={row['grad_events_per_s']:8.1f} "
          f"loss {loss_first:.3f}->{loss_last:.3f}")
    return row


def run(steps: int = 12, ranks=(1, 2, 4), transport: str = "inproc",
        procs=None, out: str = None):
    assert transport in ("inproc", "socket")
    from repro.runtime_dist.trainer import _demo_cfgs

    rows = []
    for nr in ranks:
        model_cfg, data_cfg, opt_cfg, trainer_cfg = _demo_cfgs(
            nr, steps, ckpt_dir=None)
        if transport == "socket":
            from repro import edat
            from repro.runtime_dist import trainer_program
            np_ = min(procs or max(1, nr // 2), nr)
            with edat.Session(nr, procs=np_, transport="socket",
                              timeout=600.0, unconsumed="ignore",
                              workers_per_rank=trainer_cfg.workers_per_rank
                              ) as s:
                s.run(edat.deferred(trainer_program, model_cfg, data_cfg,
                                    opt_cfg, trainer_cfg))
                res = s.gather()
                wall = float(s.stats.get("run_seconds", 0.0))
            rows.append(_row_from_history(res["history"], steps, wall,
                                          "edat-socket", nr, np_))
        else:
            from repro.models import build_model
            from repro.runtime_dist import EventDrivenTrainer
            tr = EventDrivenTrainer(build_model(model_cfg), data_cfg,
                                    opt_cfg, trainer_cfg)
            t0 = time.monotonic()
            out_run = tr.run(timeout=600.0)
            wall = time.monotonic() - t0
            rows.append(_row_from_history(out_run["history"], steps, wall,
                                          "edat-inproc", nr, 1))
    result = {"steps": steps, "transport": transport, "rows": rows}
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out", nargs="?", default=None,
                    help="optional path for the bench JSON")
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc")
    ap.add_argument("--ranks", type=int, nargs="+", default=None,
                    help="rank counts to sweep (default: 1 2 4 inproc, "
                         "2 4 socket)")
    ap.add_argument("--procs", type=int, default=None,
                    help="processes for socket runs (default ranks//2)")
    ap.add_argument("--steps", type=int, default=12)
    a = ap.parse_args()
    ranks = tuple(a.ranks) if a.ranks else (
        (2, 4) if a.transport == "socket" else (1, 2, 4))
    run(steps=a.steps, ranks=ranks, transport=a.transport, procs=a.procs,
        out=a.out)
