"""Paper Fig 5 analogue: in-situ analytics bandwidth + latency vs number
of analytics cores, EDAT pipeline vs bespoke (MONC-style) comms stack."""
from __future__ import annotations

import json
import os

from repro.analytics import BespokeAnalytics, EdatAnalytics, InsituCfg


def run(analytics=(1, 2, 4, 8), items: int = 64, elems: int = 1024,
        out: str = None):
    rows = []
    for n in analytics:
        cfg = InsituCfg(n_analytics=n, items_per_producer=items,
                        field_elems=elems, n_fields=2)
        e = EdatAnalytics(cfg).run()
        b = BespokeAnalytics(cfg).run()
        rows.append({"analytics_ranks": n, "edat": e, "bespoke": b})
        print(f"  insitu n={n:2d} edat bw={e['bandwidth_items_s']:9.1f}/s "
              f"lat={e['mean_latency_s']*1e3:7.2f}ms | bespoke "
              f"bw={b['bandwidth_items_s']:9.1f}/s "
              f"lat={b['mean_latency_s']*1e3:7.2f}ms")
    result = {"items_per_producer": items, "field_elems": elems,
              "rows": rows}
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    run()
