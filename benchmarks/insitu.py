"""Paper Fig 5 analogue: in-situ analytics bandwidth + latency vs number
of analytics cores, EDAT pipeline vs bespoke (MONC-style) comms stack.

``--transport socket`` additionally runs the EDAT pipeline with one OS
process per rank (2n processes) over the coalescing SocketTransport; raw
field slices cross process boundaries as zero-copy protocol-5 frames and
the row gains an ``edat_socket`` entry (bandwidth from in-child run
time).
"""
from __future__ import annotations

import argparse
import json
import os

from repro.analytics import BespokeAnalytics, EdatAnalytics, InsituCfg
# the Session-backed distributed run (the deprecated shim minus the
# warning), so the bench and the v1 compat path can never drift apart
from repro.analytics.insitu import _distributed_insitu as _socket_insitu


def run(analytics=(1, 2, 4, 8), items: int = 64, elems: int = 1024,
        out: str = None, transport: str = "inproc"):
    assert transport in ("inproc", "socket", "both")
    rows = []
    for n in analytics:
        cfg = InsituCfg(n_analytics=n, items_per_producer=items,
                        field_elems=elems, n_fields=2)
        row = {"analytics_ranks": n}
        if transport in ("inproc", "both"):
            e = EdatAnalytics(cfg).run()
            b = BespokeAnalytics(cfg).run()
            row.update(edat=e, bespoke=b)
            print(f"  insitu n={n:2d} edat bw={e['bandwidth_items_s']:9.1f}/s "
                  f"lat={e['mean_latency_s']*1e3:7.2f}ms | bespoke "
                  f"bw={b['bandwidth_items_s']:9.1f}/s "
                  f"lat={b['mean_latency_s']*1e3:7.2f}ms")
        if transport in ("socket", "both"):
            s = _socket_insitu(cfg)
            row["edat_socket"] = s
            print(f"  insitu n={n:2d} edat-sock "
                  f"bw={s['bandwidth_items_s']:9.1f}/s "
                  f"lat={s['mean_latency_s']*1e3:7.2f}ms "
                  f"({s['results']} reductions)")
        rows.append(row)
    result = {"items_per_producer": items, "field_elems": elems,
              "transport": transport, "rows": rows}
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("out", nargs="?", default=None,
                    help="optional path for the bench JSON")
    ap.add_argument("--transport", choices=("inproc", "socket", "both"),
                    default="inproc")
    ap.add_argument("--analytics", type=int, nargs="+", default=None,
                    help="analytics-rank counts to sweep (default 1 2 4 8; "
                         "socket default 1 2 4)")
    ap.add_argument("--items", type=int, default=64)
    ap.add_argument("--elems", type=int, default=1024)
    a = ap.parse_args()
    analytics = tuple(a.analytics) if a.analytics else (
        (1, 2, 4) if a.transport != "inproc" else (1, 2, 4, 8))
    run(analytics=analytics, items=a.items, elems=a.elems, out=a.out,
        transport=a.transport)
