"""Convenience alias: ``from repro import edat``."""
from repro.core import *  # noqa: F401,F403
from repro.core import __all__ as _core_all
from repro.net import (ProcessGroup, SocketTransport,  # noqa: F401
                       launch_processes)

__all__ = list(_core_all) + ["ProcessGroup", "SocketTransport",
                             "launch_processes"]
