"""Convenience alias: ``from repro import edat``."""
from repro.core import *  # noqa: F401,F403
from repro.core import __all__  # noqa: F401
