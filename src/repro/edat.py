"""``from repro import edat`` — the public facade (v2).

Everything lives in :mod:`repro.api`: ``Session``/``run`` (the one way
programs start), typed ``Channel``\\ s, the ``Program`` protocol,
driver-side ``Future``\\ s, collective patterns, timers, and the core /
distribution re-exports.  The v1 entry points (``Runtime.run``,
``distributed_*``) remain importable but emit DeprecationWarnings.
"""
from repro.api import *  # noqa: F401,F403
from repro.api import __all__ as _api_all

__all__ = list(_api_all)
