from .insitu import InsituCfg, EdatAnalytics, BespokeAnalytics
