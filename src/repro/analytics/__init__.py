from .insitu import (InsituCfg, EdatAnalytics, BespokeAnalytics,
                     distributed_insitu, insitu_program)
