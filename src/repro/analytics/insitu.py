"""MONC-style in-situ data analytics (paper §VI, Figs 4-5).

Computational ranks saturate their analytics rank with raw ``field``
events; analytics ranks run the paper's pipeline as EDAT tasks:

  * a persistent *registration* task — a computational core registers, and
    per-core handler + deregistration tasks are submitted (paper Fig 4);
  * per-field persistent handler tasks that process raw data (arithmetic)
    and contribute to an inter-analytics reduction via events;
  * the reduction root is distributed round-robin over analytics ranks per
    (field, timestep) — the paper's explanation for bandwidth levelling
    off rather than degrading;
  * a persistent *writer federator* task on the root consumes the reduced
    value ("writes" it) and records the end-to-end latency.

The baseline (``BespokeAnalytics``) mimics the original MONC comms stack:
a single handler thread pool per rank with one coarse global lock
protecting shared state, synchronous reductions through a shared
structure, and explicit memory-cleaning passes that lock out progress —
the design the paper replaced.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import edat
from repro.core.deprecation import warn_deprecated


@dataclasses.dataclass
class InsituCfg:
    n_analytics: int = 2
    items_per_producer: int = 50
    field_elems: int = 512       # elements per raw data item
    n_fields: int = 2


def _analyse(x: np.ndarray) -> np.ndarray:
    """The per-item arithmetic of the paper's tests (ops + local reduce)."""
    return np.array([x.sum(), (x * x).sum(), x.min(), x.max()])


# ------------------------------------------------------------------- EDAT
class EdatAnalytics:
    """1:1 computational:analytics ranks (paper's benchmark setup):
    ranks [0, n) are analytics, ranks [n, 2n) are computational.

    A v2 ``edat.Program`` over ``2 * cfg.n_analytics`` ranks: declares
    its typed channels (including the per-(field, timestep) reduction
    channels, which are enumerable upfront from the config), attaches to
    *any* SPMD context via :meth:`start`, and reports through
    :meth:`result` — so the pipeline runs threads-as-ranks (:meth:`run`)
    or across OS processes (``edat.run(edat.deferred(insitu_program,
    cfg_kw), ranks=2n, transport="socket")``).  Each analytics rank
    knows upfront how many (field, timestep) reductions it roots; when
    its writer federator has consumed them all it fires one
    ``insitu_done`` event, and a transitory gather task on rank 0 folds
    those into ``self.summary`` (result count + mean latency) — the
    cross-process replacement for reading ``self.results`` from shared
    memory."""

    def __init__(self, cfg: InsituCfg, workers_per_rank: int = 4):
        self.cfg = cfg
        self.workers = workers_per_rank
        self.results: List[tuple] = []
        self._mu = threading.Lock()
        self.t0 = 0.0
        n = cfg.n_analytics
        self._done_count = [0] * n
        self._lat_sum = [0.0] * n
        #: aggregated by rank 0's gather task: {"results", "mean_latency_s"}
        self.summary: Optional[Dict[str, float]] = None
        #: called (on rank 0's process) with the summary dict
        self.on_summary = None

    @property
    def channels(self) -> Sequence[edat.Channel]:
        """The pipeline's typed event vocabulary, enumerable upfront: the
        registration/data/completion channels plus one reduction channel
        per (field, timestep) pair."""
        cfg = self.cfg
        per_field = cfg.items_per_producer // cfg.n_fields
        chans = [edat.Channel("register", payload=int),
                 edat.Channel("field", payload=dict),
                 edat.Channel("dereg", payload=int),
                 edat.Channel("insitu_done", payload=dict)]
        chans += [edat.Channel(f"partial.{fid}.{ts}", payload=dict)
                  for fid in range(cfg.n_fields)
                  for ts in range(per_field)]
        return chans

    def result(self) -> Optional[Dict[str, float]]:
        """Gathered output (rank 0's process): the reduction summary."""
        return self.summary

    def expected_roots(self, rank: int) -> int:
        """How many (field, timestep) reductions ``rank`` roots."""
        cfg = self.cfg
        per_field = cfg.items_per_producer // cfg.n_fields
        return sum(1 for fid in range(cfg.n_fields)
                   for ts in range(per_field)
                   if (fid + ts) % cfg.n_analytics == rank)

    def run(self) -> Dict[str, float]:
        """In-proc convenience: all 2n ranks as threads in one Session."""
        cfg = self.cfg
        n = cfg.n_analytics
        self.t0 = time.monotonic()
        with edat.Session(2 * n, workers_per_rank=self.workers,
                          unconsumed="error", timeout=600) as s:
            s.run(self)
        dt = time.monotonic() - self.t0
        raw = cfg.n_analytics * cfg.items_per_producer
        lat = np.mean([r[1] for r in self.results]) if self.results else 0
        return {"raw_items": raw, "results": len(self.results),
                "seconds": dt, "bandwidth_items_s": raw / max(dt, 1e-9),
                "mean_latency_s": float(lat)}

    def start(self, ctx: edat.Context):
        """Attach one rank's role (analytics or computational) to any
        in-proc or distributed runtime."""
        n = self.cfg.n_analytics
        if ctx.rank < n:
            self._analytics_main(ctx)
        else:
            self._producer_main(ctx)

    # -- analytics side -------------------------------------------------------
    def _analytics_main(self, ctx: edat.Context):
        cfg = self.cfg
        n = cfg.n_analytics

        def on_register(ctx2, events):
            core = events[0].data
            # per-core handler + deregistration tasks (paper Fig 4)
            ctx2.submit_persistent(on_field, deps=[(core, "field")],
                                   name=f"handler.{core}")
            ctx2.submit(on_deregister, deps=[(core, "dereg")])

        def on_field(ctx2, events):
            item = events[0].data
            partial = _analyse(item["data"])
            # events are tagged with field+timestep (paper: "data is sent
            # tagged with the timestep and field name"); the reduction root
            # is distributed round-robin over analytics ranks
            root = (item["fid"] + item["ts"]) % n
            eid = f"partial.{item['fid']}.{item['ts']}"
            ctx2.fire(root if root != ctx2.rank else edat.SELF, eid,
                      {"t_fire": item["t_fire"], "partial": partial})

        def on_partial(ctx2, events):
            # reduction across analytics ranks: ALL-sourced dependency on
            # this (field, timestep)'s tagged events
            datas = [e.data for e in events]
            total = np.sum([d["partial"] for d in datas], axis=0)
            t_fire = min(d["t_fire"] for d in datas)
            lat = time.monotonic() - t_fire
            with self._mu:
                self.results.append((total, lat))
                self._done_count[ctx2.rank] += 1
                self._lat_sum[ctx2.rank] += lat
                done = self._done_count[ctx2.rank] == expected
            if done:
                self._fire_done(ctx2)

        def on_deregister(ctx2, events):
            ctx2.remove_task(f"handler.{events[0].data}")

        ctx.submit_persistent(on_register, deps=[(edat.ANY, "register")],
                              name="registration")
        if ctx.rank == 0:
            ctx.submit(self._gather_task,
                       deps=[(r, "insitu_done") for r in range(n)],
                       name="insitu-gather")
        expected = self.expected_roots(ctx.rank)
        if expected == 0:
            # this rank roots nothing (more analytics ranks than (field,
            # timestep) residues): report an empty completion immediately
            self._fire_done(ctx)
        # writer federator: one task per (field, timestep) this rank roots.
        # Dependencies name the n analytics ranks explicitly (EDAT_ALL would
        # also include the computational ranks).
        assert cfg.items_per_producer % cfg.n_fields == 0
        per_field = cfg.items_per_producer // cfg.n_fields
        for fid in range(cfg.n_fields):
            for ts in range(per_field):
                if (fid + ts) % n == ctx.rank:
                    ctx.submit(on_partial,
                               deps=[(r, f"partial.{fid}.{ts}")
                                     for r in range(n)])

    def _fire_done(self, ctx: edat.Context) -> None:
        with self._mu:
            payload = {"rank": ctx.rank,
                       "results": self._done_count[ctx.rank],
                       "lat_sum": self._lat_sum[ctx.rank]}
        ctx.fire(0 if ctx.rank != 0 else edat.SELF, "insitu_done", payload)

    def _gather_task(self, ctx: edat.Context, events):
        """Rank 0, once: fold every analytics rank's completion report."""
        total = sum(ev.data["results"] for ev in events)
        lat_sum = sum(ev.data["lat_sum"] for ev in events)
        self.summary = {"results": total,
                        "mean_latency_s": lat_sum / max(total, 1)}
        if self.on_summary is not None:
            self.on_summary(self.summary)

    # -- computational side -----------------------------------------------------
    def _producer_main(self, ctx: edat.Context):
        cfg = self.cfg
        n = cfg.n_analytics
        target = ctx.rank - n          # my analytics core
        ctx.fire(target, "register", ctx.rank)
        rng = np.random.default_rng(ctx.rank)
        for i in range(cfg.items_per_producer):
            fid = i % cfg.n_fields
            data = rng.standard_normal(cfg.field_elems)
            # ref=True: the array is never touched again — the coalescing
            # socket transport ships the field slice zero-copy
            ctx.fire(target, "field",
                     {"fid": fid, "ts": i // cfg.n_fields, "data": data,
                      "t_fire": time.monotonic()}, ref=True)
        ctx.fire(target, "dereg", ctx.rank)


# ------------------------------------------------- distributed (processes)
def insitu_program(cfg_kw: Dict, workers_per_rank: int = 4
                   ) -> EdatAnalytics:
    """Program factory for ``edat.run``/``Session`` (wrap in
    ``edat.deferred`` so each spawned process builds its own pipeline):
    2n ranks, analytics [0, n) and computational [n, 2n)."""
    return EdatAnalytics(InsituCfg(**cfg_kw), workers_per_rank)


def _distributed_insitu(cfg: InsituCfg, timeout: float = 180.0,
                        **launch_kwargs) -> Dict[str, float]:
    """Session-backed distributed run returning the v1-shaped metrics
    dict (bandwidth from the in-child ``run_seconds``).  Shared by the
    deprecation shim and the benchmarks."""
    import dataclasses as _dc
    # default matches the v1 helper (children ran the Runtime default of
    # one worker per rank) — the benchmark baselines depend on it
    workers = launch_kwargs.pop("workers_per_rank", 1)
    # v1 launcher kwargs that moved in v2: keep the old contract working
    procs = launch_kwargs.pop("n_procs", None)
    check = launch_kwargs.pop("check", True)
    join_timeout = launch_kwargs.pop("join_timeout", None)
    with edat.Session(2 * cfg.n_analytics, procs=procs,
                      transport="socket", timeout=timeout,
                      workers_per_rank=workers, **launch_kwargs) as s:
        s.start(edat.deferred(insitu_program, _dc.asdict(cfg), workers))
        s.wait(join_timeout, check=check)
        summary = s.gather()
        stats = s.stats
    raw = cfg.n_analytics * cfg.items_per_producer
    dt = max(float(stats.get("run_seconds", 0.0)), 1e-9)
    return {"raw_items": raw, "results": int(summary["results"]),
            "seconds": dt, "bandwidth_items_s": raw / dt,
            "mean_latency_s": float(summary["mean_latency_s"])}


def distributed_insitu(cfg: InsituCfg, timeout: float = 180.0,
                       **launch_kwargs) -> Dict[str, float]:
    """Deprecated v1 helper — use the v2 Session API::

        edat.run(edat.deferred(insitu_program, dataclasses.asdict(cfg)),
                 ranks=2 * cfg.n_analytics, transport="socket")

    Returns the same metrics dict as :meth:`EdatAnalytics.run`, with
    bandwidth computed from the in-child ``run_seconds``."""
    warn_deprecated(
        "distributed_insitu is deprecated: use edat.run(edat.deferred("
        "insitu_program, ...), ranks=2*n, transport='socket')")
    return _distributed_insitu(cfg, timeout, **launch_kwargs)


# ---------------------------------------------------------------- baseline
class BespokeAnalytics:
    """MONC's original design, faithfully bad: coarse global lock, threads
    signalling through shared state, synchronous reduction, periodic
    memory-cleaning that blocks all handlers (paper §VI)."""

    def __init__(self, cfg: InsituCfg, threads_per_rank: int = 4):
        self.cfg = cfg
        self.nthreads = threads_per_rank
        self.results: List[tuple] = []

    def run(self) -> Dict[str, float]:
        cfg = self.cfg
        n = cfg.n_analytics
        glock = threading.Lock()                  # the coarse lock
        pending: Dict[tuple, list] = {}           # (fid, ts) -> partials
        queues = [[] for _ in range(n)]
        qcv = [threading.Condition() for _ in range(n)]
        stop = [False]
        processed = [0]

        t0 = time.monotonic()

        def producer(rank):
            rng = np.random.default_rng(rank + 1000)
            for i in range(cfg.items_per_producer):
                item = {"fid": i % cfg.n_fields, "ts": i // cfg.n_fields,
                        "data": rng.standard_normal(cfg.field_elems),
                        "t_fire": time.monotonic()}
                with qcv[rank]:
                    queues[rank].append(item)
                    qcv[rank].notify()

        def handler(rank, tid):
            clean_counter = 0
            while True:
                with qcv[rank]:
                    if not queues[rank]:
                        if stop[0]:
                            return
                        qcv[rank].wait(0.01)
                        continue
                    item = queues[rank].pop(0)
                partial = _analyse(item["data"])
                key = (item["fid"], item["ts"])
                with glock:                       # all state under one lock
                    lst = pending.setdefault(key, [])
                    lst.append((partial, item["t_fire"]))
                    if len(lst) == n:
                        total = np.sum([p for p, _ in lst], axis=0)
                        t_fire = min(t for _, t in lst)
                        self.results.append(
                            (total, time.monotonic() - t_fire))
                        del pending[key]
                    processed[0] += 1
                    clean_counter += 1
                    if clean_counter % 16 == 0:
                        # "memory cleaning" pass: holds the global lock
                        time.sleep(0.0005)
                        _ = {k: len(v) for k, v in pending.items()}

        producers = [threading.Thread(target=producer, args=(r,))
                     for r in range(n)]
        handlers = [threading.Thread(target=handler, args=(r, t))
                    for r in range(n) for t in range(self.nthreads)]
        for t in handlers + producers:
            t.start()
        for t in producers:
            t.join()
        total_items = n * cfg.items_per_producer
        while processed[0] < total_items:
            time.sleep(0.005)
        stop[0] = True
        for t in handlers:
            t.join()
        dt = time.monotonic() - t0
        lat = np.mean([r[1] for r in self.results]) if self.results else 0
        return {"raw_items": total_items, "results": len(self.results),
                "seconds": dt,
                "bandwidth_items_s": total_items / max(dt, 1e-9),
                "mean_latency_s": float(lat)}
