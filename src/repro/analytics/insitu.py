"""MONC-style in-situ data analytics (paper §VI, Figs 4-5).

Computational ranks saturate their analytics rank with raw ``field``
events; analytics ranks run the paper's pipeline as EDAT tasks:

  * a persistent *registration* task — a computational core registers, and
    per-core handler + deregistration tasks are submitted (paper Fig 4);
  * per-field persistent handler tasks that process raw data (arithmetic)
    and contribute to an inter-analytics reduction via events;
  * the reduction root is distributed round-robin over analytics ranks per
    (field, timestep) — the paper's explanation for bandwidth levelling
    off rather than degrading;
  * a persistent *writer federator* task on the root consumes the reduced
    value ("writes" it) and records the end-to-end latency.

The baseline (``BespokeAnalytics``) mimics the original MONC comms stack:
a single handler thread pool per rank with one coarse global lock
protecting shared state, synchronous reductions through a shared
structure, and explicit memory-cleaning passes that lock out progress —
the design the paper replaced.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro import edat


@dataclasses.dataclass
class InsituCfg:
    n_analytics: int = 2
    items_per_producer: int = 50
    field_elems: int = 512       # elements per raw data item
    n_fields: int = 2


def _analyse(x: np.ndarray) -> np.ndarray:
    """The per-item arithmetic of the paper's tests (ops + local reduce)."""
    return np.array([x.sum(), (x * x).sum(), x.min(), x.max()])


# ------------------------------------------------------------------- EDAT
class EdatAnalytics:
    """1:1 computational:analytics ranks (paper's benchmark setup):
    ranks [0, n) are analytics, ranks [n, 2n) are computational."""

    def __init__(self, cfg: InsituCfg, workers_per_rank: int = 4):
        self.cfg = cfg
        self.workers = workers_per_rank
        self.results: List[tuple] = []
        self._mu = threading.Lock()
        self.t0 = 0.0

    def run(self) -> Dict[str, float]:
        cfg = self.cfg
        n = cfg.n_analytics
        rt = edat.Runtime(2 * n, workers_per_rank=self.workers,
                          unconsumed="error")
        self.t0 = time.monotonic()
        rt.run(self._main, timeout=600)
        dt = time.monotonic() - self.t0
        raw = cfg.n_analytics * cfg.items_per_producer
        lat = np.mean([r[1] for r in self.results]) if self.results else 0
        return {"raw_items": raw, "results": len(self.results),
                "seconds": dt, "bandwidth_items_s": raw / max(dt, 1e-9),
                "mean_latency_s": float(lat)}

    def _main(self, ctx: edat.Context):
        cfg = self.cfg
        n = cfg.n_analytics
        if ctx.rank < n:
            self._analytics_main(ctx)
        else:
            self._producer_main(ctx)

    # -- analytics side -------------------------------------------------------
    def _analytics_main(self, ctx: edat.Context):
        cfg = self.cfg
        n = cfg.n_analytics

        def on_register(ctx2, events):
            core = events[0].data
            # per-core handler + deregistration tasks (paper Fig 4)
            ctx2.submit_persistent(on_field, deps=[(core, "field")],
                                   name=f"handler.{core}")
            ctx2.submit(on_deregister, deps=[(core, "dereg")])

        def on_field(ctx2, events):
            item = events[0].data
            partial = _analyse(item["data"])
            # events are tagged with field+timestep (paper: "data is sent
            # tagged with the timestep and field name"); the reduction root
            # is distributed round-robin over analytics ranks
            root = (item["fid"] + item["ts"]) % n
            eid = f"partial.{item['fid']}.{item['ts']}"
            ctx2.fire(root if root != ctx2.rank else edat.SELF, eid,
                      {"t_fire": item["t_fire"], "partial": partial})

        def on_partial(ctx2, events):
            # reduction across analytics ranks: ALL-sourced dependency on
            # this (field, timestep)'s tagged events
            datas = [e.data for e in events]
            total = np.sum([d["partial"] for d in datas], axis=0)
            t_fire = min(d["t_fire"] for d in datas)
            with self._mu:
                self.results.append((total, time.monotonic() - t_fire))

        def on_deregister(ctx2, events):
            ctx2.remove_task(f"handler.{events[0].data}")

        ctx.submit_persistent(on_register, deps=[(edat.ANY, "register")],
                              name="registration")
        # writer federator: one task per (field, timestep) this rank roots.
        # Dependencies name the n analytics ranks explicitly (EDAT_ALL would
        # also include the computational ranks).
        assert cfg.items_per_producer % cfg.n_fields == 0
        per_field = cfg.items_per_producer // cfg.n_fields
        for fid in range(cfg.n_fields):
            for ts in range(per_field):
                if (fid + ts) % n == ctx.rank:
                    ctx.submit(on_partial,
                               deps=[(r, f"partial.{fid}.{ts}")
                                     for r in range(n)])

    # -- computational side -----------------------------------------------------
    def _producer_main(self, ctx: edat.Context):
        cfg = self.cfg
        n = cfg.n_analytics
        target = ctx.rank - n          # my analytics core
        ctx.fire(target, "register", ctx.rank)
        rng = np.random.default_rng(ctx.rank)
        for i in range(cfg.items_per_producer):
            fid = i % cfg.n_fields
            data = rng.standard_normal(cfg.field_elems)
            ctx.fire(target, "field",
                     {"fid": fid, "ts": i // cfg.n_fields, "data": data,
                      "t_fire": time.monotonic()})
        ctx.fire(target, "dereg", ctx.rank)


# ---------------------------------------------------------------- baseline
class BespokeAnalytics:
    """MONC's original design, faithfully bad: coarse global lock, threads
    signalling through shared state, synchronous reduction, periodic
    memory-cleaning that blocks all handlers (paper §VI)."""

    def __init__(self, cfg: InsituCfg, threads_per_rank: int = 4):
        self.cfg = cfg
        self.nthreads = threads_per_rank
        self.results: List[tuple] = []

    def run(self) -> Dict[str, float]:
        cfg = self.cfg
        n = cfg.n_analytics
        glock = threading.Lock()                  # the coarse lock
        pending: Dict[tuple, list] = {}           # (fid, ts) -> partials
        queues = [[] for _ in range(n)]
        qcv = [threading.Condition() for _ in range(n)]
        stop = [False]
        processed = [0]

        t0 = time.monotonic()

        def producer(rank):
            rng = np.random.default_rng(rank + 1000)
            for i in range(cfg.items_per_producer):
                item = {"fid": i % cfg.n_fields, "ts": i // cfg.n_fields,
                        "data": rng.standard_normal(cfg.field_elems),
                        "t_fire": time.monotonic()}
                with qcv[rank]:
                    queues[rank].append(item)
                    qcv[rank].notify()

        def handler(rank, tid):
            clean_counter = 0
            while True:
                with qcv[rank]:
                    if not queues[rank]:
                        if stop[0]:
                            return
                        qcv[rank].wait(0.01)
                        continue
                    item = queues[rank].pop(0)
                partial = _analyse(item["data"])
                key = (item["fid"], item["ts"])
                with glock:                       # all state under one lock
                    lst = pending.setdefault(key, [])
                    lst.append((partial, item["t_fire"]))
                    if len(lst) == n:
                        total = np.sum([p for p, _ in lst], axis=0)
                        t_fire = min(t for _, t in lst)
                        self.results.append(
                            (total, time.monotonic() - t_fire))
                        del pending[key]
                    processed[0] += 1
                    clean_counter += 1
                    if clean_counter % 16 == 0:
                        # "memory cleaning" pass: holds the global lock
                        time.sleep(0.0005)
                        _ = {k: len(v) for k, v in pending.items()}

        producers = [threading.Thread(target=producer, args=(r,))
                     for r in range(n)]
        handlers = [threading.Thread(target=handler, args=(r, t))
                    for r in range(n) for t in range(self.nthreads)]
        for t in handlers + producers:
            t.start()
        for t in producers:
            t.join()
        total_items = n * cfg.items_per_producer
        while processed[0] < total_items:
            time.sleep(0.005)
        stop[0] = True
        for t in handlers:
            t.join()
        dt = time.monotonic() - t0
        lat = np.mean([r[1] for r in self.results]) if self.results else 0
        return {"raw_items": total_items, "results": len(self.results),
                "seconds": dt,
                "bandwidth_items_s": total_items / max(dt, 1e-9),
                "mean_latency_s": float(lat)}
