"""Checkpointing: atomic, sharded-by-leaf, restart- and reshard-safe.

Layout:  <dir>/step_<N>/
           meta.msgpack   {step, data_cursor, tree structure, leaf index}
           arrays.npz     flat {path: array} (single host container)
         <dir>/LATEST     atomic pointer file

Arrays are written via a temp directory + rename so a crash mid-save never
corrupts the latest checkpoint — the failure-injection tests rely on this.
Restore returns plain numpy leaves; the caller device_puts them with the
current mesh's shardings (so restoring onto a different topology works).
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np

import jax


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}"))
        if len(tree) == 0:
            out[prefix + "/#empty"] = np.zeros((0,), np.int32)
    elif tree is None:
        out[prefix + "/#none"] = np.zeros((0,), np.int32)
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray], proto):
    """Rebuild using a prototype tree for structure."""
    def rec(proto, prefix):
        if isinstance(proto, dict):
            return {k: rec(v, f"{prefix}/{k}") for k, v in proto.items()}
        if isinstance(proto, (list, tuple)):
            vals = [rec(v, f"{prefix}/#{i}") for i, v in enumerate(proto)]
            return type(proto)(vals)
        if proto is None:
            return None
        return flat[prefix]
    return rec(proto, "")


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomic save; returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(jax.tree.map(np.asarray, tree))
    flat = {k.lstrip("/"): v for k, v in flat.items()}  # zip-safe names
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {"step": step, "extra": extra or {}}
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb(meta))
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr = os.path.join(ckpt_dir, "LATEST")
    with tempfile.NamedTemporaryFile("w", dir=ckpt_dir, delete=False) as f:
        f.write(f"step_{step:08d}")
        tmpname = f.name
    os.replace(tmpname, ptr)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(path):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, proto: Any,
            step: Optional[int] = None) -> Tuple[int, Any, Dict]:
    """Restore (step, tree, extra).  ``proto`` provides the structure (e.g.
    a freshly-initialised state); leaves are numpy arrays."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {f"/{k}" if not k.startswith("/") else k: z[k] for k in z.files}
    tree = _unflatten(flat, proto)
    return meta["step"], tree, meta.get("extra", {})
