from .kronecker import kronecker_edges, build_csr, PartitionedCSR
from .bfs import (EdatBFS, ReferenceBFS, bfs_program, default_root,
                  distributed_bfs, validate_bfs_tree)
