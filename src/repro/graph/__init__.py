from .kronecker import kronecker_edges, build_csr, PartitionedCSR
from .bfs import EdatBFS, ReferenceBFS, validate_bfs_tree
