"""Graph500 Kronecker (R-MAT) generator + partitioned CSR.

Vectorised numpy implementation of the Graph500 reference generator
(A=0.57, B=0.19, C=0.19, D=0.05), scale s -> 2^s vertices, edgefactor 16.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

A, B, C = 0.57, 0.19, 0.19


def kronecker_edges(scale: int, edgefactor: int = 16,
                    seed: int = 20) -> np.ndarray:
    """Returns (2, M) int64 edge list (undirected; duplicates/selfloops kept
    as in the reference, filtered during CSR build)."""
    n = 1 << scale
    m = n * edgefactor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, c_norm, a_norm = A + B, C / (1 - A - B), A / (A + B)
    for bit in range(scale):
        ii = rng.random(m) > ab
        jj = rng.random(m) > np.where(ii, c_norm, a_norm)
        src |= (ii.astype(np.int64) << bit)
        dst |= (jj.astype(np.int64) << bit)
    # permute vertex labels (deterministic) to avoid locality artifacts
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    return np.stack([src, dst])


@dataclasses.dataclass
class PartitionedCSR:
    """Block 1-D vertex partition across ranks; per-rank CSR of OUT edges."""
    n_vertices: int
    n_ranks: int
    indptr: List[np.ndarray]     # per rank, local CSR
    indices: List[np.ndarray]
    n_edges: int

    def owner(self, v):
        return np.minimum(v // self.block, self.n_ranks - 1)

    @property
    def block(self):
        return -(-self.n_vertices // self.n_ranks)

    def local_range(self, rank) -> Tuple[int, int]:
        lo = rank * self.block
        return lo, min(lo + self.block, self.n_vertices)


def build_csr(edges: np.ndarray, n_vertices: int,
              n_ranks: int) -> PartitionedCSR:
    src = np.concatenate([edges[0], edges[1]])   # undirected: both dirs
    dst = np.concatenate([edges[1], edges[0]])
    keep = src != dst
    src, dst = src[keep], dst[keep]
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    dedup = np.ones(len(src), bool)
    dedup[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
    src, dst = src[dedup], dst[dedup]

    block = -(-n_vertices // n_ranks)
    indptr, indices = [], []
    for r in range(n_ranks):
        lo, hi = r * block, min((r + 1) * block, n_vertices)
        sel = (src >= lo) & (src < hi)
        s, d = src[sel] - lo, dst[sel]
        counts = np.bincount(s, minlength=hi - lo)
        indptr.append(np.concatenate([[0], np.cumsum(counts)]).astype(np.int64))
        indices.append(d.astype(np.int64))
    return PartitionedCSR(n_vertices, n_ranks, indptr, indices,
                          n_edges=len(src) // 2)
