"""Graph500 BFS kernel: EDAT event-driven vs bulk-synchronous reference.

EDAT version (paper §V, Fig 2): one *persistent* visit task per rank with
an EDAT_ALL dependency on ``visit`` events.  Each level, every rank fires
exactly one batched visit event to every rank (possibly empty), so the
ALL-dependency frames pair levels deterministically via the per-(src,dst)
FIFO guarantee — the level barrier is *implicit in the event matching*,
no global synchronisation call exists.  Per-rank frontier expansion is
vectorised numpy (the TPU-native adaptation: batch the per-vertex handler).

:class:`EdatBFS` is a v2 ``edat.Program``: it declares its typed event
channels, attaches to any SPMD context via :meth:`EdatBFS.start`, and
returns its gathered output through :meth:`EdatBFS.result` — so the same
code runs threads-as-ranks (:meth:`EdatBFS.run`, the in-proc
convenience) or across OS processes::

    res = edat.run(edat.deferred(bfs_program, n_ranks, scale=12, root=5),
                   ranks=n_ranks, transport="socket")

(:func:`bfs_program` rebuilds the Kronecker graph deterministically in
each spawned process — no broadcast needed.)  On convergence every rank
fires its parent fragment to rank 0 (``ref=True`` — ownership handover,
so the coalescing socket transport ships the numpy frontier zero-copy);
a transitory gather task on rank 0 assembles the full parent array.
Level batches are also fired ``ref=True`` for the same reason.

Reference version: classic BSP level-synchronous BFS — compute, exchange,
explicit global barrier per level (threading.Barrier standing in for
MPI_Alltoallv + barrier).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import edat
from repro.core.deprecation import warn_deprecated
from .kronecker import PartitionedCSR, build_csr, kronecker_edges

#: typed event channels of the BFS program (v2 API)
VISIT = edat.Channel("visit", payload=dict)
BFS_PARENTS = edat.Channel("bfs_parents", payload=dict)


# --------------------------------------------------------------- EDAT BFS
class EdatBFS:
    """Event-driven BFS over a partitioned CSR — an ``edat.Program``.

    ``run(root)`` owns an in-proc Session (threads-as-ranks); for a
    distributed run hand the program (usually via
    ``edat.deferred(bfs_program, ...)``) to ``edat.run``/``Session`` —
    each process hosts ``transport.local_ranks`` and the event flow is
    identical.  The assembled parent array lands in ``self.result_parent``
    on the process hosting rank 0 (returned by :meth:`result`, and passed
    to ``on_result`` if set)."""

    channels = (VISIT, BFS_PARENTS)

    def __init__(self, csr: PartitionedCSR, workers_per_rank: int = 1,
                 progress: str = "thread", root: Optional[int] = None):
        self.csr = csr
        self.workers = workers_per_rank
        self.progress = progress
        #: default traversal root for start(ctx) (the Program protocol)
        self.root = root
        self.parent: List[Optional[np.ndarray]] = [None] * csr.n_ranks
        self.traversed = [0] * csr.n_ranks
        self.levels = [0] * csr.n_ranks
        #: full parent array, assembled by rank 0's gather task
        self.result_parent: Optional[np.ndarray] = None
        #: called (on rank 0's process) as on_result(parent, traversed)
        self.on_result: Optional[Callable[[np.ndarray, List[int]], None]] \
            = None
        #: test hook: (rank, level, seconds, ready_path) — that rank's
        #: visit task touches ready_path then sleeps at that level,
        #: holding the traversal mid-flight (SIGKILL injection point)
        self.stall: Optional[Tuple[int, int, float, Optional[str]]] = None

    def run(self, root: int, timeout: float = 600.0) -> np.ndarray:
        """In-proc convenience: all ranks as threads in one Session."""
        self.root = root
        with edat.Session(self.csr.n_ranks,
                          workers_per_rank=self.workers,
                          progress=self.progress, unconsumed="error",
                          timeout=timeout) as s:
            self._rt = s.runtime
            s.run(self)
        return self.result_parent

    def result(self) -> Dict[str, object]:
        """Gathered output (rank 0's process): the assembled parent array
        plus per-rank traversed-edge counts."""
        return {"parent": self.result_parent,
                "traversed": list(self.traversed)}

    def start(self, ctx: edat.Context, root: Optional[int] = None) -> None:
        """Attach the BFS to one rank of any (in-proc or distributed)
        runtime: submit the visit/gather/fail-stop tasks and fire the
        level-0 seed batches."""
        csr = self.csr
        root = self.root if root is None else root
        if root is None:
            raise ValueError("no BFS root: pass start(ctx, root) or set "
                             "EdatBFS(..., root=)")
        lo, hi = csr.local_range(ctx.rank)
        self.parent[ctx.rank] = np.full(hi - lo, -1, np.int64)

        ctx.submit_persistent(self._visit_task,
                              deps=[(edat.ALL, VISIT)], name="visit")
        # fail-stop: without this, survivors of a mid-traversal rank loss
        # would idle forever inside the ALL-dependency (the dead rank's
        # level batch never arrives); raising turns RANK_FAILED into a
        # clean abort that the runtime propagates to every process
        ctx.submit_persistent(self._failstop,
                              deps=[(edat.ANY, edat.RANK_FAILED)],
                              name="bfs-failstop")
        if ctx.rank == 0:
            ctx.submit(self._gather_task,
                       deps=[(r, BFS_PARENTS)
                             for r in range(ctx.n_ranks)], name="gather")
        # level 0: everyone fires its (mostly empty) seed batch
        if csr.owner(np.int64(root)) == ctx.rank:
            seed = np.array([[root, root]], np.int64)
        else:
            seed = np.empty((0, 2), np.int64)
        for r in range(ctx.n_ranks):
            ctx.fire(r if r != ctx.rank else edat.SELF, "visit",
                     {"edges": seed if r == csr.owner(np.int64(root))
                      else np.empty((0, 2), np.int64), "active": 1},
                     ref=True)

    def _failstop(self, ctx: edat.Context, events):
        raise RuntimeError(
            f"BFS aborted on rank {ctx.rank}: rank {events[0].data} "
            f"failed mid-traversal")

    def _gather_task(self, ctx: edat.Context, events):
        """Rank 0, once: assemble the global parent array from every
        rank's converged fragment."""
        out = np.full(self.csr.n_vertices, -1, np.int64)
        for ev in events:
            d = ev.data
            lo, hi = self.csr.local_range(d["rank"])
            out[lo:hi] = d["parent"]
            self.traversed[d["rank"]] = int(d["traversed"])
        self.result_parent = out
        if self.on_result is not None:
            self.on_result(out, list(self.traversed))

    def _visit_task(self, ctx: edat.Context, events):
        """One execution per level: consume all ranks' batches, expand."""
        csr = self.csr
        lo, hi = csr.local_range(ctx.rank)
        parent = self.parent[ctx.rank]
        level = self.levels[ctx.rank]
        self.levels[ctx.rank] = level + 1
        if self.stall is not None and self.stall[0] == ctx.rank \
                and self.stall[1] == level:
            if self.stall[3]:
                open(self.stall[3], "w").close()
            time.sleep(self.stall[2])

        total_active = sum(ev.data["active"] for ev in events)
        if total_active == 0:
            # converged: nobody fired real work; stop the cascade and ship
            # this rank's fragment to the gatherer
            ctx.fire(0 if ctx.rank != 0 else edat.SELF, "bfs_parents",
                     {"rank": ctx.rank, "parent": parent,
                      "traversed": self.traversed[ctx.rank]}, ref=True)
            return

        batches = [ev.data["edges"] for ev in events
                   if len(ev.data["edges"])]
        if batches:
            inc = np.concatenate(batches)       # (k, 2): [dst, parent]
            v = inc[:, 0] - lo
            first = np.unique(v, return_index=True)[1]
            v, p = v[first], inc[first, 1]
            fresh = parent[v] == -1
            v, p = v[fresh], p[fresh]
            parent[v] = p
            frontier = v + lo
        else:
            frontier = np.empty((0,), np.int64)

        # expand local frontier via CSR (vectorised)
        indptr, indices = csr.indptr[ctx.rank], csr.indices[ctx.rank]
        vloc = frontier - lo
        starts, ends = indptr[vloc], indptr[vloc + 1]
        counts = ends - starts
        self.traversed[ctx.rank] += int(counts.sum())
        if len(vloc):
            offs = np.repeat(starts, counts) + (
                np.arange(counts.sum()) -
                np.repeat(np.cumsum(counts) - counts, counts))
            nbrs = indices[offs]
            pars = np.repeat(frontier, counts)
            owners = csr.owner(nbrs)
            order = np.argsort(owners, kind="stable")
            nbrs, pars, owners = nbrs[order], pars[order], owners[order]
            cuts = np.searchsorted(owners, np.arange(ctx.n_ranks + 1))
        else:
            nbrs = pars = np.empty((0,), np.int64)
            cuts = np.zeros(ctx.n_ranks + 1, np.int64)

        active = 1 if len(frontier) else 0
        ctx.fire_batch(
            [(r if r != ctx.rank else edat.SELF, "visit",
              {"edges": np.stack([nbrs[cuts[r]:cuts[r + 1]],
                                  pars[cuts[r]:cuts[r + 1]]], axis=1),
               "active": active})
             for r in range(ctx.n_ranks)], ref=True)


# ------------------------------------------------- distributed (processes)
def bfs_program(n_ranks: int, scale: int, edgefactor: int = 16,
                seed: int = 20, root: int = 0, *, workers_per_rank: int = 1,
                stall=None, ready_path: Optional[str] = None) -> EdatBFS:
    """Program factory for ``edat.run``/``Session``: regenerates the
    Kronecker graph deterministically (no broadcast needed — each
    spawned process builds its own copy when wrapped in
    ``edat.deferred``), partitions it over ``n_ranks``, and returns the
    :class:`EdatBFS` program rooted at ``root``."""
    edges = kronecker_edges(scale, edgefactor, seed)
    csr = build_csr(edges, 1 << scale, n_ranks)
    bfs = EdatBFS(csr, workers_per_rank=workers_per_rank, root=root)
    if stall is not None:
        bfs.stall = (stall[0], stall[1], stall[2], ready_path)
    return bfs


def default_root(scale: int, edgefactor: int = 16, seed: int = 20) -> int:
    """First vertex with nonzero degree (the Graph500 root rule)."""
    edges = kronecker_edges(scale, edgefactor, seed)
    n = 1 << scale
    deg = np.bincount(np.concatenate([edges[0], edges[1]]), minlength=n)
    return int(np.where(deg > 0)[0][0])


def _distributed_bfs(n_ranks: int, scale: int, edgefactor: int = 16,
                     seed: int = 20, root: Optional[int] = None,
                     timeout: float = 120.0, **launch_kwargs):
    """Session-backed distributed run returning ``(parent, info)`` in the
    v1 shape.  Shared by the deprecation shim and the benchmarks."""
    if root is None:
        root = default_root(scale, edgefactor, seed)
    workers = launch_kwargs.pop("workers_per_rank", 1)
    # v1 launcher kwargs that moved in v2: keep the old contract working
    procs = launch_kwargs.pop("n_procs", None)
    check = launch_kwargs.pop("check", True)
    join_timeout = launch_kwargs.pop("join_timeout", None)
    with edat.Session(n_ranks, procs=procs, transport="socket",
                      timeout=timeout, workers_per_rank=workers,
                      **launch_kwargs) as s:
        s.start(edat.deferred(bfs_program, n_ranks, scale,
                              edgefactor=edgefactor, seed=seed, root=root,
                              workers_per_rank=workers))
        s.wait(join_timeout, check=check)
        res = s.gather()
        stats = s.stats
    parent = res["parent"]
    traversed = int(np.sum(res["traversed"]))
    info = dict(stats)
    dt = max(float(stats.get("run_seconds", 0.0)), 1e-9)
    info.update(root=root, traversed=traversed, teps=traversed / dt,
                events_per_s=stats.get("events_sent", 0) / dt)
    return parent, info


def distributed_bfs(n_ranks: int, scale: int, edgefactor: int = 16,
                    seed: int = 20, root: Optional[int] = None,
                    timeout: float = 120.0, **launch_kwargs):
    """Deprecated v1 helper — use the v2 Session API::

        res = edat.run(edat.deferred(bfs_program, n_ranks, scale=scale,
                                     root=root),
                       ranks=n_ranks, transport="socket")

    Returns ``(parent, info)`` exactly as before: the assembled parent
    array plus run stats (``run_seconds``, ``teps``, ``events_per_s`` —
    all-rank user events/s incl. SELF loopback fires — ``traversed``,
    ``root``)."""
    warn_deprecated(
        "distributed_bfs is deprecated: use edat.run(edat.deferred("
        "bfs_program, ...), ranks=..., transport='socket')")
    return _distributed_bfs(n_ranks, scale, edgefactor, seed, root,
                            timeout, **launch_kwargs)


# ---------------------------------------------------------- BSP reference
class ReferenceBFS:
    """Bulk-synchronous level-stepped BFS (the paper's reference analog)."""

    def __init__(self, csr: PartitionedCSR):
        self.csr = csr
        self.traversed = [0] * csr.n_ranks

    def run(self, root: int) -> np.ndarray:
        csr = self.csr
        n = csr.n_ranks
        barrier = threading.Barrier(n)
        parent = [np.full(csr.local_range(r)[1] - csr.local_range(r)[0],
                          -1, np.int64) for r in range(n)]
        # exchange buffers: inbox[dst][src] = batch
        inbox = [[None] * n for _ in range(n)]
        done = [False]

        def worker(rank):
            lo, hi = csr.local_range(rank)
            if csr.owner(np.int64(root)) == rank:
                my = np.array([[root, root]], np.int64)
            else:
                my = np.empty((0, 2), np.int64)
            for r in range(n):
                inbox[r][rank] = my if csr.owner(np.int64(root)) == r \
                    else np.empty((0, 2), np.int64)
            barrier.wait()
            while not done[0]:
                inc = np.concatenate([b for b in inbox[rank]])
                v = inc[:, 0] - lo if len(inc) else np.empty((0,), np.int64)
                if len(v):
                    first = np.unique(v, return_index=True)[1]
                    v, p = v[first], inc[first, 1]
                    fresh = parent[rank][v] == -1
                    v, p = v[fresh], p[fresh]
                    parent[rank][v] = p
                    frontier = v + lo
                else:
                    frontier = np.empty((0,), np.int64)
                indptr, indices = csr.indptr[rank], csr.indices[rank]
                vloc = frontier - lo
                starts, ends = indptr[vloc], indptr[vloc + 1]
                counts = ends - starts
                self.traversed[rank] += int(counts.sum())
                if len(vloc):
                    offs = np.repeat(starts, counts) + (
                        np.arange(counts.sum()) -
                        np.repeat(np.cumsum(counts) - counts, counts))
                    nbrs = indices[offs]
                    pars = np.repeat(frontier, counts)
                    owners = csr.owner(nbrs)
                    order = np.argsort(owners, kind="stable")
                    nbrs, pars, owners = nbrs[order], pars[order], owners[order]
                    cuts = np.searchsorted(owners, np.arange(n + 1))
                else:
                    nbrs = pars = np.empty((0,), np.int64)
                    cuts = np.zeros(n + 1, np.int64)
                out = [np.stack([nbrs[cuts[r]:cuts[r + 1]],
                                 pars[cuts[r]:cuts[r + 1]]], axis=1)
                       for r in range(n)]
                got_any = len(frontier) > 0
                barrier.wait()               # everyone finished computing
                for r in range(n):
                    inbox[r][rank] = out[r]
                self._active[rank] = got_any
                barrier.wait()               # exchange complete
                if rank == 0:
                    done[0] = not any(self._active)
                barrier.wait()               # "broadcast" of done flag

        self._active = [True] * n
        threads = [threading.Thread(target=worker, args=(r,)) for r in
                   range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        out = np.full(csr.n_vertices, -1, np.int64)
        for r in range(n):
            lo, hi = csr.local_range(r)
            out[lo:hi] = parent[r]
        return out


def validate_bfs_tree(edges: np.ndarray, parent: np.ndarray,
                      root: int) -> bool:
    """Graph500-style validation: root is its own parent, every reached
    vertex's parent edge exists, tree levels are consistent (parent level =
    child level - 1 via BFS from root over the tree)."""
    n = len(parent)
    if parent[root] != root:
        return False
    eset = set()
    for s, d in edges.T:
        if s != d:
            eset.add((min(int(s), int(d)), max(int(s), int(d))))
    reached = np.where(parent >= 0)[0]
    for v in reached:
        p = int(parent[v])
        if v != root and (min(v, p), max(v, p)) not in eset:
            return False
    # level consistency via tree walk
    level = np.full(n, -1, np.int64)
    level[root] = 0
    # iterate: child level = parent level + 1 (tree is acyclic by parent)
    for _ in range(n):
        upd = (level == -1) & (parent >= 0) & (level[parent] >= 0)
        if not upd.any():
            break
        level[np.where(upd)[0]] = level[parent[np.where(upd)[0]]] + 1
    return bool((level[reached] >= 0).all())
