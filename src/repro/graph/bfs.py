"""Graph500 BFS kernel: EDAT event-driven vs bulk-synchronous reference.

EDAT version (paper §V, Fig 2): one *persistent* visit task per rank with
an EDAT_ALL dependency on ``visit`` events.  Each level, every rank fires
exactly one batched visit event to every rank (possibly empty), so the
ALL-dependency frames pair levels deterministically via the per-(src,dst)
FIFO guarantee — the level barrier is *implicit in the event matching*,
no global synchronisation call exists.  Per-rank frontier expansion is
vectorised numpy (the TPU-native adaptation: batch the per-vertex handler).

Reference version: classic BSP level-synchronous BFS — compute, exchange,
explicit global barrier per level (threading.Barrier standing in for
MPI_Alltoallv + barrier).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro import edat
from .kronecker import PartitionedCSR


# --------------------------------------------------------------- EDAT BFS
class EdatBFS:
    def __init__(self, csr: PartitionedCSR, workers_per_rank: int = 1,
                 progress: str = "thread"):
        self.csr = csr
        self.workers = workers_per_rank
        self.progress = progress
        self.parent: List[Optional[np.ndarray]] = [None] * csr.n_ranks
        self.traversed = [0] * csr.n_ranks

    def run(self, root: int) -> np.ndarray:
        csr = self.csr
        n_ranks = csr.n_ranks
        rt = edat.Runtime(n_ranks, workers_per_rank=self.workers,
                          progress=self.progress, unconsumed="error")
        self._rt = rt
        rt.run(lambda ctx: self._main(ctx, root), timeout=600)
        out = np.full(csr.n_vertices, -1, np.int64)
        for r in range(n_ranks):
            lo, hi = csr.local_range(r)
            out[lo:hi] = self.parent[r]
        return out

    def _main(self, ctx: edat.Context, root: int):
        csr = self.csr
        lo, hi = csr.local_range(ctx.rank)
        self.parent[ctx.rank] = np.full(hi - lo, -1, np.int64)

        ctx.submit_persistent(self._visit_task,
                              deps=[(edat.ALL, "visit")], name="visit")
        # level 0: everyone fires its (mostly empty) seed batch
        if csr.owner(np.int64(root)) == ctx.rank:
            seed = np.array([[root, root]], np.int64)
        else:
            seed = np.empty((0, 2), np.int64)
        for r in range(ctx.n_ranks):
            ctx.fire(r if r != ctx.rank else edat.SELF, "visit",
                     {"edges": seed if r == csr.owner(np.int64(root))
                      else np.empty((0, 2), np.int64), "active": 1})

    def _visit_task(self, ctx: edat.Context, events):
        """One execution per level: consume all ranks' batches, expand."""
        csr = self.csr
        lo, hi = csr.local_range(ctx.rank)
        parent = self.parent[ctx.rank]

        total_active = sum(ev.data["active"] for ev in events)
        if total_active == 0:
            return  # converged: nobody fired real work; stop the cascade

        batches = [ev.data["edges"] for ev in events
                   if len(ev.data["edges"])]
        if batches:
            inc = np.concatenate(batches)       # (k, 2): [dst, parent]
            v = inc[:, 0] - lo
            first = np.unique(v, return_index=True)[1]
            v, p = v[first], inc[first, 1]
            fresh = parent[v] == -1
            v, p = v[fresh], p[fresh]
            parent[v] = p
            frontier = v + lo
        else:
            frontier = np.empty((0,), np.int64)

        # expand local frontier via CSR (vectorised)
        indptr, indices = csr.indptr[ctx.rank], csr.indices[ctx.rank]
        vloc = frontier - lo
        starts, ends = indptr[vloc], indptr[vloc + 1]
        counts = ends - starts
        self.traversed[ctx.rank] += int(counts.sum())
        if len(vloc):
            offs = np.repeat(starts, counts) + (
                np.arange(counts.sum()) -
                np.repeat(np.cumsum(counts) - counts, counts))
            nbrs = indices[offs]
            pars = np.repeat(frontier, counts)
            owners = csr.owner(nbrs)
            order = np.argsort(owners, kind="stable")
            nbrs, pars, owners = nbrs[order], pars[order], owners[order]
            cuts = np.searchsorted(owners, np.arange(ctx.n_ranks + 1))
        else:
            nbrs = pars = np.empty((0,), np.int64)
            cuts = np.zeros(ctx.n_ranks + 1, np.int64)

        active = 1 if len(frontier) else 0
        for r in range(ctx.n_ranks):
            sl = slice(cuts[r], cuts[r + 1])
            batch = np.stack([nbrs[sl], pars[sl]], axis=1)
            ctx.fire(r if r != ctx.rank else edat.SELF, "visit",
                     {"edges": batch, "active": active})


# ---------------------------------------------------------- BSP reference
class ReferenceBFS:
    """Bulk-synchronous level-stepped BFS (the paper's reference analog)."""

    def __init__(self, csr: PartitionedCSR):
        self.csr = csr
        self.traversed = [0] * csr.n_ranks

    def run(self, root: int) -> np.ndarray:
        csr = self.csr
        n = csr.n_ranks
        barrier = threading.Barrier(n)
        parent = [np.full(csr.local_range(r)[1] - csr.local_range(r)[0],
                          -1, np.int64) for r in range(n)]
        # exchange buffers: inbox[dst][src] = batch
        inbox = [[None] * n for _ in range(n)]
        done = [False]

        def worker(rank):
            lo, hi = csr.local_range(rank)
            if csr.owner(np.int64(root)) == rank:
                my = np.array([[root, root]], np.int64)
            else:
                my = np.empty((0, 2), np.int64)
            for r in range(n):
                inbox[r][rank] = my if csr.owner(np.int64(root)) == r \
                    else np.empty((0, 2), np.int64)
            barrier.wait()
            while not done[0]:
                inc = np.concatenate([b for b in inbox[rank]])
                v = inc[:, 0] - lo if len(inc) else np.empty((0,), np.int64)
                if len(v):
                    first = np.unique(v, return_index=True)[1]
                    v, p = v[first], inc[first, 1]
                    fresh = parent[rank][v] == -1
                    v, p = v[fresh], p[fresh]
                    parent[rank][v] = p
                    frontier = v + lo
                else:
                    frontier = np.empty((0,), np.int64)
                indptr, indices = csr.indptr[rank], csr.indices[rank]
                vloc = frontier - lo
                starts, ends = indptr[vloc], indptr[vloc + 1]
                counts = ends - starts
                self.traversed[rank] += int(counts.sum())
                if len(vloc):
                    offs = np.repeat(starts, counts) + (
                        np.arange(counts.sum()) -
                        np.repeat(np.cumsum(counts) - counts, counts))
                    nbrs = indices[offs]
                    pars = np.repeat(frontier, counts)
                    owners = csr.owner(nbrs)
                    order = np.argsort(owners, kind="stable")
                    nbrs, pars, owners = nbrs[order], pars[order], owners[order]
                    cuts = np.searchsorted(owners, np.arange(n + 1))
                else:
                    nbrs = pars = np.empty((0,), np.int64)
                    cuts = np.zeros(n + 1, np.int64)
                out = [np.stack([nbrs[cuts[r]:cuts[r + 1]],
                                 pars[cuts[r]:cuts[r + 1]]], axis=1)
                       for r in range(n)]
                got_any = len(frontier) > 0
                barrier.wait()               # everyone finished computing
                for r in range(n):
                    inbox[r][rank] = out[r]
                self._active[rank] = got_any
                barrier.wait()               # exchange complete
                if rank == 0:
                    done[0] = not any(self._active)
                barrier.wait()               # "broadcast" of done flag

        self._active = [True] * n
        threads = [threading.Thread(target=worker, args=(r,)) for r in
                   range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        out = np.full(csr.n_vertices, -1, np.int64)
        for r in range(n):
            lo, hi = csr.local_range(r)
            out[lo:hi] = parent[r]
        return out


def validate_bfs_tree(edges: np.ndarray, parent: np.ndarray,
                      root: int) -> bool:
    """Graph500-style validation: root is its own parent, every reached
    vertex's parent edge exists, tree levels are consistent (parent level =
    child level - 1 via BFS from root over the tree)."""
    n = len(parent)
    if parent[root] != root:
        return False
    eset = set()
    for s, d in edges.T:
        if s != d:
            eset.add((min(int(s), int(d)), max(int(s), int(d))))
    reached = np.where(parent >= 0)[0]
    for v in reached:
        p = int(parent[v])
        if v != root and (min(v, p), max(v, p)) not in eset:
            return False
    # level consistency via tree walk
    level = np.full(n, -1, np.int64)
    level[root] = 0
    # iterate: child level = parent level + 1 (tree is acyclic by parent)
    for _ in range(n):
        upd = (level == -1) & (parent >= 0) & (level[parent] >= 0)
        if not upd.any():
            break
        level[np.where(upd)[0]] = level[parent[np.where(upd)[0]]] + 1
    return bool((level[reached] >= 0).all())
