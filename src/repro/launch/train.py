"""Production training launcher.

On a real TPU pod this process runs once per host: ``jax.distributed`` is
initialised from the pod runtime environment, the production mesh spans
all chips, and the EDAT runtime (one rank per host, pluggable transport)
coordinates data prefetch / checkpointing / analytics / failure recovery
around the pjit-sharded train_step.  In this CPU container it runs the
same code path on whatever devices exist (use --dry-run to lower against
the full production mesh instead of executing).

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
      --shape train_4k --dry-run               # lower+compile, no exec
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 4 \
      --reduced                                # actually step on this host
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower + compile against the production mesh")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-executable)")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--distributed-init", action="store_true",
                    help="call jax.distributed.initialize() (real pods)")
    args = ap.parse_args(argv)

    if args.dry_run:
        # delegated to the dry-run driver (sets XLA device-count flags
        # before importing jax — must run in a fresh interpreter)
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        return subprocess.call(cmd)

    if args.distributed_init:
        import jax
        jax.distributed.initialize()

    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduce_cfg
    from repro.data import DataCfg, SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import OptCfg, make_optimizer
    from repro.train import make_train_step

    spec = ARCHS[args.arch]
    cfg = reduce_cfg(spec.cfg) if args.reduced else spec.cfg
    if cfg.frontend != "none" or cfg.encdec:
        cfg = cfg.replace(frontend="none", n_frontend_tokens=0,
                          encdec=False)
    model = build_model(cfg)
    opt = make_optimizer(OptCfg())
    step_fn = jax.jit(make_train_step(model, opt))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(DataCfg(vocab=cfg.vocab, seq=args.seq,
                               global_batch=args.batch))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"{args.arch}: {n/1e6:.1f}M params on "
          f"{len(jax.devices())} device(s)")
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        t0 = time.monotonic()
        params, opt_state, m = step_fn(params, opt_state, b,
                                       jnp.asarray(i))
        dt = time.monotonic() - t0
        print(f"  step {i}: loss={float(m['loss']):.4f} "
              f"({dt:.2f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
