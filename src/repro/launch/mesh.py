"""Production meshes.  A FUNCTION (not module constant) so importing never
touches jax device state — required by the dry-run contract."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
