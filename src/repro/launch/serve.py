"""Production serving launcher: prefill + decode steps on the host mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduce_cfg
    from repro.models import build_model
    from repro.train import make_serve_step

    spec = ARCHS[args.arch]
    cfg = reduce_cfg(spec.cfg) if args.reduced else spec.cfg
    if cfg.frontend != "none" or cfg.encdec:
        cfg = cfg.replace(frontend="none", n_frontend_tokens=0,
                          encdec=False)
    total = args.prompt_len + args.max_new
    cfg = cfg.replace(max_target_length=max(cfg.max_target_length, total))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = args.batch
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (B, args.prompt_len), 0, cfg.vocab)
    caches = model.init_cache(B, total)
    t0 = time.monotonic()
    logits, caches = jax.jit(model.prefill)(params, tokens, caches)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.monotonic() - t0
    serve_step = jax.jit(make_serve_step(model))
    pos = jnp.full((B, 1), args.prompt_len, jnp.int32)
    out = [nxt]
    t0 = time.monotonic()
    for i in range(args.max_new - 1):
        nxt, caches = serve_step(params, caches, nxt, pos)
        pos = pos + 1
        out.append(nxt)
    dt = time.monotonic() - t0
    toks = B * (args.max_new - 1)
    print(f"{args.arch}: prefill({B}x{args.prompt_len}) {t_prefill:.2f}s; "
          f"decode {toks} tokens in {dt:.2f}s ({toks/max(dt,1e-9):.1f} "
          f"tok/s incl. compile)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
