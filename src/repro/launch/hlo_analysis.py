"""HLO cost roll-up with while-loop trip-count multipliers.

XLA's built-in ``HloCostAnalysis`` (exposed as ``compiled.cost_analysis()``)
visits every computation ONCE — a scan-over-layers body, which is where
~all FLOPs live, is counted a single time.  This module parses the
post-optimization, post-SPMD HLO text and rolls up:

  * dot FLOPs        2 * prod(output dims) * prod(contracting dims)
  * elementwise FLOPs ~ prod(output dims) per arithmetic op
  * memory bytes     operand + result bytes of top-level (post-fusion)
                     instructions — fusion bodies are compute-only
  * collective bytes per collective kind (raw result bytes and ring-wire
                     estimates)

multiplied through the call graph: while bodies x trip count (parsed from
the loop-condition constant), fusions/calls x1, conditionals x max-branch.
Shapes in the partitioned module are per-device shards, so every number is
per-device; multiply by device count for machine totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?|\w+\[\])\s*"
    r"([\w\-]+)\(")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w\.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_GRP_RE = re.compile(r"replica_groups=\[(\d+)(?:,(\d+))?\]")
_GRP_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "cosine", "sine", "select", "compare", "and", "or", "xor",
    "convert", "floor", "ceil", "round-nearest-afz", "clamp",
    "exponential-minus-one", "log-plus-one", "atan2", "sign", "erf",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _TYPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Comp:
    name: str
    instrs: List[Instr]
    is_entry: bool = False


def split_computations(text: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry = None
    cur: Optional[Comp] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Comp(m.group(2), [], is_entry=bool(m.group(1)))
                if cur.is_entry:
                    entry = cur.name
            continue
        s = line.strip()
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    line))
    return comps, entry


def _group_size(line: str, default: int = 2) -> int:
    m = _GRP_RE.search(line)
    if m:
        return int(m.group(2)) if m.group(2) else int(m.group(1))
    m = _GRP_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class Cost:
    dot_flops: float = 0.0
    elem_flops: float = 0.0
    mem_bytes: float = 0.0       # operands + outputs (upper bound)
    mem_bytes_out: float = 0.0   # outputs only (~ buffers materialised)
    coll_raw: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.elem_flops += other.elem_flops * mult
        self.mem_bytes += other.mem_bytes * mult
        self.mem_bytes_out += other.mem_bytes_out * mult
        for d_self, d_other in ((self.coll_raw, other.coll_raw),
                                (self.coll_wire, other.coll_wire),
                                (self.coll_counts, other.coll_counts)):
            for k, v in d_other.items():
                d_self[k] = d_self.get(k, 0.0) + v * mult


class HloCost:
    """Roll-up engine over one HLO module's text."""

    def __init__(self, text: str):
        self.comps, self.entry = split_computations(text)
        self._fusion_bodies = set()
        self._trip_cache: Dict[str, int] = {}
        for comp in self.comps.values():
            for ins in comp.instrs:
                if ins.op == "fusion":
                    m = _ATTR_COMP_RE["calls"].search(ins.line)
                    if m:
                        self._fusion_bodies.add(m.group(1))
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # -- trip counts ---------------------------------------------------------
    def trip_count(self, cond_name: str) -> int:
        if cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        comp = self.comps.get(cond_name)
        trip = 1
        if comp is not None:
            consts = [int(x) for ins in comp.instrs
                      for x in _CONST_RE.findall(ins.line)]
            if consts:
                trip = max(consts)
        self._trip_cache[cond_name] = max(trip, 1)
        return self._trip_cache[cond_name]

    # -- per-computation -----------------------------------------------------
    def comp_cost(self, name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        comp = self.comps.get(name)
        if comp is None:
            self._memo[key] = total
            return total
        symtab = {ins.name: ins.type_str for ins in comp.instrs}
        for ins in comp.instrs:
            op = ins.op
            out_elems = _shape_elems(ins.type_str)
            out_bytes = _shape_bytes(ins.type_str)
            if op == "dot":
                k = self._dot_contract_elems(ins, symtab)
                total.dot_flops += 2.0 * out_elems * k
            elif op in ("convolution",):
                total.dot_flops += 2.0 * out_elems  # lower bound
            elif op in _ELEMENTWISE:
                total.elem_flops += out_elems
            elif op.startswith(_COLLECTIVES):
                base = op
                for c in _COLLECTIVES:
                    if op.startswith(c):
                        base = c
                        break
                if op.endswith("-done"):
                    continue
                nbytes = out_bytes
                if op.endswith("-start") and base == "all-reduce":
                    nbytes //= 2
                g = _group_size(ins.line)
                if base == "all-reduce":
                    w = 2 * nbytes * (g - 1) / g
                elif base in ("all-gather", "all-to-all",
                              "ragged-all-to-all"):
                    w = nbytes * (g - 1) / g
                elif base == "reduce-scatter":
                    w = nbytes * (g - 1)
                else:
                    w = nbytes
                total.coll_raw[base] = total.coll_raw.get(base, 0) + nbytes
                total.coll_wire[base] = total.coll_wire.get(base, 0) + w
                total.coll_counts[base] = total.coll_counts.get(base, 0) + 1

            # memory traffic: only at top (post-fusion) level
            if not in_fusion and op not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast", "while", "call",
                                            "conditional"):
                opers = 0
                args = ins.line[ins.line.find("(") + 1:]
                for nm in _OPERAND_NAME_RE.findall(args):
                    if nm in symtab:
                        opers += _shape_bytes(symtab[nm])
                total.mem_bytes += out_bytes + opers
                total.mem_bytes_out += out_bytes

            # control flow / nested computations
            if op == "while":
                body = _ATTR_COMP_RE["body"].search(ins.line)
                cond = _ATTR_COMP_RE["condition"].search(ins.line)
                trip = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    total.add(self.comp_cost(body.group(1), in_fusion), trip)
            elif op == "fusion":
                m = _ATTR_COMP_RE["calls"].search(ins.line)
                if m:
                    total.add(self.comp_cost(m.group(1), True), 1.0)
            elif op == "call":
                m = _ATTR_COMP_RE["to_apply"].search(ins.line)
                if m:
                    total.add(self.comp_cost(m.group(1), in_fusion), 1.0)
            elif op == "conditional":
                m = _ATTR_COMP_RE["branches"].search(ins.line)
                if m:
                    branches = _OPERAND_NAME_RE.findall(m.group(1))
                    costs = [self.comp_cost(b, in_fusion) for b in branches]
                    if costs:
                        best = max(costs, key=lambda c: c.dot_flops
                                   + c.elem_flops)
                        total.add(best, 1.0)
        self._memo[key] = total
        return total

    def _dot_contract_elems(self, ins: Instr, symtab) -> int:
        m = _DOT_CONTRACT_RE.search(ins.line)
        args = ins.line[ins.line.find("(") + 1:]
        names = _OPERAND_NAME_RE.findall(args)
        if not m or not names or names[0] not in symtab:
            return 1
        lhs_dims = []
        tm = _TYPE_RE.search(symtab[names[0]])
        if tm:
            lhs_dims = [int(d) for d in tm.group(2).split(",") if d]
        k = 1
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
        return k

    # -- public --------------------------------------------------------------
    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry, False)


def analyze(hlo_text: str) -> dict:
    cost = HloCost(hlo_text).total()
    return {
        "dot_flops": cost.dot_flops,
        "elem_flops": cost.elem_flops,
        "flops": cost.dot_flops + cost.elem_flops,
        "mem_bytes": cost.mem_bytes,
        "mem_bytes_out": cost.mem_bytes_out,
        "collectives_raw": {k: v for k, v in sorted(cost.coll_raw.items())},
        "collectives_wire": {k: v for k, v in sorted(cost.coll_wire.items())},
        "collective_counts": {k: v for k, v in
                              sorted(cost.coll_counts.items())},
        "collective_raw_total": sum(cost.coll_raw.values()),
        "collective_wire_total": sum(cost.coll_wire.values()),
    }
