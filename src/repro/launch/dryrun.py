import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the roofline
benchmark (benchmarks/roofline.py) consumes them.
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_LINE_RE = re.compile(
    r"=\s*([^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GRP_RE = re.compile(r"replica_groups=\[(\d+)(?:,(\d+))?\]")
_GRP_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

# scan(length=N) bodies appear once in HLO: collectives inside a while loop
# must be multiplied by the trip count.  XLA CPU emits the loop bound in the
# while condition; we conservatively detect scan trip counts from the
# "jvp()/while" metadata is unreliable, so we instead count collectives in
# the unrolled module produced with as_text() of the *optimized* module —
# trip counts are applied by the caller via cell metadata when needed.


def _group_size(line: str) -> int:
    m = _GRP_RE.search(line)
    if m:
        a, b = m.group(1), m.group(2)
        return int(b) if b else int(a)
    m = _GRP_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from the partitioned HLO.

    For each collective instruction: ``raw`` sums the result-shape bytes
    (shapes in the post-SPMD module are per-device); ``wire`` applies the
    standard ring-traffic multipliers (all-reduce 2(g-1)/g, all-gather /
    all-to-all (g-1)/g, reduce-scatter (g-1), permute 1).  Instructions
    inside while loops (scan-over-layers) are counted once per loop body —
    multiply by trip count externally where needed (roofline does)."""
    per_op = {}
    wire = {}
    counts = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m is None:
            continue
        if m.group(3) == "-done":
            continue  # count the -start only
        op = m.group(2)
        lhs = m.group(1)
        nbytes = 0
        for dt, dims in _TYPE_RE.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        if m.group(3) == "-start" and op == "all-reduce":
            nbytes //= 2  # start tuple carries (operand, result)
        g = _group_size(line)
        if op == "all-reduce":
            w = 2 * nbytes * (g - 1) / g
        elif op in ("all-gather", "all-to-all"):
            w = nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            w = nbytes * (g - 1)
        else:  # collective-permute
            w = nbytes
        per_op[op] = per_op.get(op, 0) + nbytes
        wire[op] = wire.get(op, 0) + int(w)
        counts[op] = counts.get(op, 0) + 1
    per_op["total"] = sum(per_op.values())
    wire["total"] = sum(wire.values())
    return {"raw": per_op, "wire": wire, "counts": counts}


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: str,
             verbose: bool = True, variant: str = "",
             **cell_kw) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    res = {"arch": arch, "shape": shape, "variant": variant,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "n_devices": mesh.devices.size, "cell_kw": repr(cell_kw)}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh, **cell_kw)
        res["meta"] = {k: v for k, v in cell.meta.items()}
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        res["lower_s"] = round(t1 - t0, 2)
        res["compile_s"] = round(t2 - t1, 2)

        try:
            ma = compiled.memory_analysis()
            res["memory"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
            if verbose:
                print(f"  memory_analysis: {res['memory']}")
        except Exception as e:  # noqa: BLE001 - backend-dependent
            res["memory"] = {"error": str(e)}

        try:
            ca = compiled.cost_analysis()
            res["cost"] = {k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float)) and (
                               "flops" in k or "bytes" in k or "utiliz" in k)}
            if verbose:
                fl = res["cost"].get("flops", 0)
                print(f"  cost_analysis: flops={fl:.3e}")
        except Exception as e:  # noqa: BLE001
            res["cost"] = {"error": str(e)}

        hlo = compiled.as_text()
        from repro.launch.hlo_analysis import analyze
        res["analysis"] = analyze(hlo)   # trip-count-aware roll-up
        res["collectives"] = collective_bytes(hlo)  # single-visit (legacy)
        res["hlo_bytes"] = len(hlo)
        res["ok"] = True
        if verbose:
            a = res["analysis"]
            print(f"  rollup: dot_flops/dev={a['dot_flops']:.3e} "
                  f"mem_bytes/dev={a['mem_bytes']:.3e} "
                  f"coll_wire/dev={a['collective_wire_total']:.3e}")
    except Exception as e:  # noqa: BLE001
        res["ok"] = False
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc()[-4000:]
    res["total_s"] = round(time.time() - t0, 2)

    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = f"__{variant}" if variant else ""
        path = os.path.join(outdir, f"{arch}__{shape}{tag}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    return res


def iter_cells():
    for arch, spec in sorted(ARCHS.items()):
        for shape in SHAPES:
            if shape in spec.skip_shapes:
                continue
            yield arch, shape


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--outdir", default=None)
    # perf-iteration knobs (§Perf hillclimbing); results tagged --variant
    ap.add_argument("--variant", default="")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--acc-dtype", default="float32")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--rg-blockheads", type=int, default=None)
    ap.add_argument("--tp-sp", action="store_true")
    args = ap.parse_args(argv)

    mesh_tag = "pod2x16x16" if args.multi_pod else "pod16x16"
    outdir = args.outdir or os.path.join("experiments", "dryrun", mesh_tag)
    cell_kw = dict(microbatches=args.microbatches,
                   acc_dtype=args.acc_dtype, remat=args.remat,
                   optimizer=args.optimizer,
                   rg_block_heads=args.rg_blockheads,
                   tp_sp=args.tp_sp)

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in cells:
        print(f"[dryrun {mesh_tag}] {arch} x {shape} ...", flush=True)
        res = run_cell(arch, shape, args.multi_pod, outdir,
                       variant=args.variant, **cell_kw)
        status = "OK" if res["ok"] else f"FAIL: {res.get('error')}"
        print(f"[dryrun {mesh_tag}] {arch} x {shape}: {status} "
              f"({res['total_s']}s)", flush=True)
        failures += 0 if res["ok"] else 1
    print(f"[dryrun {mesh_tag}] done, {failures} failure(s) "
          f"of {len(cells)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
