"""Cell builder: (architecture x input shape x mesh) -> lowerable closure.

A *cell* packages the step function, abstract inputs (ShapeDtypeStruct — no
allocation), and in/out shardings for one dry-run / roofline entry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs import ARCHS, SHAPES, ArchSpec, ShapeCfg
from repro.models import build_model
from repro.optim import OptCfg, make_optimizer
from repro.sharding import (DEFAULT_RULES, fsdp_rules, serve_rules, sp_rules,
                            resolve, use_sharding)
from repro.train.step import make_prefill_step, make_serve_step, make_train_step

WHISPER_CROSS_LEN = 1500  # encoder frames for enc-dec decode cells


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Any
    out_shardings: Any
    mesh: Mesh
    rules: dict
    meta: dict
    donate: Tuple[int, ...] = ()   # donated arg indices (in-place updates)


def _shard_tree(axes_tree, abs_tree, mesh, rules):
    def one(ax, ab):
        return NamedSharding(mesh, resolve(ab.shape, ax, mesh, rules))
    return jax.tree.map(
        one, axes_tree, abs_tree,
        is_leaf=lambda x: (isinstance(x, tuple)
                           and all(isinstance(a, (str, type(None)))
                                   for a in x)))


def _batch_abstract(spec: ArchSpec, shape: ShapeCfg):
    cfg = spec.cfg
    B, S = shape.global_batch, shape.seq
    batch = {}
    axes = {}
    if cfg.frontend == "vision":
        s_text = S - cfg.n_frontend_tokens
        batch["tokens"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        axes["patch_embeds"] = ("batch", "seq", "embed")
    elif cfg.frontend == "audio":
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.float32)
        axes["frame_embeds"] = ("batch", "seq", "embed")
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    axes.setdefault("tokens", ("batch", "seq"))
    axes.setdefault("labels", ("batch", "seq"))
    return batch, axes


def opt_for(spec: ArchSpec) -> OptCfg:
    name = getattr(spec, "optimizer", None) or (
        "adamw8" if spec.published_params and spec.published_params > 1e11
        else "adamw")
    return OptCfg(name=name)


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               rules: Optional[dict] = None,
               microbatches: Optional[int] = None,
               remat: Optional[str] = None,
               acc_dtype: str = "float32",
               optimizer: Optional[str] = None,
               rg_block_heads: Optional[int] = None,
               tp_sp: bool = False) -> Cell:
    spec = ARCHS[arch]
    shape = SHAPES[shape_name]
    cfg = spec.cfg
    if rg_block_heads and cfg.rglru is not None:
        cfg = cfg.replace(rglru=dataclasses.replace(
            cfg.rglru, block_heads=rg_block_heads))
    if shape.kind == "decode":
        cfg = cfg.replace(max_target_length=max(shape.seq + 8,
                                                cfg.max_target_length))
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    if shape.kind != "train":
        cfg = cfg.replace(remat="none")
    model = build_model(cfg)
    params_abs = model.abstract_params()
    params_axes = model.param_axes()

    if rules is None:
        if shape_name.startswith("long"):
            rules = sp_rules(serve_rules())
        elif shape.kind == "train":
            from repro.sharding.rules import tp_sp_rules
            rules = tp_sp_rules() if tp_sp else fsdp_rules()
        elif shape.kind == "prefill":
            # prefill is compute-shaped like training: FSDP weight
            # gathers per layer beat replicated-weight serving rules
            rules = fsdp_rules()
        else:
            rules = serve_rules()
            # kv-heads that cannot split the model axis: shard the cache
            # *length* over 'model' instead (keeps the cache in HBM bounds)
            if (not cfg.encdec and cfg.mla is None and cfg.ssm is None
                    and cfg.n_kv_heads % mesh.shape["model"] != 0):
                rules = dict(rules, cache="model", kv_heads=None)

    p_shard = _shard_tree(params_axes, params_abs, mesh, rules)
    meta = dict(kind=shape.kind, seq=shape.seq,
                global_batch=shape.global_batch,
                n_params=sum(int(jnp.prod(jnp.array(x.shape)))
                             for x in jax.tree.leaves(params_abs)))

    if shape.kind == "train":
        mb = microbatches
        if mb is None:
            mb = (spec.microbatches or {}).get(shape_name, 1)
            # production default: never slice the per-microbatch batch
            # below the data-parallel extent, or the whole step replicates
            # across 'data' (§Perf cell 1).  Explicit --microbatches
            # overrides (how the paper-faithful baseline is reproduced).
            dp = 1
            for ax in ("pod", "data"):
                if ax in mesh.axis_names:
                    dp *= mesh.shape[ax]
            while mb > 1 and shape.global_batch // mb < dp:
                mb //= 2
        ocfg = opt_for(spec)
        if optimizer:
            ocfg = OptCfg(name=optimizer)
        opt = make_optimizer(ocfg)
        opt_abs = opt.abstract_state(params_abs)
        opt_axes = opt.state_axes(params_axes)
        o_shard = _shard_tree(opt_axes, opt_abs, mesh, rules)
        batch_abs, batch_axes = _batch_abstract(spec, shape)
        b_shard = _shard_tree(batch_axes, batch_abs, mesh, rules)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        raw_step = make_train_step(model, opt, microbatches=mb,
                                   acc_dtype=jnp.dtype(acc_dtype))

        def fn(params, opt_state, batch, step):
            with use_sharding(mesh, rules):
                return raw_step(params, opt_state, batch, step)

        repl = NamedSharding(mesh, PartitionSpec())
        metrics_shard = {k: repl for k in
                         ["loss", "ce", "aux", "mtp", "grad_norm", "lr"]}
        meta["microbatches"] = mb
        return Cell(arch, shape_name, fn,
                    (params_abs, opt_abs, batch_abs, step_abs),
                    (p_shard, o_shard, b_shard, repl),
                    (p_shard, o_shard, None),
                    mesh, rules, meta, donate=(0, 1))   # params, opt_state

    if shape.kind == "prefill":
        batch_abs, batch_axes = _batch_abstract(spec, shape)
        b_shard = _shard_tree(batch_axes, batch_abs, mesh, rules)
        batch_abs.pop("labels")
        b_shard.pop("labels")
        raw = make_prefill_step(model)

        def fn(params, batch):
            with use_sharding(mesh, rules):
                return raw(params, batch)

        return Cell(arch, shape_name, fn, (params_abs, batch_abs),
                    (p_shard, b_shard), None, mesh, rules, meta)

    # decode: serve_step over a pre-existing cache of length seq
    B, S = shape.global_batch, shape.seq
    if cfg.encdec:
        cache_abs = (model.abstract_cache(B, S),
                     _cross_kv_abstract(model, B))
        cache_axes = (jax.tree.map(lambda s: s.axes, model.cache_specs(B, S),
                                   is_leaf=lambda x: hasattr(x, "axes")),
                      _cross_kv_axes(model))
    else:
        cache_abs = model.abstract_cache(B, S)
        cache_axes = jax.tree.map(lambda s: s.axes, model.cache_specs(B, S),
                                  is_leaf=lambda x: hasattr(x, "axes"))
    c_shard = _shard_tree(cache_axes, cache_abs, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_shard = NamedSharding(mesh, resolve((B, 1), ("batch", "seq"),
                                          mesh, rules))
    raw = make_serve_step(model)

    def fn(params, caches, tokens, pos):
        with use_sharding(mesh, rules):
            return raw(params, caches, tokens, pos)

    return Cell(arch, shape_name, fn, (params_abs, cache_abs, tok_abs,
                                       pos_abs),
                (p_shard, c_shard, t_shard, t_shard),
                (t_shard, c_shard), mesh, rules, meta,
                donate=(1,))                             # KV caches in-place


def _cross_kv_abstract(model, B):
    cfg = model.cfg
    L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    sh = (L, B, WHISPER_CROSS_LEN, KH, hd)
    return (jax.ShapeDtypeStruct(sh, dt), jax.ShapeDtypeStruct(sh, dt))


def _cross_kv_axes(model):
    ax = ("layers", "batch", "cache", "kv_heads", "head_dim")
    return (ax, ax)
