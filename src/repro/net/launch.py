"""Multi-process rank launcher for SocketTransport runs.

API (paper's ``mpiexec`` role, for one machine)::

    from repro import edat

    def main(ctx):            # must be importable (module level): children
        ...                   # are spawned, not forked

    stats = edat.launch_processes(4, main)              # 1 rank / process
    stats = edat.launch_processes(4, main, n_procs=2)   # 2 ranks / process

or, for failure-injection control::

    pg = ProcessGroup(4, main, n_procs=2)
    pg.start()
    pg.kill(3)                # SIGKILL the process hosting rank 3: every
    stats = pg.wait()         # rank it hosted dies; survivors' heartbeat
                              # detectors raise RANK_FAILED for each

CLI::

    python -m repro.net.launch --ranks 4 examples/net_pingpong.py:main
    python -m repro.net.launch -n 4 --procs 2 repro.something:main

The spec is ``module.path:callable`` or ``path/to/file.py:callable``
(callable defaults to ``main``); each child resolves it independently, so
file-based specs need no importable package.  With ``n_procs`` (or an
explicit ``placement`` list of rank tuples) each spawned process hosts a
contiguous block of ranks — ``main(ctx)`` still runs once per *rank*, and
co-located ranks exchange events in-process without touching a socket.
Children rendezvous through the rank-0 coordinator
(:mod:`repro.net.bootstrap`); the parent only picks the coordinator port,
spawns, and reaps.

Every child also exports ``EDAT_RANK`` / ``EDAT_LOCAL_RANKS`` /
``EDAT_NRANKS`` / ``EDAT_COORD`` so user code can introspect its
placement.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import multiprocessing as mp
import os
import socket
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

MainSpec = Union[Callable, str]


def _free_port(host: str = "127.0.0.1") -> int:
    """Probe a currently-free port.  Inherently racy (the port is released
    before the coordinator child re-binds it); the bootstrap side closes
    the race with a bind-retry loop — see
    :func:`repro.net.bootstrap._listener_retry`."""
    with socket.socket() as s:
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind((host, 0))
        return s.getsockname()[1]


def default_placement(n_ranks: int, n_procs: int) -> List[Tuple[int, ...]]:
    """Contiguous block placement: ``n_ranks`` over ``n_procs`` processes,
    earlier processes taking the larger blocks."""
    assert 1 <= n_procs <= n_ranks, (n_ranks, n_procs)
    base, extra = divmod(n_ranks, n_procs)
    out, r = [], 0
    for p in range(n_procs):
        k = base + (1 if p < extra else 0)
        out.append(tuple(range(r, r + k)))
        r += k
    return out


def _resolve_spec(spec: str) -> Callable:
    """``pkg.mod:fn`` or ``path/file.py:fn`` (fn defaults to ``main``)."""
    target, _, fn_name = spec.partition(":")
    fn_name = fn_name or "main"
    if target.endswith(".py") or os.sep in target:
        name = "_edat_main_" + os.path.splitext(os.path.basename(target))[0]
        s = importlib.util.spec_from_file_location(name, target)
        if s is None:
            raise ValueError(f"cannot load {target!r}")
        mod = importlib.util.module_from_spec(s)
        sys.modules[name] = mod
        s.loader.exec_module(mod)
    else:
        mod = importlib.import_module(target)
    fn = getattr(mod, fn_name, None)
    if not callable(fn):
        raise ValueError(f"{spec!r}: no callable {fn_name!r} in {target!r}")
    return fn


def _child_entry(ranks: Tuple[int, ...], n_ranks: int, coord_addr,
                 main: MainSpec, runtime_kwargs: Dict[str, Any],
                 run_timeout: float, net: Dict[str, Any], result_q,
                 launch_id: str = "", join: bool = False,
                 ready_file: Optional[str] = None) -> None:
    os.environ["EDAT_RANK"] = str(ranks[0])
    os.environ["EDAT_LOCAL_RANKS"] = ",".join(str(r) for r in ranks)
    os.environ["EDAT_NRANKS"] = str(n_ranks)
    os.environ["EDAT_COORD"] = f"{coord_addr[0]}:{coord_addr[1]}"
    if launch_id:
        # unique per ProcessGroup.start(): lets user code key shared
        # scratch space to THIS launch (a reused coordinator port must
        # not resurrect a previous run's on-disk state)
        os.environ["EDAT_LAUNCH_ID"] = launch_id
    if join:
        # lets user code distinguish an elastic replacement from the
        # original incarnation of its ranks (e.g. chaos programs that
        # stall their first incarnation must not stall the second)
        os.environ["EDAT_JOINED"] = "1"
    try:
        from repro.core.runtime import Runtime
        from .bootstrap import bootstrap, bootstrap_join
        if isinstance(main, str):
            main = _resolve_spec(main)
        if join:
            # replacement process: HELLO into the *running* coordinator
            # and re-host this placement entry's (dead) ranks
            jnet = {k: v for k, v in net.items() if k != "elastic"}
            transport = bootstrap_join(ranks[0], n_ranks, coord_addr,
                                       local_ranks=ranks, **jnet)
        else:
            transport = bootstrap(ranks[0], n_ranks, coord_addr,
                                  local_ranks=ranks, **net)
        if ready_file:
            # the mesh splice is complete: tell the observer (chaos tests
            # key "the replacement is in" off this file's existence)
            with open(ready_file, "w"):
                pass
        rt = Runtime(n_ranks, transport=transport, **runtime_kwargs)
        t0 = time.monotonic()
        stats = rt._run_internal(main, timeout=run_timeout)
        # the wall time of the run itself: stamped *before* the finalize
        # hook so result spooling (pickling a large gathered array) never
        # inflates the in-child run_seconds benchmarks divide by
        run_seconds = time.monotonic() - t0
        # post-run hook (v2 Session result gathering): a main object may
        # carry an `_edat_finalize(ranks, stats)` method, run after clean
        # global termination — e.g. to persist the program's gathered
        # result for the launching parent.  The deliberately-prefixed
        # name cannot collide with an unrelated user method.
        fin = getattr(main, "_edat_finalize", None)
        if fin is not None:
            fin(ranks, stats)
        # every child (not just rank 0's) reports its metric snapshot so
        # the parent can merge per-channel counters across processes
        mt = rt.metrics()
        if mt is not None:
            try:
                result_q.put(("metrics", ranks[0], mt))
            except Exception:
                pass  # unpicklable trace payload etc: stats still flow
        if 0 in ranks:
            stats = dict(stats)
            stats["run_seconds"] = run_seconds
            result_q.put(("ok", stats))
    except BaseException as e:  # noqa: BLE001 - report, then non-zero exit
        if type(e).__name__ == "RankDiedError":
            # the termination coordinator (rank 0's process) died under
            # this rank: an *expected* casualty of fault injection, not a
            # bug in this child — report distinctly and exit cleanly so
            # chaos tests can assert "no survivor crashed"
            try:
                result_q.put(("rankdied", ranks[0], str(e)))
            except Exception:
                pass
            raise SystemExit(0)
        try:
            result_q.put(("err", ranks[0], f"{type(e).__name__}: {e}"))
        except Exception:
            pass
        raise SystemExit(1)


class ProcessGroup:
    """A set of spawned rank processes sharing one SocketTransport world.

    ``n_procs`` (or an explicit ``placement``: a partition of
    ``range(n_ranks)`` into per-process rank tuples) places several ranks
    in one OS process; default is one rank per process."""

    #: ProcessGroup kwargs forwarded to the SocketTransport (via bootstrap)
    #: rather than to the Runtime
    NET_KEYS = ("hb_interval", "hb_timeout", "coalesce", "flush_interval",
                "max_batch_bytes", "elastic")

    def __init__(self, n_ranks: int, main: MainSpec, *,
                 n_procs: Optional[int] = None,
                 placement: Optional[Sequence[Sequence[int]]] = None,
                 run_timeout: float = 120.0,
                 host: str = "127.0.0.1",
                 **kwargs: Any):
        self.n_ranks = n_ranks
        self.main = main
        self.run_timeout = run_timeout
        if placement is not None:
            self.placement = [tuple(sorted(int(r) for r in rs))
                              for rs in placement]
        else:
            self.placement = default_placement(n_ranks, n_procs or n_ranks)
        covered = sorted(r for rs in self.placement for r in rs)
        assert covered == list(range(n_ranks)), \
            f"placement {self.placement} does not partition 0..{n_ranks-1}"
        self._net = {k: kwargs.pop(k) for k in list(kwargs)
                     if k in self.NET_KEYS}
        self._net.setdefault("hb_interval", 0.5)
        self._net.setdefault("hb_timeout", 5.0)
        self.runtime_kwargs = kwargs
        self._host = host
        #: one process per placement entry, keyed by its lead rank
        self._procs: Dict[int, mp.process.BaseProcess] = {}
        self._killed = set()        # ranks whose process we SIGKILLed
        self._q = None
        self._coord: Optional[Tuple[str, int]] = None
        self._launch_id = ""
        #: every (kind, ...) report the children queued, populated by wait()
        self.child_reports: List[tuple] = []

    def _proc_of(self, rank: int) -> Tuple[int, Tuple[int, ...]]:
        for rs in self.placement:
            if rank in rs:
                return rs[0], rs
        raise KeyError(rank)

    def start(self) -> "ProcessGroup":
        import uuid
        ctx = mp.get_context("spawn")
        self._q = ctx.SimpleQueue()
        self._coord = (self._host, _free_port(self._host))
        self._launch_id = uuid.uuid4().hex[:12]
        for rs in self.placement:
            p = ctx.Process(
                target=_child_entry,
                args=(rs, self.n_ranks, self._coord, self.main,
                      self.runtime_kwargs, self.run_timeout, self._net,
                      self._q, self._launch_id),
                daemon=False,
                name="edat-ranks" + "_".join(str(r) for r in rs))
            p.start()
            self._procs[rs[0]] = p
        return self

    def kill(self, rank: int) -> None:
        """SIGKILL the process hosting ``rank`` — the cross-process
        equivalent of ``Runtime.kill_rank``, at process granularity: every
        co-located rank dies with it, and survivors' heartbeat detectors
        raise one RANK_FAILED per lost rank."""
        lead, rs = self._proc_of(rank)
        self._killed.update(rs)
        self._procs[lead].kill()

    def respawn(self, rank: int,
                ready_file: Optional[str] = None) -> None:
        """Launch a replacement process for the (dead) process hosting
        ``rank``: the elastic-join counterpart of :meth:`kill`.  The child
        runs the same ``main`` but rendezvouses through
        :func:`~repro.net.bootstrap.bootstrap_join` against the *running*
        coordinator — requires the group to have been started with
        ``elastic=True``.  ``ready_file`` (if given) is created by the
        child the moment its mesh splice completes, so a chaos test can
        key "the replacement is in" without polling the coordinator.  The
        replacement is expected to exit cleanly: its ranks are removed
        from the killed set."""
        if not self._net.get("elastic"):
            raise RuntimeError(
                "respawn() requires ProcessGroup(..., elastic=True): "
                "without it the coordinator listener is closed after "
                "bootstrap and a replacement has nothing to JOIN")
        lead, rs = self._proc_of(rank)
        old = self._procs.get(lead)
        if old is not None and old.is_alive():
            # a just-delivered SIGKILL needs a moment to reap
            old.join(5.0)
        if old is not None and old.is_alive():
            raise RuntimeError(
                f"process hosting rank {rank} is still alive; respawn is "
                f"for replacing a dead process")
        ctx = mp.get_context("spawn")
        p = ctx.Process(
            target=_child_entry,
            args=(rs, self.n_ranks, self._coord, self.main,
                  self.runtime_kwargs, self.run_timeout, self._net,
                  self._q, self._launch_id, True, ready_file),
            daemon=False,
            name="edat-rejoin" + "_".join(str(r) for r in rs))
        p.start()
        self._procs[lead] = p
        self._killed -= set(rs)

    def join_all(self, timeout: Optional[float] = None) -> bool:
        """Soft join: wait for every process to exit *without* killing
        stragglers.  True iff all processes have exited.  This is the
        non-destructive probe ``Future.result(timeout)`` uses — a timeout
        must leave the round running and retryable, not SIGKILL it."""
        deadline = time.monotonic() + (
            timeout if timeout is not None else self.run_timeout + 30.0)
        for p in self._procs.values():
            p.join(max(0.0, deadline - time.monotonic()))
        return all(not p.is_alive() for p in self._procs.values())

    def wait(self, timeout: Optional[float] = None,
             check: bool = True) -> Dict[str, Any]:
        """Join all processes; return rank 0's stats (with the merged
        cross-process metric counters attached when metrics are on).
        Stragglers past the deadline are killed (tests must fail fast, not
        hang).  With ``check``, any unexpected child failure raises
        ``RuntimeError`` (deliberately ``kill()``-ed processes are
        expected to die)."""
        deadline = time.monotonic() + (
            timeout if timeout is not None else self.run_timeout + 30.0)
        hung = []
        for lead, p in self._procs.items():
            p.join(max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                hung.append(lead)
                p.kill()
                p.join(5.0)
        results = []
        while not self._q.empty():
            results.append(self._q.get())
        self.child_reports = results
        stats = next((x[1] for x in results if x[0] == "ok"), None)
        if check:
            if hung:
                raise RuntimeError(
                    f"process(es) led by ranks {hung} did not exit within "
                    f"the deadline; killed.  child reports: {results}")
            errs = [x for x in results if x[0] == "err"
                    and x[1] not in self._killed]
            bad = [lead for lead, p in self._procs.items()
                   if p.exitcode not in (0, None)
                   and lead not in self._killed]
            if errs or bad:
                raise RuntimeError(
                    f"rank process(es) failed: exitcodes="
                    f"{self.exitcodes()} reports={results}")
        out = dict(stats) if stats is not None else {}
        parts = [(x[1], x[2]) for x in results if x[0] == "metrics"]
        if parts:
            from repro.core.metrics import merge_metrics
            out.update(merge_metrics(parts))
        return out

    def exitcodes(self) -> Dict[int, Optional[int]]:
        """Exit code per *rank* (co-located ranks share their process's)."""
        out = {}
        for rs in self.placement:
            code = self._procs[rs[0]].exitcode
            for r in rs:
                out[r] = code
        return out


def launch_processes(n_ranks: int, main: MainSpec, *,
                     timeout: float = 120.0, join_timeout: float = None,
                     check: bool = True,
                     **kwargs: Any) -> Dict[str, Any]:
    """Spawn rank processes running ``main`` SPMD over SocketTransport;
    block until they all exit and return rank 0's stats (including
    ``run_seconds``, the in-child wall time of ``Runtime.run``).  By
    default each rank gets its own process; ``n_procs=k`` packs the ranks
    into ``k`` processes (``placement`` for full control).  Extra kwargs
    go to :class:`ProcessGroup`: transport knobs (``hb_interval``,
    ``hb_timeout``, ``coalesce``, ``flush_interval``, ``max_batch_bytes``)
    reach the :class:`~repro.net.SocketTransport`; everything else reaches
    the ``Runtime`` (e.g. ``workers_per_rank``, ``progress``,
    ``unconsumed``)."""
    pg = ProcessGroup(n_ranks, main, run_timeout=timeout, **kwargs)
    pg.start()
    return pg.wait(join_timeout, check=check)


def _cli(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.net.launch",
        description="Run an EDAT main SPMD across local rank processes "
                    "over SocketTransport.")
    ap.add_argument("spec", help="module.path:fn or path/to/file.py:fn "
                                 "(fn defaults to 'main')")
    ap.add_argument("-n", "--ranks", type=int, default=2)
    ap.add_argument("--procs", type=int, default=None,
                    help="number of OS processes to pack the ranks into "
                         "(default: one per rank); co-located ranks "
                         "exchange events without touching a socket")
    ap.add_argument("--workers", type=int, default=1,
                    help="workers per rank (default 1)")
    ap.add_argument("--progress", choices=("thread", "worker"),
                    default="thread")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-rank Runtime.run timeout (s)")
    ap.add_argument("--unconsumed", choices=("error", "warn", "ignore"),
                    default="error")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable writer-side event coalescing (one frame "
                         "per send; the slow path, for A/B comparisons)")
    ap.add_argument("--flush-interval", type=float, default=0.0,
                    help="writer batching window in seconds (default 0: "
                         "purely opportunistic coalescing)")
    ap.add_argument("--max-batch-bytes", type=int, default=1 << 20,
                    help="approximate cap on one coalesced frame (bytes)")
    args = ap.parse_args(argv)
    _resolve_spec(args.spec)  # fail fast in the parent on a bad spec
    stats = launch_processes(
        args.ranks, args.spec, timeout=args.timeout, n_procs=args.procs,
        workers_per_rank=args.workers, progress=args.progress,
        unconsumed=args.unconsumed, coalesce=not args.no_coalesce,
        flush_interval=args.flush_interval,
        max_batch_bytes=args.max_batch_bytes)
    print(f"[repro.net.launch] {args.ranks} ranks terminated cleanly: "
          f"{stats}")
    return 0


if __name__ == "__main__":
    sys.exit(_cli())
