"""Multi-process rank launcher for SocketTransport runs.

API (paper's ``mpiexec`` role, for one machine)::

    from repro import edat

    def main(ctx):            # must be importable (module level): children
        ...                   # are spawned, not forked

    stats = edat.launch_processes(4, main)          # blocks, returns stats

or, for failure-injection control::

    pg = ProcessGroup(4, main)
    pg.start()
    pg.kill(3)                # SIGKILL: the heartbeat detector notices
    stats = pg.wait()

CLI::

    python -m repro.net.launch --ranks 4 examples/net_pingpong.py:main
    python -m repro.net.launch -n 2 repro.something:main --progress worker

The spec is ``module.path:callable`` or ``path/to/file.py:callable``
(callable defaults to ``main``); each child resolves it independently, so
file-based specs need no importable package.  Children rendezvous through
the rank-0 coordinator (:mod:`repro.net.bootstrap`); the parent only picks
the coordinator port, spawns, and reaps.

Every child also exports ``EDAT_RANK`` / ``EDAT_NRANKS`` / ``EDAT_COORD``
so user code can introspect its placement.
"""
from __future__ import annotations

import argparse
import importlib
import importlib.util
import multiprocessing as mp
import os
import socket
import sys
import time
from typing import Any, Callable, Dict, Optional, Union

MainSpec = Union[Callable, str]


def _free_port(host: str = "127.0.0.1") -> int:
    with socket.socket() as s:
        s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        s.bind((host, 0))
        return s.getsockname()[1]


def _resolve_spec(spec: str) -> Callable:
    """``pkg.mod:fn`` or ``path/file.py:fn`` (fn defaults to ``main``)."""
    target, _, fn_name = spec.partition(":")
    fn_name = fn_name or "main"
    if target.endswith(".py") or os.sep in target:
        name = "_edat_main_" + os.path.splitext(os.path.basename(target))[0]
        s = importlib.util.spec_from_file_location(name, target)
        if s is None:
            raise ValueError(f"cannot load {target!r}")
        mod = importlib.util.module_from_spec(s)
        sys.modules[name] = mod
        s.loader.exec_module(mod)
    else:
        mod = importlib.import_module(target)
    fn = getattr(mod, fn_name, None)
    if not callable(fn):
        raise ValueError(f"{spec!r}: no callable {fn_name!r} in {target!r}")
    return fn


def _child_entry(rank: int, n_ranks: int, coord_addr, main: MainSpec,
                 runtime_kwargs: Dict[str, Any], run_timeout: float,
                 net: Dict[str, Any], result_q) -> None:
    os.environ["EDAT_RANK"] = str(rank)
    os.environ["EDAT_NRANKS"] = str(n_ranks)
    os.environ["EDAT_COORD"] = f"{coord_addr[0]}:{coord_addr[1]}"
    try:
        from repro.core.runtime import Runtime
        from .bootstrap import bootstrap
        if isinstance(main, str):
            main = _resolve_spec(main)
        transport = bootstrap(rank, n_ranks, coord_addr, **net)
        rt = Runtime(n_ranks, transport=transport, **runtime_kwargs)
        t0 = time.monotonic()
        stats = rt.run(main, timeout=run_timeout)
        if rank == 0:
            stats = dict(stats)
            stats["run_seconds"] = time.monotonic() - t0
            result_q.put(("ok", stats))
    except BaseException as e:  # noqa: BLE001 - report, then non-zero exit
        try:
            result_q.put(("err", rank, f"{type(e).__name__}: {e}"))
        except Exception:
            pass
        raise SystemExit(1)


class ProcessGroup:
    """A set of spawned rank processes sharing one SocketTransport world."""

    #: ProcessGroup kwargs forwarded to the SocketTransport (via bootstrap)
    #: rather than to the Runtime
    NET_KEYS = ("hb_interval", "hb_timeout", "coalesce", "flush_interval",
                "max_batch_bytes")

    def __init__(self, n_ranks: int, main: MainSpec, *,
                 run_timeout: float = 120.0,
                 host: str = "127.0.0.1",
                 **kwargs: Any):
        self.n_ranks = n_ranks
        self.main = main
        self.run_timeout = run_timeout
        self._net = {k: kwargs.pop(k) for k in list(kwargs)
                     if k in self.NET_KEYS}
        self._net.setdefault("hb_interval", 0.5)
        self._net.setdefault("hb_timeout", 5.0)
        self.runtime_kwargs = kwargs
        self._host = host
        self._procs: Dict[int, mp.process.BaseProcess] = {}
        self._killed = set()
        self._q = None

    def start(self) -> "ProcessGroup":
        ctx = mp.get_context("spawn")
        self._q = ctx.SimpleQueue()
        coord = (self._host, _free_port(self._host))
        for r in range(self.n_ranks):
            p = ctx.Process(
                target=_child_entry,
                args=(r, self.n_ranks, coord, self.main,
                      self.runtime_kwargs, self.run_timeout, self._net,
                      self._q),
                daemon=False, name=f"edat-rank{r}")
            p.start()
            self._procs[r] = p
        return self

    def kill(self, rank: int) -> None:
        """SIGKILL a rank's process — the cross-process equivalent of
        ``Runtime.kill_rank``; survivors' heartbeat detectors raise
        RANK_FAILED."""
        self._killed.add(rank)
        self._procs[rank].kill()

    def wait(self, timeout: Optional[float] = None,
             check: bool = True) -> Dict[str, Any]:
        """Join all ranks; return rank 0's stats.  Stragglers past the
        deadline are killed (tests must fail fast, not hang).  With
        ``check``, any unexpected child failure raises ``RuntimeError``
        (deliberately ``kill()``-ed ranks are expected to die)."""
        deadline = time.monotonic() + (
            timeout if timeout is not None else self.run_timeout + 30.0)
        hung = []
        for r, p in self._procs.items():
            p.join(max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                hung.append(r)
                p.kill()
                p.join(5.0)
        results = []
        while not self._q.empty():
            results.append(self._q.get())
        stats = next((x[1] for x in results if x[0] == "ok"), None)
        if check:
            if hung:
                raise RuntimeError(
                    f"ranks {hung} did not exit within the deadline; "
                    f"killed.  child reports: {results}")
            errs = [x for x in results if x[0] == "err"
                    and x[1] not in self._killed]
            bad = [r for r, p in self._procs.items()
                   if p.exitcode not in (0, None) and r not in self._killed]
            if errs or bad:
                raise RuntimeError(
                    f"rank process(es) failed: exitcodes="
                    f"{ {r: p.exitcode for r, p in self._procs.items()} } "
                    f"reports={results}")
        return stats if stats is not None else {}

    def exitcodes(self) -> Dict[int, Optional[int]]:
        return {r: p.exitcode for r, p in self._procs.items()}


def launch_processes(n_ranks: int, main: MainSpec, *,
                     timeout: float = 120.0, join_timeout: float = None,
                     check: bool = True,
                     **kwargs: Any) -> Dict[str, Any]:
    """Spawn ``n_ranks`` OS processes running ``main`` SPMD over
    SocketTransport; block until they all exit and return rank 0's stats
    (including ``run_seconds``, the in-child wall time of ``Runtime.run``).
    Extra kwargs go to :class:`ProcessGroup`: transport knobs
    (``hb_interval``, ``hb_timeout``, ``coalesce``, ``flush_interval``,
    ``max_batch_bytes``) reach the :class:`~repro.net.SocketTransport`;
    everything else reaches the ``Runtime`` (e.g. ``workers_per_rank``,
    ``progress``, ``unconsumed``)."""
    pg = ProcessGroup(n_ranks, main, run_timeout=timeout, **kwargs)
    pg.start()
    return pg.wait(join_timeout, check=check)


def _cli(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.net.launch",
        description="Run an EDAT main SPMD across local rank processes "
                    "over SocketTransport.")
    ap.add_argument("spec", help="module.path:fn or path/to/file.py:fn "
                                 "(fn defaults to 'main')")
    ap.add_argument("-n", "--ranks", type=int, default=2)
    ap.add_argument("--workers", type=int, default=1,
                    help="workers per rank (default 1)")
    ap.add_argument("--progress", choices=("thread", "worker"),
                    default="thread")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-rank Runtime.run timeout (s)")
    ap.add_argument("--unconsumed", choices=("error", "warn", "ignore"),
                    default="error")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable writer-side event coalescing (one frame "
                         "per send; the slow path, for A/B comparisons)")
    ap.add_argument("--flush-interval", type=float, default=0.0,
                    help="writer batching window in seconds (default 0: "
                         "purely opportunistic coalescing)")
    ap.add_argument("--max-batch-bytes", type=int, default=1 << 20,
                    help="approximate cap on one coalesced frame (bytes)")
    args = ap.parse_args(argv)
    _resolve_spec(args.spec)  # fail fast in the parent on a bad spec
    stats = launch_processes(
        args.ranks, args.spec, timeout=args.timeout,
        workers_per_rank=args.workers, progress=args.progress,
        unconsumed=args.unconsumed, coalesce=not args.no_coalesce,
        flush_interval=args.flush_interval,
        max_batch_bytes=args.max_batch_bytes)
    print(f"[repro.net.launch] {args.ranks} ranks terminated cleanly: "
          f"{stats}")
    return 0


if __name__ == "__main__":
    sys.exit(_cli())
