"""repro.net — multi-process socket transport + rank launcher.

Makes the EDAT reproduction *actually distributed*: ranks as OS processes
exchanging length-prefixed pickled frames over TCP, a rank-0 rendezvous
(:mod:`~repro.net.bootstrap`), a heartbeat peer-failure detector feeding
the runtime's RANK_FAILED machinery, and a spawn-based local launcher
(:mod:`~repro.net.launch`, also ``python -m repro.net.launch``).

Nothing above the :class:`~repro.core.transport.Transport` interface
changes: the same ``main(ctx)`` runs threads-as-ranks in one process or
SPMD across processes.
"""
from .bootstrap import bootstrap, bootstrap_from_env, bootstrap_join
from .socket_transport import SocketTransport

__all__ = ["SocketTransport", "bootstrap", "bootstrap_from_env",
           "bootstrap_join", "ProcessGroup", "launch_processes"]


def __getattr__(name):
    # lazy: `python -m repro.net.launch` must be able to import the package
    # without the package importing repro.net.launch first (runpy warning)
    if name in ("ProcessGroup", "launch_processes"):
        from . import launch
        return getattr(launch, name)
    raise AttributeError(name)
