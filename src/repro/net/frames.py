"""Wire format for :class:`repro.net.SocketTransport`.

A frame is a 4-byte big-endian length prefix followed by a pickled Python
object.  The object is always a tuple tagged with its kind:

* ``("msg", Message)`` — a runtime :class:`~repro.core.transport.Message`
  (EVENT or CONTROL);
* ``("hello", rank)`` — connection preamble identifying the dialing peer;
* ``("hb",)`` — heartbeat (liveness only, never surfaced to the runtime);
* ``("bye",)`` — clean close: the peer is shutting down deliberately, so
  the subsequent EOF must *not* be reported as a failure.

Pickle (highest protocol) keeps arbitrary user payloads working without a
schema; frames from one sender are written under a per-connection lock and
read by a single reader thread, so per-(src,dst) FIFO order is exactly the
TCP byte order.
"""
from __future__ import annotations

import pickle
import socket
import struct
from typing import Any, Optional

_LEN = struct.Struct(">I")

#: refuse absurd frames (corruption guard), 1 GiB
MAX_FRAME = 1 << 30

MSG = "msg"
HELLO = "hello"
HEARTBEAT = "hb"
BYE = "bye"


def encode(obj: Any) -> bytes:
    """Serialise ``obj`` into one length-prefixed frame."""
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return _LEN.pack(len(data)) + data


def send_frame(sock: socket.socket, obj: Any) -> None:
    sock.sendall(encode(obj))


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes; None on EOF (including mid-frame EOF)."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[Any]:
    """Read one frame; None on EOF."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME")
    body = _recv_exact(sock, n)
    if body is None:
        return None
    return pickle.loads(body)


def recv_frame_buffered(f) -> Optional[Any]:
    """Like :func:`recv_frame` but over a buffered binary file object
    (``sock.makefile("rb")``) — a burst of small frames costs one syscall,
    not two per frame."""
    head = f.read(_LEN.size)
    if len(head) < _LEN.size:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME")
    body = f.read(n)
    if len(body) < n:
        return None
    return pickle.loads(body)
