"""Wire format for :class:`repro.net.SocketTransport`.

Two frame layouts share one 4-byte big-endian header word:

**Plain frame** (header high bit clear): the header is the body length and
the body is one pickled Python object — always a tuple tagged with its
kind:

* ``("msg", Message)`` — a runtime :class:`~repro.core.transport.Message`
  (EVENT or CONTROL);
* ``("hello", rank)`` — connection preamble identifying the dialing peer;
* ``("hb",)`` — heartbeat (liveness only, never surfaced to the runtime);
* ``("bye",)`` — clean close: the peer is shutting down deliberately, so
  the subsequent EOF must *not* be reported as a failure.

**Batch frame** (header high bit set): the writer-side coalescing layer
packs *many* messages into one frame per syscall.  The body carries a
buffer table followed by the out-of-band buffers and the main pickle —
pickle protocol 5 with ``buffer_callback``, so numpy payloads (BFS
frontiers, MONC field slices) are serialised **zero-copy**: the array
bytes are never copied into the pickle stream; on the wire they travel as
scatter/gather segments, and on the read side they are reconstructed as
views over one mutable body buffer::

    header   = (len(body)) | BATCH_BIT                  # 4 bytes
    body     = nbufs (4B) | buflen_0 (8B) ... buflen_{n-1} (8B)
             | buf_0 ... buf_{n-1} | main_pickle

Decoded batch frames are ``("msgs", [obj, ...])``.

Frames from one sender are written by a single writer (per-connection lock
or dedicated writer thread) and read by a single reader thread, so
per-(src,dst) FIFO order is exactly the TCP byte order — for batch frames,
intra-batch order is list order.

Robustness contract (fuzz-tested by ``tests/test_net_frames.py``): a
truncated stream or mid-frame EOF decodes to ``None``; a garbage header
(length beyond :data:`MAX_FRAME`) or a corrupt body raises — decoders
never block forever on a complete-but-bad byte stream.
"""
from __future__ import annotations

import pickle
import socket
import struct
from typing import Any, List, Optional, Sequence

_LEN = struct.Struct(">I")
_NBUF = struct.Struct(">I")
_BUFLEN = struct.Struct(">Q")

#: refuse absurd frames (corruption guard), 1 GiB
MAX_FRAME = 1 << 30

#: high bit of the header word marks a batch frame (MAX_FRAME leaves the
#: top two bits of the 4-byte length free)
BATCH_BIT = 0x8000_0000

MSG = "msg"
MSGS = "msgs"            # decoded batch frames: ("msgs", [obj, ...])
HELLO = "hello"
HEARTBEAT = "hb"
BYE = "bye"

# elastic join (late processes re-hosting a dead process's ranks):
JOIN = "join"                  # ("join", lead, ranks, addr) -> coordinator
WELCOME = "welcome"            # ("welcome", {...}) coordinator's acceptance
NOJOIN = "nojoin"              # ("nojoin", reason): refused, retry later
PEER_JOINED = "peer_joined"    # ("peer_joined", lead, addr): dial newcomer


def encode(obj: Any) -> bytes:
    """Serialise ``obj`` into one length-prefixed plain frame."""
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    return _LEN.pack(len(data)) + data


def encode_batch(objs: Sequence[Any], oob: bool = True) -> List[Any]:
    """Serialise a sequence of objects into one batch frame, returned as a
    list of bytes-like pieces suitable for a vectored send.

    With ``oob=True`` the large buffers are pickled out-of-band: the
    returned pieces include *views* of the original payloads — zero-copy,
    so the caller must own the payloads (nobody mutates them before the
    send completes).  ``oob=False`` pickles everything in-band, producing a
    self-contained snapshot at the cost of one copy — the right mode when
    the firing task may still mutate the payload after ``fire`` returns.

    Falls back to in-band pickling for payloads whose buffers are not
    contiguous (``PickleBuffer.raw`` refuses those).
    """
    raws: List[Any] = []
    if oob:
        pbufs: List[pickle.PickleBuffer] = []
        try:
            main = pickle.dumps(list(objs), protocol=5,
                                buffer_callback=pbufs.append)
            raws = [pb.raw() for pb in pbufs]
        except Exception:
            # non-contiguous buffer or an exotic reducer: in-band pickle
            main = pickle.dumps(list(objs), protocol=pickle.HIGHEST_PROTOCOL)
            raws = []
    else:
        main = pickle.dumps(list(objs), protocol=pickle.HIGHEST_PROTOCOL)
    table = _NBUF.pack(len(raws)) + b"".join(
        _BUFLEN.pack(len(r)) for r in raws)
    body_len = len(table) + sum(len(r) for r in raws) + len(main)
    if body_len > MAX_FRAME:
        raise ValueError(f"batch frame of {body_len} bytes exceeds "
                         f"MAX_FRAME; split the batch")
    return [_LEN.pack(body_len | BATCH_BIT) + table, *raws, main]


def decode_batch_body(body) -> Any:
    """Decode a batch-frame body (without the 4-byte header) back into
    ``("msgs", [obj, ...])``.  ``body`` should be a *mutable* buffer
    (``bytearray``) so reconstructed numpy arrays are writable views.
    Raises ``ValueError`` on a corrupt buffer table."""
    mv = memoryview(body)
    n = len(mv)
    if n < _NBUF.size:
        raise ValueError("batch frame too short for buffer table")
    (nbufs,) = _NBUF.unpack_from(mv, 0)
    off = _NBUF.size
    if nbufs > (n - off) // _BUFLEN.size:
        raise ValueError(f"batch frame claims {nbufs} buffers, body too small")
    lens = []
    for _ in range(nbufs):
        (ln,) = _BUFLEN.unpack_from(mv, off)
        off += _BUFLEN.size
        lens.append(ln)
    bufs = []
    for ln in lens:
        if off + ln > n:
            raise ValueError("batch frame buffer overruns body")
        bufs.append(mv[off:off + ln])
        off += ln
    objs = pickle.loads(mv[off:], buffers=bufs)
    if not isinstance(objs, list):
        raise ValueError(f"batch frame decoded to {type(objs).__name__}, "
                         f"expected list")
    return (MSGS, objs)


def send_frame(sock: socket.socket, obj: Any) -> None:
    sock.sendall(encode(obj))


def decode_buffer(buf) -> "tuple[List[Any], int, bool]":
    """Incremental decoder over a receive buffer: decode every *complete*
    frame in ``buf`` and return ``(frames, consumed_bytes, corrupt)``.

    A partial trailing frame is simply left unconsumed (the caller appends
    more bytes and calls again); ``corrupt`` is True when the buffer holds
    a garbage header or an undecodable body — the caller must treat the
    connection as broken, after dispatching the frames decoded so far.
    Batch-frame bodies are sliced into fresh ``bytearray``\\ s, so their
    zero-copy numpy payloads stay valid (and writable) after the caller
    compacts ``buf``.
    """
    out: List[Any] = []
    off = 0
    total = len(buf)
    while True:
        if total - off < _LEN.size:
            return out, off, False
        (word,) = _LEN.unpack_from(buf, off)
        n = word & ~BATCH_BIT
        if n > MAX_FRAME:
            return out, off, True
        start = off + _LEN.size
        if total - start < n:
            return out, off, False
        body = bytearray(memoryview(buf)[start:start + n])
        off = start + n
        try:
            if word & BATCH_BIT:
                out.append(decode_batch_body(body))
            else:
                out.append(pickle.loads(body))
        except Exception:
            return out, off, True


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytearray]:
    """Read exactly ``n`` bytes; None on EOF (including mid-frame EOF)."""
    buf = bytearray(n)
    mv = memoryview(buf)
    got = 0
    while got < n:
        try:
            k = sock.recv_into(mv[got:])
        except OSError:
            return None
        if not k:
            return None
        got += k
    return buf


def recv_frame(sock: socket.socket) -> Optional[Any]:
    """Read one frame (plain or batch); None on EOF."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (word,) = _LEN.unpack(head)
    n = word & ~BATCH_BIT
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME")
    body = _recv_exact(sock, n)
    if body is None:
        return None
    if word & BATCH_BIT:
        return decode_batch_body(body)
    return pickle.loads(body)


def _readinto_exact(f, buf) -> bool:
    """Fill ``buf`` completely from a buffered reader; False on EOF."""
    mv = memoryview(buf)
    got = 0
    while got < len(buf):
        k = f.readinto(mv[got:])
        if not k:
            return False
        got += k
    return True


def recv_frame_buffered(f) -> Optional[Any]:
    """Like :func:`recv_frame` but over a buffered binary file object
    (``sock.makefile("rb")``) — a burst of small frames costs one syscall,
    not two per frame.  Batch-frame bodies are read into one mutable
    buffer, so zero-copy numpy payloads decode to *writable* array views
    of it."""
    head = bytearray(_LEN.size)
    if not _readinto_exact(f, head):
        return None
    (word,) = _LEN.unpack(head)
    n = word & ~BATCH_BIT
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME")
    body = bytearray(n)
    if not _readinto_exact(f, body):
        return None
    if word & BATCH_BIT:
        return decode_batch_body(body)
    return pickle.loads(body)
