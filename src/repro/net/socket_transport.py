"""TCP socket transport: EDAT ranks as separate OS processes (paper §II.F).

Implements the full :class:`~repro.core.transport.Transport` contract over
stream sockets with length-prefixed pickled frames (:mod:`repro.net.frames`):

* **Placement** — one transport instance serves *all* the ranks of one OS
  process (``local_ranks``); ``placement`` maps every process (identified
  by its lowest hosted rank, the *lead*) to the ranks it hosts.  There is
  exactly **one TCP connection per unordered process pair** — co-located
  ranks share it — and events between co-located ranks never touch a
  socket at all: they take the loopback path straight into the
  destination rank's inbox (verified by the ``wire_*`` counters below).
  The default placement (no ``local_ranks``/``placement``) is the classic
  one-rank-per-process world, fully backward compatible.
* **FIFO** — each process-pair connection is written by exactly one
  writer (the per-process writer thread when coalescing, a per-connection
  lock otherwise) and read by one reader thread, so per-(src,dst)
  delivery order is exactly TCP byte order.  Loopback sends append
  atomically per destination inbox.
* **Coalescing** — the default fast path: ``send``/``send_many`` only
  *enqueue* onto a per-process send queue; a per-process writer thread
  drains the queue and packs many events into **one batch frame per
  syscall** (:func:`frames.encode_batch`, vectored ``sendmsg``) — events
  for different co-located destination ranks share batch frames.  While
  the writer is inside a syscall new sends pile up behind it, so batch
  size adapts to load with no added latency.  Knobs: ``flush_interval``
  (wait this long after the first queued message for a batch to
  accumulate; default 0 — purely opportunistic batching) and
  ``max_batch_bytes`` (approximate cap on one encoded batch; larger
  queues split into multiple frames).  ``coalesce=False`` restores the
  synchronous one-frame-per-send path.
* **Snapshots vs zero-copy** — fire-and-forget requires the payload to be
  snapshotted at fire time.  Ordinary messages are therefore batch-encoded
  *in-band, synchronously inside send* (the pickle is the snapshot; the
  writer thread only does syscalls).  Messages whose payload ownership was
  handed over (``Message.owned``, set by the runtime for ``ref=True``
  fires — the paper's ``EDAT_ADDRESS``) skip the fire-time pickle
  entirely: the writer thread encodes them with pickle protocol-5
  out-of-band buffers, so numpy payloads (BFS frontiers, MONC field
  slices, gradient trees) go from the firing task's buffer to the socket
  **zero-copy**.
* **Notification** — ``set_notify`` wakes an idle worker on arrival
  (worker-progress mode), exactly like the in-proc transport, per rank.
* **Failure detection** — every connection carries heartbeats; a peer
  process that goes silent past ``hb_timeout`` (or whose connection breaks
  without a clean BYE) is declared dead **with every rank it hosts**:
  ``on_peer_dead`` fires once per hosted rank, which the runtime wires to
  its ``RANK_FAILED`` machinery — survivors see one failure event per
  lost rank, exactly like ``kill_rank``.  Sends to dead ranks are dropped
  and counted, mirroring ``InProcTransport``.
* **Termination accounting** — per-peer ``sent_to``/``recv_from`` vectors
  (user events only; sent counts at *enqueue*, before the wire write, and
  received counts when a message is *popped* for delivery, so queued and
  in-flight events always read as in-flight).  The Mattern detector
  balances these across processes, restricted to alive ranks.  The
  parallel ``wire_sent_to``/``wire_recv_from`` vectors count only events
  that crossed (or will cross) a socket — co-located traffic never shows
  up there, which the placement tests assert.  When a peer process dies,
  every queued-but-unwritten user event to it is counted in ``dropped``
  exactly once: the send queue is drained under its condition variable
  with a dead flag raised first, so a send racing the death verdict is
  counted as dropped at enqueue instead of lingering unwritten (which
  would stall the detector to timeout).  The same accounting feeds the
  observability layer: :meth:`metrics` reports per-peer wire bytes,
  write batches, and the send-queue high-water mark alongside the
  wire/loopback event totals, so ``Session.stats()`` can show where the
  bytes went without any extra bookkeeping on the hot path.

Payloads must be picklable; :meth:`validate_payload` enforces this at
``ctx.fire()`` time so the error surfaces in the firing task.

Construction is normally via :func:`repro.net.bootstrap.bootstrap` (or
``bootstrap_from_env``); tests may wire transports directly from
``socket.socketpair()`` ends.
"""
from __future__ import annotations

import pickle
import socket
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.transport import EVENT, Message, Transport

from . import frames

#: quickly-validatable payload leaf types (exact types, not subclasses:
#: a subclass can carry arbitrary unpicklable state — see validate_payload)
_PLAIN = frozenset((type(None), bool, int, float, complex, str, bytes,
                    bytearray))

#: deeply-immutable payload types: a fire-time snapshot is pointless (the
#: firing task cannot mutate them), so they take the deferred-encode path
#: even without ``Message.owned`` — the writer thread packs whole runs of
#: them into one batch frame / one pickle.  Exact types only: an int
#: *subclass* may hold mutable (or unpicklable) attribute state.
_IMMUTABLE = frozenset((type(None), bool, int, float, complex, str, bytes))


class SocketTransport(Transport):
    """Transport for one process's ranks over per-process-pair sockets."""

    distributed = True
    serializes = True

    def __init__(self, rank: int, n_ranks: int,
                 peers: Dict[int, socket.socket], *,
                 local_ranks: Optional[Sequence[int]] = None,
                 placement: Optional[Dict[int, Sequence[int]]] = None,
                 hb_interval: float = 0.5, hb_timeout: float = 5.0,
                 coalesce: bool = True, flush_interval: float = 0.0,
                 max_batch_bytes: int = 1 << 20,
                 dead_procs: Optional[Sequence[int]] = None):
        local = tuple(sorted(set(local_ranks))) if local_ranks else (rank,)
        assert rank in local, f"rank {rank} not in local_ranks {local}"
        if placement is None:
            placement = {local[0]: local}
            placement.update({r: (r,) for r in range(n_ranks)
                              if r not in local})
        self.placement: Dict[int, Tuple[int, ...]] = {
            int(l): tuple(sorted(int(r) for r in rs))
            for l, rs in placement.items()}
        covered = sorted(r for rs in self.placement.values() for r in rs)
        assert covered == list(range(n_ranks)), \
            f"placement {self.placement} does not partition 0..{n_ranks - 1}"
        assert all(l == rs[0] for l, rs in self.placement.items()), \
            "each process must be keyed by its lowest (lead) rank"
        assert self.placement[local[0]] == local
        self.rank = local[0]          # lead local rank
        self.n_ranks = n_ranks
        self.local_ranks = local
        self._proc_of = {r: l for l, rs in self.placement.items()
                         for r in rs}
        remote = set(self.placement) - {self.rank}
        # a transport built by an elastically-joining process starts with
        # some peer processes already dead (no socket to hand over); their
        # per-peer state exists so a later add_peer can splice them in
        dead_set = {int(p) for p in (dead_procs or ())}
        assert dead_set <= remote, \
            f"dead_procs {sorted(dead_set)} not all remote {sorted(remote)}"
        assert set(peers) == remote - dead_set, \
            (f"process {self.rank}{local}: need one socket per peer "
             f"process {sorted(remote - dead_set)}, got {sorted(peers)}")
        self._peers = peers
        self._send_mu = {p: threading.Lock() for p in remote}
        #: per-local-rank inboxes (pull mode) and their condition variables
        self._inbox: Dict[int, deque] = {r: deque() for r in local}
        self._cv = {r: threading.Condition() for r in local}
        self._notify: Dict[int, Optional[Callable[[], None]]] = \
            {r: None for r in local}
        #: callback(rank) invoked (outside locks) when a peer rank is
        #: declared dead by the heartbeat/EOF detector — once per rank the
        #: failed process hosted; set by the Runtime
        self.on_peer_dead: Optional[Callable[[int], None]] = None
        #: callback(rank) invoked (outside locks) when a replacement
        #: process re-hosting a dead peer's ranks is spliced in via
        #: :meth:`add_peer` — once per revived rank; set by the Runtime
        self.on_peer_join: Optional[Callable[[int], None]] = None
        #: push-mode delivery: when the runtime registers this callback the
        #: reader threads hand message batches straight to it, skipping the
        #: inbox and the progress-thread wakeup hop (one fewer context
        #: switch per message on the latency path).  Batches may mix
        #: destination ranks; the runtime routes by ``Message.dst``.
        self._deliver: Optional[Callable[[List[Message]], None]] = None
        self._dmu = threading.Lock()   # guards the _deliver handover

        self._mu = threading.Lock()
        self._dead = [False] * n_ranks
        for p in dead_set:
            for r in self.placement[p]:
                self._dead[r] = True
        self._sock_dead = {p: p in dead_set for p in remote}  # per process
        self._bye = set()          # peer processes that closed cleanly
        self._dropped = 0
        self._sent_to = [0] * n_ranks     # user events enqueued per dst
        self._recv_from = [0] * n_ranks   # user events popped per src
        #: socket-only counterparts: co-located (loopback) traffic never
        #: appears here — the placement tests assert exactly that
        self._wire_sent_to = [0] * n_ranks
        self._wire_recv_from = [0] * n_ranks
        self._last_seen = {p: time.monotonic() for p in remote}
        self._closing = False
        self._close_started = False
        self._splicing = set()     # peer procs with an add_peer in flight

        # writer-side coalescing state (one queue + writer thread per peer
        # process — co-located destinations share batch frames)
        self.coalesce = bool(coalesce)
        self.flush_interval = flush_interval
        self.max_batch_bytes = int(max_batch_bytes)
        self._sendq: Dict[int, deque] = {p: deque() for p in remote}
        self._sendcv = {p: threading.Condition() for p in remote}
        self._wbusy = {p: False for p in remote}  # writer mid-write
        #: set (under the peer's send condvar) when the peer's queue was
        #: dropped on death: an enqueue that raced the verdict counts its
        #: events dropped instead of queueing them forever-unwritten
        self._q_dead = {p: p in dead_set for p in remote}
        # per-peer wire-level observability (bytes handed to the kernel,
        # write batches, send-queue high-water mark)
        self._m_wire_bytes = {p: 0 for p in remote}
        self._m_writes = {p: 0 for p in remote}
        self._m_sendq_max = {p: 0 for p in remote}

        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout
        self._threads: List[threading.Thread] = []
        #: live reader/writer threads per peer process — add_peer joins a
        #: dead peer's old threads before spawning replacements, so one
        #: connection never has two writers interleaving frame pieces
        self._peer_threads: Dict[int, List[threading.Thread]] = \
            {p: [] for p in remote}
        for p in peers:
            t = threading.Thread(target=self._reader, args=(p,), daemon=True,
                                 name=f"edat-net-r{self.rank}<{p}")
            self._threads.append(t)
            self._peer_threads[p].append(t)
            t.start()
        if self.coalesce:
            for p in peers:
                t = threading.Thread(target=self._writer, args=(p,),
                                     daemon=True,
                                     name=f"edat-net-w{self.rank}>{p}")
                self._threads.append(t)
                self._peer_threads[p].append(t)
                t.start()
        self._hb_stop = threading.Event()
        if hb_interval > 0 and remote:
            t = threading.Thread(target=self._heartbeat_loop, daemon=True,
                                 name=f"edat-net-hb{self.rank}")
            self._threads.append(t)
            t.start()

    # ------------------------------------------------------- local delivery
    def _deliver_local(self, msgs: List[Message], *,
                       from_wire: bool = False) -> None:
        """Hand ``msgs`` (any mix of local destination ranks) to push-mode
        delivery or the per-rank inboxes.  Messages for a locally-dead
        destination are dropped (their events die with the rank)."""
        live: List[Message] = []
        n_dead = 0
        for m in msgs:
            if m.dst in self._inbox and not self._dead[m.dst]:
                live.append(m)
            elif m.kind == EVENT:
                n_dead += 1
        if n_dead:
            with self._mu:
                self._dropped += n_dead
        if not live:
            return
        if from_wire:
            with self._mu:
                for m in live:
                    if m.kind == EVENT:
                        self._wire_recv_from[m.src] += 1
        with self._dmu:
            push = self._deliver
            if push is None:
                by_dst: Dict[int, List[Message]] = {}
                for m in live:
                    by_dst.setdefault(m.dst, []).append(m)
                for r, ms in by_dst.items():
                    with self._cv[r]:
                        self._inbox[r].extend(ms)
                        self._cv[r].notify()
        if push is not None:
            # deliver BEFORE counting: recv_from must never include an
            # event the scheduler has not seen, or the detector could
            # observe balanced counters + idle schedulers while the event
            # sits on a descheduled reader (rcv < sent in the gap is the
            # safe direction — it only delays a poll)
            push(live)
            self._count_popped(live)
        else:
            for r in {m.dst for m in live}:
                hook = self._notify.get(r)
                if hook is not None:
                    hook()  # outside inbox locks (may take sched locks)

    # ---------------------------------------------------------- reader side
    def _reader(self, peer: int) -> None:
        """Per-peer-process reader: one blocking ``recv`` per burst, then
        decode *every* complete frame already buffered and hand the whole
        run of messages (any mix of co-located destination ranks) to the
        scheduler in one delivery — the receive-side mirror of the
        writer's coalescing."""
        sock = self._peers[peer]
        buf = bytearray()
        while True:
            try:
                data = sock.recv(1 << 16)
            except OSError:
                data = b""
            eof = not data
            if data:
                buf += data
                with self._mu:
                    self._last_seen[peer] = time.monotonic()
            decoded, used, corrupt = frames.decode_buffer(buf)
            if used:
                del buf[:used]
            msgs: List[Message] = []
            for frame in decoded:
                kind = frame[0]
                if kind == frames.MSGS:
                    msgs.extend(frame[1])
                elif kind == frames.MSG:
                    msgs.append(frame[1])
                elif kind == frames.BYE:
                    with self._mu:
                        self._bye.add(peer)
                    # keep reading until EOF so late frames cannot be lost
                elif kind == frames.PEER_JOINED:
                    # the coordinator announced an elastic rejoin: dial the
                    # replacement off-thread (the dial blocks) and splice
                    # it in via add_peer when the HELLO lands
                    _, j_lead, j_addr = frame
                    threading.Thread(
                        target=self.dial_peer,
                        args=(int(j_lead), (str(j_addr[0]), int(j_addr[1]))),
                        daemon=True,
                        name=f"edat-net-join{self.rank}>{j_lead}").start()
                # HEARTBEAT: nothing beyond the last_seen update above
            if msgs:
                self._deliver_local(msgs, from_wire=True)
            if eof or corrupt:
                with self._mu:
                    clean = self._closing
                if not clean:
                    self._declare_proc_dead(peer)  # silent after a BYE
                return

    def _heartbeat_loop(self) -> None:
        beat = frames.encode((frames.HEARTBEAT,))
        while not self._hb_stop.wait(self._hb_interval):
            now = time.monotonic()
            for p in list(self._peers):
                with self._mu:
                    if self._sock_dead[p] or p in self._bye or self._closing:
                        continue
                    stale = now - self._last_seen[p] > self._hb_timeout
                if stale:
                    self._declare_proc_dead(p)
                    continue
                if self.coalesce:
                    self._enqueue(p, [("enc", [beat], 0)])
                    continue
                try:
                    with self._send_mu[p]:
                        self._peers[p].sendall(beat)
                except OSError:
                    self._declare_proc_dead(p)

    @staticmethod
    def _teardown(sock: socket.socket) -> None:
        """Force-close: shutdown reaches the peer (and unblocks our reader)
        even while a buffered makefile still holds the fd refcount."""
        try:
            sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass

    def _declare_proc_dead(self, peer: int) -> None:
        """Failure detector verdict on a peer *process*: mark every rank it
        hosts dead, close the connection, notify the runtime once per lost
        rank.  A process that already said BYE is marked dead *silently* —
        a broken connection after a clean goodbye is shutdown skew, not a
        failure."""
        with self._mu:
            if self._sock_dead[peer] or self._closing:
                return
            self._sock_dead[peer] = True
            was_clean = peer in self._bye
            newly = [r for r in self.placement[peer] if not self._dead[r]]
            for r in newly:
                self._dead[r] = True
        self._teardown(self._peers[peer])
        self._drop_queue(peer)  # queued-but-unwritten sends die with it
        for r in self.local_ranks:
            self.wake(r)  # a blocked recv should re-check the world
        cb = self.on_peer_dead
        if cb is not None and not was_clean:
            for r in newly:
                cb(r)

    # ----------------------------------------------------- coalescing writer
    def _enqueue(self, proc: int, items: List) -> None:
        """Append items to peer process ``proc``'s send queue in one lock
        round-trip.  Items are either a :class:`Message` (owned payload;
        the writer encodes it late with out-of-band buffers) or ``("enc",
        pieces, n_events)`` (a pre-encoded snapshot frame).

        If the peer died and its queue was already dropped, the items are
        counted as dropped *here* instead of being queued: the lock-free
        dead check in ``send`` can race the death verdict, and an event
        parked on a dead queue would otherwise be counted neither sent-on
        nor dropped — unbalancing the termination accounting."""
        cv = self._sendcv[proc]
        with cv:
            if not self._q_dead[proc]:
                q = self._sendq[proc]
                q.extend(items)
                if len(q) > self._m_sendq_max[proc]:
                    self._m_sendq_max[proc] = len(q)
                cv.notify_all()
                return
        self._count_items_dropped(items)

    def _count_items_dropped(self, items) -> None:
        """Account queue items that will never reach the wire."""
        n = 0
        for it in items:
            if isinstance(it, Message):
                n += 1 if it.kind == EVENT else 0
            else:
                n += it[2]
        if n:
            with self._mu:
                self._dropped += n

    def _drop_queue(self, proc: int) -> None:
        """Discard ``proc``'s queued sends, counting user events dropped.
        Raises the queue's dead flag under the condvar first, so any
        concurrent ``_enqueue`` either lands before the drain (counted
        here) or observes the flag and counts itself — every discarded
        event is accounted exactly once either way."""
        cv = self._sendcv.get(proc)
        if cv is None:
            return
        with cv:
            self._q_dead[proc] = True
            items = list(self._sendq[proc])
            self._sendq[proc].clear()
            cv.notify_all()
        self._count_items_dropped(items)

    @staticmethod
    def _rough_nbytes(msg: Message) -> int:
        """Cheap size estimate used to split oversized write batches."""
        data = getattr(msg.payload, "data", msg.payload)
        n = 512
        if isinstance(data, np.ndarray):
            n += data.nbytes
        elif isinstance(data, dict):
            for v in data.values():
                n += v.nbytes if isinstance(v, np.ndarray) else 64
        elif isinstance(data, (list, tuple)):
            for v in data:
                n += v.nbytes if isinstance(v, np.ndarray) else 64
        return n

    def _writer(self, peer: int) -> None:
        """Per-peer-process writer thread: drain the send queue, pack runs
        of owned messages into batch frames (protocol-5 out-of-band
        buffers), and push everything to the kernel with one vectored
        send."""
        sock = self._peers[peer]
        q = self._sendq[peer]
        cv = self._sendcv[peer]
        while True:
            with cv:
                while not q:
                    if self._sock_dead[peer] or self._closing:
                        return
                    cv.wait()
                if self.flush_interval > 0:
                    # let a batch accumulate behind the first message; loop
                    # on a deadline — every enqueue notifies the condvar,
                    # so a single timed wait would return after one message
                    end = time.monotonic() + self.flush_interval
                    while not self._sock_dead[peer] and not self._closing:
                        left = end - time.monotonic()
                        if left <= 0:
                            break
                        cv.wait(left)
                items = list(q)
                q.clear()
                self._wbusy[peer] = True
            try:
                if self._sock_dead[peer]:
                    # popped concurrently with the death verdict:
                    # _drop_queue saw an empty queue, so count these here
                    self._count_items_dropped(items)
                    return
                try:
                    self._write_items(peer, sock, items)
                except OSError:
                    with self._mu:
                        closing = self._closing
                    if not closing:
                        self._declare_proc_dead(peer)
                    # like the synchronous path, the whole failed write
                    # counts as dropped (some bytes may have made it out,
                    # but the peer is gone either way)
                    self._count_items_dropped(items)
                    return
            finally:
                with cv:
                    self._wbusy[peer] = False
                    cv.notify_all()

    def _write_items(self, peer: int, sock: socket.socket,
                     items: List) -> None:
        pieces: List = []
        run: List[Message] = []
        run_bytes = 0

        def flush_run():
            nonlocal run_bytes
            if not run:
                return
            try:
                pieces.extend(frames.encode_batch(run, oob=True))
            except Exception:
                # an unpicklable slipped past validate_payload: salvage the
                # rest of the run, drop (and count) the poison messages
                for m in run:
                    try:
                        pieces.extend(frames.encode_batch([m], oob=False))
                    except Exception:
                        if m.kind == EVENT:
                            with self._mu:
                                self._dropped += 1
            run.clear()
            run_bytes = 0

        for it in items:
            if isinstance(it, Message):
                run.append(it)
                run_bytes += self._rough_nbytes(it)
                if run_bytes >= self.max_batch_bytes:
                    flush_run()
            else:
                flush_run()
                pieces.extend(it[1])
        flush_run()
        nbytes = 0
        for p in pieces:
            nbytes += len(p) if isinstance(p, (bytes, bytearray)) \
                else memoryview(p).nbytes
        self._sendall_vec(sock, pieces)
        with self._mu:
            self._m_wire_bytes[peer] += nbytes
            self._m_writes[peer] += 1

    @staticmethod
    def _sendall_vec(sock: socket.socket, pieces: List) -> None:
        """Write every piece, scatter/gather where the OS supports it."""
        views = []
        for p in pieces:
            mv = p if isinstance(p, memoryview) else memoryview(p)
            if mv.ndim != 1 or mv.format != "B":
                mv = mv.cast("B")
            if len(mv):
                views.append(mv)
        if not views:
            return
        if not hasattr(sock, "sendmsg"):  # pragma: no cover - posix only
            sock.sendall(b"".join(views))
            return
        i = 0
        while i < len(views):
            sent = sock.sendmsg(views[i:i + 64])
            while sent > 0:
                v = views[i]
                if sent >= len(v):
                    sent -= len(v)
                    i += 1
                else:
                    views[i] = v[sent:]
                    sent = 0

    def flush(self, timeout: Optional[float] = 5.0) -> bool:
        """Block until every peer process's send queue has drained to the
        kernel (or ``timeout`` expires).  Returns True when fully flushed.
        Only meaningful with coalescing; a no-op (True) otherwise."""
        if not self.coalesce:
            return True
        deadline = time.monotonic() + (timeout if timeout is not None
                                       else 1e9)
        ok = True
        for p, cv in self._sendcv.items():
            with cv:
                while ((self._sendq[p] or self._wbusy[p])
                       and not self._sock_dead[p]):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        ok = False
                        break
                    cv.wait(min(left, 0.05))
        return ok

    # ---------------------------------------------------------- send side
    def validate_payload(self, data) -> None:
        if self._quick_picklable(data):
            return
        try:
            pickle.dumps(data, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception as e:
            raise TypeError(
                f"event payload of type {type(data).__name__!r} is not "
                f"picklable, which SocketTransport requires to cross "
                f"process boundaries: {e}") from e

    @classmethod
    def _quick_picklable(cls, data, depth: int = 0) -> bool:
        """Structural fast path for the common payload shapes (numbers,
        strings, numpy arrays, shallow containers of those) so fire-time
        validation does not pickle a large array twice.  Exact-type checks
        only: a subclass (e.g. a defaultdict with a lambda factory) may
        carry unpicklable state, so anything this cannot *prove* falls
        back to a real ``pickle.dumps`` probe."""
        t = type(data)
        if t in _PLAIN:
            return True
        if t is np.ndarray or isinstance(data, np.generic):
            # hasobject also catches structured dtypes with object fields,
            # which a plain `dtype != object` comparison lets through
            return not data.dtype.hasobject
        if depth >= 3:
            return False
        if t in (list, tuple, set, frozenset):
            return all(cls._quick_picklable(v, depth + 1) for v in data)
        if t is dict:
            return all(cls._quick_picklable(k, depth + 1)
                       and cls._quick_picklable(v, depth + 1)
                       for k, v in data.items())
        return False

    @staticmethod
    def _late_encodable(msg: Message) -> bool:
        """True when the writer thread may serialise ``msg`` lazily: the
        payload was handed over (``owned``) or is deeply immutable, so no
        fire-time snapshot is required."""
        if getattr(msg, "owned", False):
            return True
        return (msg.kind == EVENT
                and type(msg.payload.data) in _IMMUTABLE)

    def _encode_msg(self, msg: Message) -> bytes:
        try:
            return frames.encode((frames.MSG, msg))
        except Exception as e:
            raise TypeError(
                f"message to rank {msg.dst} (eid "
                f"{getattr(msg.payload, 'eid', msg.payload)!r}) cannot be "
                f"pickled for SocketTransport: {e}") from e

    def _encode_snapshot(self, msgs: List[Message]) -> List:
        """Fire-time snapshot of a batch: one in-band batch frame."""
        try:
            return frames.encode_batch(msgs, oob=False)
        except Exception as e:
            m = msgs[0]
            raise TypeError(
                f"message to rank {m.dst} (eid "
                f"{getattr(m.payload, 'eid', m.payload)!r}) cannot be "
                f"pickled for SocketTransport: {e}") from e

    def set_deliver(self, fn: Callable[[List[Message]], None]) -> None:
        """Enable push-mode delivery (used by the Runtime): the reader
        threads call ``fn(batch)`` directly instead of queueing into the
        per-rank inboxes.  Batches may mix co-located destination ranks;
        the runtime routes by ``Message.dst``.  Messages that arrived
        before registration are flushed to ``fn`` under the handover lock,
        so per-(src,dst) FIFO order survives the handover."""
        with self._dmu:
            backlog: List[Message] = []
            for r in self.local_ranks:
                with self._cv[r]:
                    backlog.extend(self._inbox[r])
                    self._inbox[r].clear()
            if backlog:
                fn(backlog)  # deliver-then-count, as in the reader path
                self._count_popped(backlog)
            self._deliver = fn

    def _loopback(self, msgs: List[Message]) -> None:
        """Co-located delivery: no socket, no serialisation — events go
        straight to the destination rank's inbox / push delivery."""
        with self._mu:
            for m in msgs:
                if m.kind == EVENT:
                    self._sent_to[m.dst] += 1
        self._deliver_local(msgs)

    def _queue_remote(self, proc: int, ms: List[Message]) -> None:
        """Coalescing enqueue of ``ms`` (same destination process) with
        the snapshot/late-encode split applied per message run."""
        items: List = []
        snap: List[Message] = []
        snap_ev = 0
        for m in ms:
            if self._late_encodable(m):
                if snap:
                    items.append(("enc", self._encode_snapshot(snap),
                                  snap_ev))
                    snap, snap_ev = [], 0
                items.append(m)
            else:
                snap.append(m)
                snap_ev += 1 if m.kind == EVENT else 0
        if snap:
            items.append(("enc", self._encode_snapshot(snap), snap_ev))
        self._enqueue(proc, items)

    def send(self, msg: Message) -> bool:
        dst = msg.dst
        if dst in self._inbox:            # co-located (including self)
            if self._dead[dst]:
                with self._mu:
                    self._dropped += 1
                return False
            self._loopback([msg])
            return True
        if self._dead[dst]:
            with self._mu:
                self._dropped += 1
            return False
        proc = self._proc_of[dst]
        if self.coalesce:
            if msg.kind == EVENT:
                with self._mu:
                    self._sent_to[dst] += 1
                    self._wire_sent_to[dst] += 1
            if self._late_encodable(msg):
                self._enqueue(proc, [msg])
            else:
                self._enqueue(proc, [("enc", self._encode_snapshot([msg]),
                                     1 if msg.kind == EVENT else 0)])
            return True
        data = self._encode_msg(msg)
        try:
            with self._send_mu[proc]:
                self._peers[proc].sendall(data)
        except OSError:
            self._declare_proc_dead(proc)
            with self._mu:
                self._dropped += 1
            return False
        with self._mu:
            self._m_wire_bytes[proc] += len(data)
            self._m_writes[proc] += 1
            if msg.kind == EVENT:
                self._sent_to[dst] += 1
                self._wire_sent_to[dst] += 1
        return True

    def send_many(self, msgs: List[Message]) -> int:
        local: Dict[int, List[Message]] = {}
        remote: Dict[int, List[Message]] = {}   # peer process -> messages
        n_dead = 0
        for m in msgs:
            if m.dst in self._inbox:
                if self._dead[m.dst]:
                    n_dead += 1
                else:
                    local.setdefault(m.dst, []).append(m)
            elif self._dead[m.dst]:
                n_dead += 1
            else:
                remote.setdefault(self._proc_of[m.dst], []).append(m)
        if n_dead:
            with self._mu:
                self._dropped += n_dead
        delivered = 0
        for dst, ms in local.items():
            self._loopback(ms)
            delivered += len(ms)
        for proc, ms in remote.items():
            if self.coalesce:
                with self._mu:
                    for m in ms:
                        if m.kind == EVENT:
                            self._sent_to[m.dst] += 1
                            self._wire_sent_to[m.dst] += 1
                self._queue_remote(proc, ms)
                delivered += len(ms)
                continue
            blob = b"".join(self._encode_msg(m) for m in ms)
            try:
                with self._send_mu[proc]:
                    self._peers[proc].sendall(blob)
            except OSError:
                self._declare_proc_dead(proc)
                with self._mu:
                    self._dropped += len(ms)
                continue
            with self._mu:
                self._m_wire_bytes[proc] += len(blob)
                self._m_writes[proc] += 1
                for m in ms:
                    if m.kind == EVENT:
                        self._sent_to[m.dst] += 1
                        self._wire_sent_to[m.dst] += 1
            delivered += len(ms)
        return delivered

    # --------------------------------------------------------- receive side
    def _count_popped(self, msgs) -> None:
        # pop-based receives count here, at the moment the caller takes
        # ownership; a Runtime always runs this transport in push mode,
        # where counting happens strictly *after* scheduler delivery
        with self._mu:
            for m in msgs:
                if m.kind == EVENT:
                    self._recv_from[m.src] += 1

    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        assert rank in self._inbox
        with self._cv[rank]:
            if not self._inbox[rank]:
                self._cv[rank].wait(timeout)
            if not self._inbox[rank]:
                return None
            msg = self._inbox[rank].popleft()
        self._count_popped((msg,))
        return msg

    def recv_many(self, rank: int,
                  timeout: Optional[float]) -> List[Message]:
        assert rank in self._inbox
        with self._cv[rank]:
            if not self._inbox[rank]:
                self._cv[rank].wait(timeout)
            out = list(self._inbox[rank])
            self._inbox[rank].clear()
        self._count_popped(out)
        return out

    def drain(self, rank: int, max_n: Optional[int] = None) -> List[Message]:
        assert rank in self._inbox
        with self._cv[rank]:
            box = self._inbox[rank]
            if not box:
                return []
            if max_n is None or max_n >= len(box):
                out = list(box)
                box.clear()
            else:
                out = [box.popleft() for _ in range(max_n)]
        self._count_popped(out)
        return out

    def wake(self, rank: int) -> None:
        cv = self._cv.get(rank)
        if cv is None:
            return
        with cv:
            cv.notify_all()

    def set_notify(self, rank: int,
                   fn: Optional[Callable[[], None]]) -> None:
        assert rank in self._inbox
        self._notify[rank] = fn

    # ------------------------------------------------------- failure / info
    def is_dead(self, rank: int) -> bool:
        return self._dead[rank]

    def mark_dead(self, rank: int) -> None:
        """Local failure injection (``kill_rank`` parity): stop sending to
        ``rank`` without invoking the peer-death callback — the caller is
        responsible for its own RANK_FAILED notification.  A remote
        process's connection is only severed once *every* rank it hosts
        has been marked dead (co-located survivors keep using it); a local
        rank's inbox is cleared, its queued events counted as dropped."""
        with self._mu:
            if self._dead[rank]:
                return
            self._dead[rank] = True
        if rank in self._inbox:
            with self._cv[rank]:
                n = sum(1 for m in self._inbox[rank] if m.kind == EVENT)
                self._inbox[rank].clear()
                self._cv[rank].notify_all()
            if n:
                with self._mu:
                    self._dropped += n
            return
        proc = self._proc_of[rank]
        with self._mu:
            sever = (not self._sock_dead[proc]
                     and all(self._dead[r] for r in self.placement[proc]))
            if sever:
                self._sock_dead[proc] = True
        if sever:
            self._teardown(self._peers[proc])  # plain close() would leave
            # the reader's fd alive and keep delivering dead-rank events
            self._drop_queue(proc)

    # --------------------------------------------------------- elastic join
    def add_peer(self, lead: int, sock: socket.socket) -> bool:
        """Splice a replacement process's connection into the live mesh.

        ``lead`` must be the lead rank of a placement entry whose ranks
        are ALL currently dead (the replacement re-hosts exactly the dead
        process's ranks, so the placement never changes shape).  Sequence
        matters: the dead peer's old reader/writer threads are joined
        first (two writers on one socket would interleave frame pieces),
        queue state is reset before the new writer starts (it checks the
        dead flags), counters for the re-hosted ranks are zeroed (the new
        incarnation starts from zero, and the termination balance must be
        computed against *its* traffic), and only then are the ranks
        marked alive — a send observing ``_dead[r] == False`` must find a
        working queue behind it.  Returns False (closing ``sock``) when
        the splice is not applicable."""
        ranks = self.placement.get(lead)
        with self._mu:
            ok = (ranks is not None and lead != self.rank
                  and not self._closing and lead not in self._splicing
                  and self._sock_dead.get(lead, False)
                  and all(self._dead[r] for r in ranks))
            if ok:
                self._splicing.add(lead)   # claim: one splice at a time
        if not ok:
            self._teardown(sock)
            return False
        try:
            for t in self._peer_threads[lead]:
                t.join(5.0)
                if t.is_alive():           # wedged old thread: abort
                    self._teardown(sock)
                    return False
            self._peer_threads[lead] = []
            with self._sendcv[lead]:
                self._sendq[lead].clear()
                self._q_dead[lead] = False
                self._wbusy[lead] = False
            with self._mu:
                self._peers[lead] = sock
                self._sock_dead[lead] = False
                self._bye.discard(lead)
                self._last_seen[lead] = time.monotonic()
                for r in ranks:
                    self._sent_to[r] = 0
                    self._recv_from[r] = 0
                    self._wire_sent_to[r] = 0
                    self._wire_recv_from[r] = 0
            news = [threading.Thread(target=self._reader, args=(lead,),
                                     daemon=True,
                                     name=f"edat-net-r{self.rank}<{lead}")]
            if self.coalesce:
                news.append(threading.Thread(
                    target=self._writer, args=(lead,), daemon=True,
                    name=f"edat-net-w{self.rank}>{lead}"))
            self._peer_threads[lead] = news
            self._threads.extend(news)
            for t in news:
                t.start()
            with self._mu:
                for r in ranks:
                    self._dead[r] = False
        finally:
            with self._mu:
                self._splicing.discard(lead)
        cb = self.on_peer_join
        if cb is not None:
            for r in ranks:
                cb(r)
        for r in self.local_ranks:
            self.wake(r)   # blocked receivers should re-check the world
        return True

    def dial_peer(self, lead: int, addr: Tuple[str, int],
                  timeout: float = 10.0) -> bool:
        """Dial a just-announced replacement process, identify ourselves
        with a HELLO, and splice the connection in via :meth:`add_peer`."""
        try:
            s = socket.create_connection(addr, timeout=timeout)
            frames.send_frame(s, (frames.HELLO, self.rank))
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            s.settimeout(None)
        except OSError:
            return False
        return self.add_peer(lead, s)

    def announce_join(self, lead: int, addr: Tuple[str, int]) -> None:
        """Broadcast ``PEER_JOINED`` to every live peer process: each one
        dials the newcomer at ``addr`` and splices it in (the coordinator
        calls this after accepting an elastic JOIN)."""
        frame = frames.encode((frames.PEER_JOINED, lead, tuple(addr)))
        for p in list(self._peers):
            if p == lead:
                continue
            with self._mu:
                if (self._sock_dead.get(p, True) or p in self._bye
                        or self._closing):
                    continue
            if self.coalesce:
                self._enqueue(p, [("enc", [frame], 0)])
                continue
            try:
                with self._send_mu[p]:
                    self._peers[p].sendall(frame)
            except OSError:
                self._declare_proc_dead(p)

    @property
    def dropped(self) -> int:
        return self._dropped

    def pending(self, rank: int) -> int:
        with self._cv[rank]:
            return len(self._inbox[rank])

    def sent_vector(self) -> List[int]:
        with self._mu:
            return list(self._sent_to)

    def recv_vector(self) -> List[int]:
        with self._mu:
            return list(self._recv_from)

    def wire_sent_vector(self) -> List[int]:
        """Per-destination count of user events that took a socket (the
        co-located loopback path never increments this)."""
        with self._mu:
            return list(self._wire_sent_to)

    def wire_recv_vector(self) -> List[int]:
        """Per-source count of user events that arrived over a socket."""
        with self._mu:
            return list(self._wire_recv_from)

    def metrics(self) -> dict:
        """Wire-level observability snapshot for this process (consumed by
        ``Runtime.metrics()`` / ``Session.stats()``): event totals split
        wire vs loopback, drop count, and per-peer-process bytes, write
        batches, and send-queue high-water mark."""
        with self._mu:
            return {
                "kind": "socket",
                "coalesce": self.coalesce,
                "wire_events_sent": sum(self._wire_sent_to),
                "wire_events_recv": sum(self._wire_recv_from),
                "loopback_events": (sum(self._sent_to)
                                    - sum(self._wire_sent_to)),
                "dropped": self._dropped,
                "wire_bytes": sum(self._m_wire_bytes.values()),
                "writes": sum(self._m_writes.values()),
                "sendq_max": max(self._m_sendq_max.values(), default=0),
                "peers": {p: {"wire_bytes": self._m_wire_bytes[p],
                              "writes": self._m_writes[p],
                              "sendq_max": self._m_sendq_max[p]}
                          for p in self._peers},
            }

    # -------------------------------------------------------------- close
    def close(self) -> None:
        """Clean shutdown: BYE every live peer process (so their failure
        detectors stay quiet), flush the write queues, close all sockets,
        release blocked receivers."""
        with self._mu:
            if self._close_started:
                return
            self._close_started = True
        self._hb_stop.set()
        bye = frames.encode((frames.BYE,))
        if self.coalesce:
            # the BYE must take the same path as queued data so it is the
            # *last* frame on the wire; then wait for the writers to drain
            for p in self._peers:
                if not self._sock_dead[p]:
                    self._enqueue(p, [("enc", [bye], 0)])
            self.flush(timeout=1.0)
        else:
            for p, sock in self._peers.items():
                if not self._sock_dead[p]:
                    try:
                        with self._send_mu[p]:
                            sock.sendall(bye)
                    except OSError:
                        pass
        with self._mu:
            self._closing = True
        for cv in self._sendcv.values():
            with cv:
                cv.notify_all()  # writers observe _closing and exit
        for sock in self._peers.values():
            self._teardown(sock)  # readers unblock with EOF -> clean exit
        for r in self.local_ranks:
            self.wake(r)
        for t in self._threads:
            t.join(0.5)
