"""TCP socket transport: EDAT ranks as separate OS processes (paper §II.F).

Implements the full :class:`~repro.core.transport.Transport` contract over
stream sockets with length-prefixed pickled frames (:mod:`repro.net.frames`):

* **FIFO** — one connection per unordered rank pair, written under a
  per-connection lock and read by one reader thread per peer, so
  per-(src,dst) delivery order is exactly TCP byte order.  Self-sends take
  a lock-free-ish loopback straight into the local inbox.
* **Batching** — ``send_many`` concatenates a whole fire-batch into one
  ``sendall`` per destination; ``drain``/``recv_many`` pop the entire inbox
  in one lock round-trip.
* **Notification** — ``set_notify`` wakes an idle worker on arrival
  (worker-progress mode), exactly like the in-proc transport.
* **Failure detection** — every connection carries heartbeats; a peer that
  goes silent past ``hb_timeout`` (or whose connection breaks without a
  clean BYE) is declared dead and reported through ``on_peer_dead``, which
  the runtime wires to its ``RANK_FAILED`` machinery.  Sends to dead peers
  are dropped and counted, mirroring ``InProcTransport``.
* **Termination accounting** — per-peer ``sent_to``/``recv_from`` vectors
  (user events only; received counts when a message is *popped* for
  delivery, so an un-drained inbox still reads as in-flight).  The Mattern
  detector balances these across processes, restricted to alive ranks.

Payloads must be picklable; :meth:`validate_payload` enforces this at
``ctx.fire()`` time so the error surfaces in the firing task.

Construction is normally via :func:`repro.net.bootstrap.bootstrap` (or
``bootstrap_from_env``); tests may wire transports directly from
``socket.socketpair()`` ends.
"""
from __future__ import annotations

import pickle
import socket
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.core.transport import EVENT, Message, Transport

from . import frames


class SocketTransport(Transport):
    """Point-to-point transport for one local rank over per-peer sockets."""

    distributed = True
    serializes = True

    def __init__(self, rank: int, n_ranks: int,
                 peers: Dict[int, socket.socket], *,
                 hb_interval: float = 0.5, hb_timeout: float = 5.0):
        assert set(peers) == set(range(n_ranks)) - {rank}, \
            f"rank {rank}/{n_ranks}: need a socket per peer, got {set(peers)}"
        self.rank = rank
        self.n_ranks = n_ranks
        self.local_ranks = (rank,)
        self._peers = peers
        self._send_mu = {p: threading.Lock() for p in peers}
        self._inbox: deque = deque()
        self._cv = threading.Condition()
        self._notify: Optional[Callable[[], None]] = None
        #: callback(rank) invoked (outside locks) when a peer is declared
        #: dead by the heartbeat/EOF detector; set by the Runtime
        self.on_peer_dead: Optional[Callable[[int], None]] = None
        #: push-mode delivery: when the runtime registers this callback the
        #: reader threads hand message batches straight to it, skipping the
        #: inbox and the progress-thread wakeup hop (one fewer context
        #: switch per message on the latency path)
        self._deliver: Optional[Callable[[List[Message]], None]] = None

        self._mu = threading.Lock()
        self._dead = [False] * n_ranks
        self._bye = set()          # peers that closed cleanly
        self._dropped = 0
        self._sent_to = [0] * n_ranks     # user events written per dst
        self._recv_from = [0] * n_ranks   # user events popped per src
        self._last_seen = {p: time.monotonic() for p in peers}
        self._closing = False

        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout
        self._threads: List[threading.Thread] = []
        for p in peers:
            t = threading.Thread(target=self._reader, args=(p,), daemon=True,
                                 name=f"edat-net-r{rank}<{p}")
            self._threads.append(t)
            t.start()
        self._hb_stop = threading.Event()
        if hb_interval > 0:
            t = threading.Thread(target=self._heartbeat_loop, daemon=True,
                                 name=f"edat-net-hb{rank}")
            self._threads.append(t)
            t.start()

    # ---------------------------------------------------------- reader side
    def _reader(self, peer: int) -> None:
        sock = self._peers[peer]
        try:
            f = sock.makefile("rb")
        except OSError:
            f = None
        while True:
            try:
                frame = (frames.recv_frame_buffered(f) if f is not None
                         else None)
            except Exception:
                frame = None  # broken/corrupt connection == EOF
            if frame is None:
                with self._mu:
                    clean = self._closing
                if not clean:
                    self._declare_dead(peer)  # silent if the peer said BYE
                if f is not None:
                    try:
                        f.close()
                    except OSError:
                        pass
                return
            with self._mu:
                self._last_seen[peer] = time.monotonic()
            kind = frame[0]
            if kind == frames.MSG:
                msg = frame[1]
                with self._cv:
                    push = self._deliver
                    if push is None:
                        self._inbox.append(msg)
                        self._cv.notify()
                if push is not None:
                    # deliver BEFORE counting: recv_from must never include
                    # an event the scheduler has not seen, or the detector
                    # could observe balanced counters + idle schedulers while
                    # the event sits on a descheduled reader (rcv < sent in
                    # the gap is the safe direction — it only delays a poll)
                    push([msg])
                    self._count_popped((msg,))
                    continue
                hook = self._notify
                if hook is not None:
                    hook()  # outside the inbox lock (may take sched locks)
            elif kind == frames.BYE:
                with self._mu:
                    self._bye.add(peer)
                # keep reading until EOF so late frames cannot be lost
            # HEARTBEAT: nothing beyond the last_seen update above

    def _heartbeat_loop(self) -> None:
        beat = frames.encode((frames.HEARTBEAT,))
        while not self._hb_stop.wait(self._hb_interval):
            now = time.monotonic()
            for p in list(self._peers):
                with self._mu:
                    if self._dead[p] or p in self._bye or self._closing:
                        continue
                    stale = now - self._last_seen[p] > self._hb_timeout
                if stale:
                    self._declare_dead(p)
                    continue
                try:
                    with self._send_mu[p]:
                        self._peers[p].sendall(beat)
                except OSError:
                    self._declare_dead(p)

    @staticmethod
    def _teardown(sock: socket.socket) -> None:
        """Force-close: shutdown reaches the peer (and unblocks our reader)
        even while a buffered makefile still holds the fd refcount."""
        try:
            sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            sock.close()
        except OSError:
            pass

    def _declare_dead(self, peer: int) -> None:
        """Failure detector verdict: mark dead, close, notify the runtime.
        A peer that already said BYE is marked dead *silently* — a broken
        connection after a clean goodbye is shutdown skew, not a failure."""
        with self._mu:
            if self._dead[peer] or self._closing:
                return
            self._dead[peer] = True
            was_clean = peer in self._bye
        self._teardown(self._peers[peer])
        self.wake(self.rank)  # a blocked recv should re-check the world
        cb = self.on_peer_dead
        if cb is not None and not was_clean:
            cb(peer)

    # ---------------------------------------------------------- send side
    def validate_payload(self, data) -> None:
        try:
            pickle.dumps(data, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception as e:
            raise TypeError(
                f"event payload of type {type(data).__name__!r} is not "
                f"picklable, which SocketTransport requires to cross "
                f"process boundaries: {e}") from e

    def _encode_msg(self, msg: Message) -> bytes:
        try:
            return frames.encode((frames.MSG, msg))
        except Exception as e:
            raise TypeError(
                f"message to rank {msg.dst} (eid "
                f"{getattr(msg.payload, 'eid', msg.payload)!r}) cannot be "
                f"pickled for SocketTransport: {e}") from e

    def set_deliver(self, fn: Callable[[List[Message]], None]) -> None:
        """Enable push-mode delivery (used by the Runtime): the reader
        threads call ``fn(batch)`` directly instead of queueing into the
        inbox.  Messages that arrived before registration are flushed to
        ``fn`` under the inbox lock, so per-(src,dst) FIFO order survives
        the handover."""
        with self._cv:
            backlog = list(self._inbox)
            self._inbox.clear()
            if backlog:
                fn(backlog)  # deliver-then-count, as in the reader path
                self._count_popped(backlog)
            self._deliver = fn

    def _loopback(self, msgs: List[Message]) -> None:
        with self._mu:
            for m in msgs:
                if m.kind == EVENT:
                    self._sent_to[self.rank] += 1
        with self._cv:
            push = self._deliver
            if push is None:
                self._inbox.extend(msgs)
                self._cv.notify()
        if push is not None:
            push(msgs)  # deliver-then-count, as in the reader path
            self._count_popped(msgs)
            return
        hook = self._notify
        if hook is not None:
            hook()

    def send(self, msg: Message) -> bool:
        if msg.dst == self.rank:
            self._loopback([msg])
            return True
        if self._dead[msg.dst]:
            with self._mu:
                self._dropped += 1
            return False
        data = self._encode_msg(msg)
        try:
            with self._send_mu[msg.dst]:
                self._peers[msg.dst].sendall(data)
        except OSError:
            self._declare_dead(msg.dst)
            with self._mu:
                self._dropped += 1
            return False
        if msg.kind == EVENT:
            with self._mu:
                self._sent_to[msg.dst] += 1
        return True

    def send_many(self, msgs: List[Message]) -> int:
        by_dst: Dict[int, List[Message]] = {}
        for m in msgs:
            by_dst.setdefault(m.dst, []).append(m)
        delivered = 0
        for dst, ms in by_dst.items():
            if dst == self.rank:
                self._loopback(ms)
                delivered += len(ms)
                continue
            if self._dead[dst]:
                with self._mu:
                    self._dropped += len(ms)
                continue
            blob = b"".join(self._encode_msg(m) for m in ms)
            try:
                with self._send_mu[dst]:
                    self._peers[dst].sendall(blob)
            except OSError:
                self._declare_dead(dst)
                with self._mu:
                    self._dropped += len(ms)
                continue
            n_ev = sum(1 for m in ms if m.kind == EVENT)
            with self._mu:
                self._sent_to[dst] += n_ev
            delivered += len(ms)
        return delivered

    # --------------------------------------------------------- receive side
    def _count_popped(self, msgs) -> None:
        # pop-based receives count here, at the moment the caller takes
        # ownership; a Runtime always runs this transport in push mode,
        # where counting happens strictly *after* scheduler delivery
        with self._mu:
            for m in msgs:
                if m.kind == EVENT:
                    self._recv_from[m.src] += 1

    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        assert rank == self.rank
        with self._cv:
            if not self._inbox:
                self._cv.wait(timeout)
            if not self._inbox:
                return None
            msg = self._inbox.popleft()
        self._count_popped((msg,))
        return msg

    def recv_many(self, rank: int,
                  timeout: Optional[float]) -> List[Message]:
        assert rank == self.rank
        with self._cv:
            if not self._inbox:
                self._cv.wait(timeout)
            out = list(self._inbox)
            self._inbox.clear()
        self._count_popped(out)
        return out

    def drain(self, rank: int, max_n: Optional[int] = None) -> List[Message]:
        assert rank == self.rank
        with self._cv:
            if not self._inbox:
                return []
            if max_n is None or max_n >= len(self._inbox):
                out = list(self._inbox)
                self._inbox.clear()
            else:
                out = [self._inbox.popleft() for _ in range(max_n)]
        self._count_popped(out)
        return out

    def wake(self, rank: int) -> None:
        with self._cv:
            self._cv.notify_all()

    def set_notify(self, rank: int,
                   fn: Optional[Callable[[], None]]) -> None:
        assert rank == self.rank
        self._notify = fn

    # ------------------------------------------------------- failure / info
    def is_dead(self, rank: int) -> bool:
        return self._dead[rank]

    def mark_dead(self, rank: int) -> None:
        """Local failure injection (``kill_rank`` parity): stop sending to
        ``rank`` without invoking the peer-death callback — the caller is
        responsible for its own RANK_FAILED notification."""
        with self._mu:
            if self._dead[rank]:
                return
            self._dead[rank] = True
        sock = self._peers.get(rank)
        if sock is not None:
            self._teardown(sock)  # plain close() would leave the reader's
            # makefile fd alive and keep delivering the dead rank's events

    @property
    def dropped(self) -> int:
        return self._dropped

    def pending(self, rank: int) -> int:
        with self._cv:
            return len(self._inbox)

    def sent_vector(self) -> List[int]:
        with self._mu:
            return list(self._sent_to)

    def recv_vector(self) -> List[int]:
        with self._mu:
            return list(self._recv_from)

    # -------------------------------------------------------------- close
    def close(self) -> None:
        """Clean shutdown: BYE every live peer (so their failure detectors
        stay quiet), close all sockets, release blocked receivers."""
        with self._mu:
            if self._closing:
                return
            self._closing = True
        self._hb_stop.set()
        bye = frames.encode((frames.BYE,))
        for p, sock in self._peers.items():
            if not self._dead[p]:
                try:
                    with self._send_mu[p]:
                        sock.sendall(bye)
                except OSError:
                    pass
        for sock in self._peers.values():
            self._teardown(sock)  # readers unblock with EOF -> clean exit
        self.wake(self.rank)
        for t in self._threads:
            t.join(0.5)
