"""Rendezvous: wire up all-pairs connections for :class:`SocketTransport`.

Coordinator pattern (rank 0 + environment addressing, the usual launcher
contract of distributed runtimes).  The unit of rendezvous is a *process*,
identified by the lowest rank it hosts (its **lead**) — a process may host
several ranks (``local_ranks``), and co-located ranks share the process's
connections:

1. every process opens a listening socket on an ephemeral port;
2. the process hosting rank 0 additionally listens on the well-known
   *coordinator* address (with a bind-retry loop: the launcher probes a
   free port and releases it before the child re-binds it, so a TOCTOU
   loser waits for the squatter instead of crashing);
3. the other processes dial the coordinator and register their lead,
   hosted ranks, and listen address (re-dialing if they reached a
   squatter that hung up or spoke garbage instead of the placement
   reply — the dial side of the same race);
4. the coordinator replies to each with the complete placement
   ``{lead: (address, ranks)}``;
5. each process dials every lower-lead process (identified by a HELLO
   frame), accepts from every higher one — one TCP connection per
   unordered process pair, used bidirectionally by all hosted ranks.

Because every process listens *before* registering with the coordinator,
no peer can learn an address that is not yet accepting — dialing needs no
retry loop (a short one is kept for OS-level accept-queue hiccups).

Environment contract (used by ``python -m repro.net.launch`` and usable by
any external process manager, e.g. one process per node under slurm/k8s):

* ``EDAT_RANK``        — this process's lead rank;
* ``EDAT_LOCAL_RANKS`` — optional comma list of ranks this process hosts
  (default: just ``EDAT_RANK``);
* ``EDAT_NRANKS``      — world size;
* ``EDAT_COORD``       — ``host:port`` of the rank-0 coordinator;
* ``EDAT_HOST``        — optional bind/advertise host (default
  ``127.0.0.1``).
"""
from __future__ import annotations

import errno
import os
import pickle
import socket
import time
from typing import Dict, Optional, Sequence, Tuple

from . import frames
from .socket_transport import SocketTransport

Addr = Tuple[str, int]


def _listener(host: str, port: int = 0, backlog: int = 64) -> socket.socket:
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(backlog)
    return srv


def _listener_retry(host: str, port: int, deadline: float,
                    backlog: int = 64) -> socket.socket:
    """Bind a well-known port, retrying on EADDRINUSE until ``deadline``.

    The coordinator port is probed by the launcher parent and *released*
    before this child re-binds it — another process can grab it in the
    gap (the classic free-port TOCTOU).  Retrying turns a transient
    squatter (TIME_WAIT, a short-lived test socket, a just-exited
    previous run) into a short wait instead of a crashed world."""
    while True:
        try:
            return _listener(host, port, backlog)
        except OSError as e:
            if e.errno != errno.EADDRINUSE or time.monotonic() >= deadline:
                raise
            time.sleep(0.05)


def _dial(addr: Addr, deadline: float) -> socket.socket:
    last = None
    while time.monotonic() < deadline:
        try:
            return socket.create_connection(
                addr, timeout=max(0.1, deadline - time.monotonic()))
        except OSError as e:
            last = e
            time.sleep(0.05)
    raise RuntimeError(f"bootstrap: could not connect to {addr}: {last}")


def _configure(sock: socket.socket) -> socket.socket:
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return sock


def bootstrap(rank: int, n_ranks: int, coord_addr: Addr, *,
              local_ranks: Optional[Sequence[int]] = None,
              host: str = "127.0.0.1", timeout: float = 30.0,
              hb_interval: float = 0.5, hb_timeout: float = 5.0,
              **transport_kw) -> SocketTransport:
    """Run the process-level rendezvous and return a connected transport.

    ``rank`` is this process's lead rank; ``local_ranks`` lists every rank
    the process hosts (default: just ``rank`` — the classic one-rank-per-
    process world).  Extra keyword arguments (``coalesce``,
    ``flush_interval``, ``max_batch_bytes``) pass through to
    :class:`SocketTransport`."""
    ranks = tuple(sorted(set(local_ranks))) if local_ranks else (rank,)
    assert rank == ranks[0], \
        f"bootstrap rank {rank} must be the lead of local_ranks {ranks}"
    if len(ranks) == n_ranks:     # one process hosts the whole world
        return SocketTransport(rank, n_ranks, {}, local_ranks=ranks,
                               placement={rank: ranks},
                               hb_interval=hb_interval,
                               hb_timeout=hb_timeout, **transport_kw)
    deadline = time.monotonic() + timeout
    listener = _listener(host)
    my_addr: Addr = (host, listener.getsockname()[1])

    # -- placement exchange through the coordinator -------------------------
    if rank == 0:
        coord = _listener_retry(coord_addr[0], coord_addr[1], deadline)
        coord.settimeout(timeout)
        world: Dict[int, Tuple[Addr, Tuple[int, ...]]] = {
            0: (my_addr, ranks)}
        covered = len(ranks)
        conns = []
        try:
            while covered < n_ranks:
                c, _ = coord.accept()
                c.settimeout(timeout)
                try:
                    frame = frames.recv_frame(c)
                except (OSError, ValueError, pickle.UnpicklingError,
                        EOFError):
                    frame = None
                # a well-known port attracts strays: squatter-era clients
                # of another launch, half-closed dials, port scanners.
                # Anything that is not a plausible HELLO for THIS world
                # (right shape, in-range non-overlapping ranks) is dropped
                # instead of crashing or corrupting the placement.
                if (not isinstance(frame, tuple) or len(frame) != 4
                        or frame[0] != frames.HELLO):
                    c.close()
                    continue
                _, peer_lead, peer_ranks, peer_addr = frame
                try:
                    peer_ranks = tuple(int(r) for r in peer_ranks)
                    peer_addr = (str(peer_addr[0]), int(peer_addr[1]))
                except (TypeError, ValueError, IndexError):
                    c.close()
                    continue
                taken = {r for l, (_, rs) in world.items()
                         if l != peer_lead for r in rs}
                if (not peer_ranks or peer_lead != peer_ranks[0]
                        or any(not 0 <= r < n_ranks for r in peer_ranks)
                        or taken & set(peer_ranks)):
                    c.close()
                    continue
                if peer_lead in world:
                    # a retrying process re-registers with the SAME addr
                    # and ranks (its listener never changed); a mismatch
                    # is a foreign launch colliding on this port
                    if world[peer_lead] != (peer_addr, peer_ranks):
                        c.close()
                        continue
                else:
                    covered += len(peer_ranks)
                    world[peer_lead] = (peer_addr, peer_ranks)
                conns.append(c)
            for c in conns:
                try:
                    frames.send_frame(c, ("addrs", world))
                except OSError:
                    pass  # a retrier abandoned this connection
        finally:
            for c in conns:
                c.close()
            coord.close()
    else:
        # register-with-retry: until the real coordinator owns the port a
        # dial may reach a squatter (the same TOCTOU the coordinator's
        # bind-retry rides out) — EOF, a reset, or garbage instead of the
        # addrs reply just means "not the coordinator yet, try again"
        world = None
        while world is None:
            if time.monotonic() >= deadline:
                raise RuntimeError(
                    f"bootstrap: no coordinator reply from {coord_addr}")
            c = _dial(coord_addr, deadline)
            c.settimeout(max(0.1, min(timeout,
                                      deadline - time.monotonic())))
            try:
                frames.send_frame(c, (frames.HELLO, rank, ranks, my_addr))
                got = frames.recv_frame(c)
                if (isinstance(got, tuple) and len(got) == 2
                        and got[0] == "addrs" and isinstance(got[1], dict)):
                    world = {int(l): ((str(a[0]), int(a[1])),
                                      tuple(int(r) for r in rs))
                             for l, (a, rs) in got[1].items()}
            except (OSError, TypeError, KeyError, IndexError, ValueError,
                    pickle.UnpicklingError, EOFError):
                world = None  # squatter hung up / spoke garbage: retry
            finally:
                c.close()
            if world is None:
                time.sleep(0.1)
    placement = {l: rs for l, (_, rs) in world.items()}

    # -- all-pairs process mesh: dial down, accept up -----------------------
    peers: Dict[int, socket.socket] = {}
    for q in sorted(world):
        if q >= rank:
            continue
        s = _dial(world[q][0], deadline)
        frames.send_frame(s, (frames.HELLO, rank))
        peers[q] = _configure(s)
    listener.settimeout(timeout)
    try:
        while len(peers) < len(world) - 1:
            s, _ = listener.accept()
            s.settimeout(timeout)
            try:
                frame = frames.recv_frame(s)
            except (OSError, ValueError, pickle.UnpicklingError, EOFError):
                frame = None
            if (not isinstance(frame, tuple) or len(frame) != 2
                    or frame[0] != frames.HELLO or frame[1] not in world
                    or frame[1] <= rank or frame[1] in peers):
                s.close()        # stray connection, not a mesh peer
                continue
            peers[frame[1]] = _configure(s)
    finally:
        listener.close()
    return SocketTransport(rank, n_ranks, peers, local_ranks=ranks,
                           placement=placement, hb_interval=hb_interval,
                           hb_timeout=hb_timeout, **transport_kw)


def bootstrap_from_env(**kw) -> SocketTransport:
    """Rendezvous addressed entirely by ``EDAT_*`` environment variables."""
    rank = int(os.environ["EDAT_RANK"])
    n_ranks = int(os.environ["EDAT_NRANKS"])
    host, port = os.environ["EDAT_COORD"].rsplit(":", 1)
    local = os.environ.get("EDAT_LOCAL_RANKS")
    if local:
        kw.setdefault("local_ranks",
                      tuple(int(r) for r in local.split(",")))
    kw.setdefault("host", os.environ.get("EDAT_HOST", "127.0.0.1"))
    return bootstrap(rank, n_ranks, (host, int(port)), **kw)
