"""Rendezvous: wire up all-pairs connections for :class:`SocketTransport`.

Coordinator pattern (rank 0 + environment addressing, the usual launcher
contract of distributed runtimes):

1. every rank opens a listening socket on an ephemeral port;
2. rank 0 additionally listens on the well-known *coordinator* address;
3. ranks 1..n-1 dial the coordinator and register their listen address;
4. rank 0 replies to each with the complete ``{rank: address}`` map;
5. each rank dials every lower-numbered rank (identified by a HELLO frame),
   accepts from every higher-numbered one — one TCP connection per
   unordered pair, used bidirectionally.

Because every rank listens *before* registering with the coordinator, no
peer can learn an address that is not yet accepting — dialing needs no
retry loop (a short one is kept for OS-level accept-queue hiccups).

Environment contract (used by ``python -m repro.net.launch`` and usable by
any external process manager, e.g. one process per node under slurm/k8s):

* ``EDAT_RANK``    — this process's rank;
* ``EDAT_NRANKS``  — world size;
* ``EDAT_COORD``   — ``host:port`` of the rank-0 coordinator;
* ``EDAT_HOST``    — optional bind/advertise host (default ``127.0.0.1``).
"""
from __future__ import annotations

import os
import socket
import time
from typing import Dict, Tuple

from . import frames
from .socket_transport import SocketTransport

Addr = Tuple[str, int]


def _listener(host: str, port: int = 0, backlog: int = 64) -> socket.socket:
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(backlog)
    return srv


def _dial(addr: Addr, deadline: float) -> socket.socket:
    last = None
    while time.monotonic() < deadline:
        try:
            return socket.create_connection(
                addr, timeout=max(0.1, deadline - time.monotonic()))
        except OSError as e:
            last = e
            time.sleep(0.05)
    raise RuntimeError(f"bootstrap: could not connect to {addr}: {last}")


def _configure(sock: socket.socket) -> socket.socket:
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return sock


def bootstrap(rank: int, n_ranks: int, coord_addr: Addr, *,
              host: str = "127.0.0.1", timeout: float = 30.0,
              hb_interval: float = 0.5, hb_timeout: float = 5.0,
              **transport_kw) -> SocketTransport:
    """Run the rendezvous for ``rank`` and return a connected transport.
    Extra keyword arguments (``coalesce``, ``flush_interval``,
    ``max_batch_bytes``) pass through to :class:`SocketTransport`."""
    if n_ranks == 1:
        return SocketTransport(0, 1, {}, hb_interval=hb_interval,
                               hb_timeout=hb_timeout, **transport_kw)
    deadline = time.monotonic() + timeout
    listener = _listener(host)
    my_addr: Addr = (host, listener.getsockname()[1])

    # -- address exchange through the coordinator ---------------------------
    if rank == 0:
        coord = _listener(coord_addr[0], coord_addr[1])
        coord.settimeout(timeout)
        addrs: Dict[int, Addr] = {0: my_addr}
        conns = []
        try:
            while len(addrs) < n_ranks:
                c, _ = coord.accept()
                c.settimeout(timeout)
                tag, peer_rank, peer_addr = frames.recv_frame(c)
                assert tag == frames.HELLO
                addrs[peer_rank] = tuple(peer_addr)
                conns.append(c)
            for c in conns:
                frames.send_frame(c, ("addrs", addrs))
        finally:
            for c in conns:
                c.close()
            coord.close()
    else:
        c = _dial(coord_addr, deadline)
        c.settimeout(timeout)
        try:
            frames.send_frame(c, (frames.HELLO, rank, my_addr))
            tag, addrs = frames.recv_frame(c)
            assert tag == "addrs"
            addrs = {int(r): tuple(a) for r, a in addrs.items()}
        finally:
            c.close()

    # -- all-pairs mesh: dial down, accept up -------------------------------
    peers: Dict[int, socket.socket] = {}
    for q in range(rank):
        s = _dial(addrs[q], deadline)
        frames.send_frame(s, (frames.HELLO, rank))
        peers[q] = _configure(s)
    listener.settimeout(timeout)
    try:
        while len(peers) < n_ranks - 1:
            s, _ = listener.accept()
            s.settimeout(timeout)
            tag, peer_rank = frames.recv_frame(s)
            assert tag == frames.HELLO and peer_rank > rank
            peers[peer_rank] = _configure(s)
    finally:
        listener.close()
    return SocketTransport(rank, n_ranks, peers, hb_interval=hb_interval,
                           hb_timeout=hb_timeout, **transport_kw)


def bootstrap_from_env(**kw) -> SocketTransport:
    """Rendezvous addressed entirely by ``EDAT_*`` environment variables."""
    rank = int(os.environ["EDAT_RANK"])
    n_ranks = int(os.environ["EDAT_NRANKS"])
    host, port = os.environ["EDAT_COORD"].rsplit(":", 1)
    kw.setdefault("host", os.environ.get("EDAT_HOST", "127.0.0.1"))
    return bootstrap(rank, n_ranks, (host, int(port)), **kw)
