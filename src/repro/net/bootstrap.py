"""Rendezvous: wire up all-pairs connections for :class:`SocketTransport`.

Coordinator pattern (rank 0 + environment addressing, the usual launcher
contract of distributed runtimes).  The unit of rendezvous is a *process*,
identified by the lowest rank it hosts (its **lead**) — a process may host
several ranks (``local_ranks``), and co-located ranks share the process's
connections:

1. every process opens a listening socket on an ephemeral port;
2. the process hosting rank 0 additionally listens on the well-known
   *coordinator* address (with a bind-retry loop: the launcher probes a
   free port and releases it before the child re-binds it, so a TOCTOU
   loser waits for the squatter instead of crashing);
3. the other processes dial the coordinator and register their lead,
   hosted ranks, and listen address (re-dialing if they reached a
   squatter that hung up or spoke garbage instead of the placement
   reply — the dial side of the same race);
4. the coordinator replies to each with the complete placement
   ``{lead: (address, ranks)}``;
5. each process dials every lower-lead process (identified by a HELLO
   frame), accepts from every higher one — one TCP connection per
   unordered process pair, used bidirectionally by all hosted ranks.

Because every process listens *before* registering with the coordinator,
no peer can learn an address that is not yet accepting — dialing needs no
retry loop (a short one is kept for OS-level accept-queue hiccups).

Environment contract (used by ``python -m repro.net.launch`` and usable by
any external process manager, e.g. one process per node under slurm/k8s):

* ``EDAT_RANK``        — this process's lead rank;
* ``EDAT_LOCAL_RANKS`` — optional comma list of ranks this process hosts
  (default: just ``EDAT_RANK``);
* ``EDAT_NRANKS``      — world size;
* ``EDAT_COORD``       — ``host:port`` of the rank-0 coordinator;
* ``EDAT_HOST``        — optional bind/advertise host (default
  ``127.0.0.1``).
"""
from __future__ import annotations

import errno
import os
import pickle
import socket
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

from . import frames
from .socket_transport import SocketTransport

Addr = Tuple[str, int]


def _listener(host: str, port: int = 0, backlog: int = 64) -> socket.socket:
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(backlog)
    return srv


def _listener_retry(host: str, port: int, deadline: float,
                    backlog: int = 64) -> socket.socket:
    """Bind a well-known port, retrying on EADDRINUSE until ``deadline``.

    The coordinator port is probed by the launcher parent and *released*
    before this child re-binds it — another process can grab it in the
    gap (the classic free-port TOCTOU).  Retrying turns a transient
    squatter (TIME_WAIT, a short-lived test socket, a just-exited
    previous run) into a short wait instead of a crashed world."""
    while True:
        try:
            return _listener(host, port, backlog)
        except OSError as e:
            if e.errno != errno.EADDRINUSE or time.monotonic() >= deadline:
                raise
            time.sleep(0.05)


def _dial(addr: Addr, deadline: float) -> socket.socket:
    last = None
    while time.monotonic() < deadline:
        try:
            return socket.create_connection(
                addr, timeout=max(0.1, deadline - time.monotonic()))
        except OSError as e:
            last = e
            time.sleep(0.05)
    raise RuntimeError(f"bootstrap: could not connect to {addr}: {last}")


def _configure(sock: socket.socket) -> socket.socket:
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(None)
    return sock


def bootstrap(rank: int, n_ranks: int, coord_addr: Addr, *,
              local_ranks: Optional[Sequence[int]] = None,
              host: str = "127.0.0.1", timeout: float = 30.0,
              hb_interval: float = 0.5, hb_timeout: float = 5.0,
              elastic: bool = False,
              **transport_kw) -> SocketTransport:
    """Run the process-level rendezvous and return a connected transport.

    ``rank`` is this process's lead rank; ``local_ranks`` lists every rank
    the process hosts (default: just ``rank`` — the classic one-rank-per-
    process world).  Extra keyword arguments (``coalesce``,
    ``flush_interval``, ``max_batch_bytes``) pass through to
    :class:`SocketTransport`.

    With ``elastic=True`` the rank-0 process keeps the coordinator
    listener open after rendezvous and serves :func:`bootstrap_join`
    requests from replacement processes for the life of the run: a late
    process may re-host a dead process's ranks, and every survivor is
    told to dial it (``PEER_JOINED``) and splices it into the mesh."""
    ranks = tuple(sorted(set(local_ranks))) if local_ranks else (rank,)
    assert rank == ranks[0], \
        f"bootstrap rank {rank} must be the lead of local_ranks {ranks}"
    if len(ranks) == n_ranks:     # one process hosts the whole world
        return SocketTransport(rank, n_ranks, {}, local_ranks=ranks,
                               placement={rank: ranks},
                               hb_interval=hb_interval,
                               hb_timeout=hb_timeout, **transport_kw)
    deadline = time.monotonic() + timeout
    listener = _listener(host)
    my_addr: Addr = (host, listener.getsockname()[1])

    # -- placement exchange through the coordinator -------------------------
    coord = None
    if rank == 0:
        coord = _listener_retry(coord_addr[0], coord_addr[1], deadline)
        coord.settimeout(timeout)
        world: Dict[int, Tuple[Addr, Tuple[int, ...]]] = {
            0: (my_addr, ranks)}
        covered = len(ranks)
        conns = []
        try:
            while covered < n_ranks:
                c, _ = coord.accept()
                c.settimeout(timeout)
                try:
                    frame = frames.recv_frame(c)
                except (OSError, ValueError, pickle.UnpicklingError,
                        EOFError):
                    frame = None
                # a well-known port attracts strays: squatter-era clients
                # of another launch, half-closed dials, port scanners.
                # Anything that is not a plausible HELLO for THIS world
                # (right shape, in-range non-overlapping ranks) is dropped
                # instead of crashing or corrupting the placement.
                if (not isinstance(frame, tuple) or len(frame) != 4
                        or frame[0] != frames.HELLO):
                    c.close()
                    continue
                _, peer_lead, peer_ranks, peer_addr = frame
                try:
                    peer_ranks = tuple(int(r) for r in peer_ranks)
                    peer_addr = (str(peer_addr[0]), int(peer_addr[1]))
                except (TypeError, ValueError, IndexError):
                    c.close()
                    continue
                taken = {r for l, (_, rs) in world.items()
                         if l != peer_lead for r in rs}
                if (not peer_ranks or peer_lead != peer_ranks[0]
                        or any(not 0 <= r < n_ranks for r in peer_ranks)
                        or taken & set(peer_ranks)):
                    c.close()
                    continue
                if peer_lead in world:
                    # a retrying process re-registers with the SAME addr
                    # and ranks (its listener never changed); a mismatch
                    # is a foreign launch colliding on this port
                    if world[peer_lead] != (peer_addr, peer_ranks):
                        c.close()
                        continue
                else:
                    covered += len(peer_ranks)
                    world[peer_lead] = (peer_addr, peer_ranks)
                conns.append(c)
            for c in conns:
                try:
                    frames.send_frame(c, ("addrs", world))
                except OSError:
                    pass  # a retrier abandoned this connection
        finally:
            for c in conns:
                c.close()
            if not elastic:      # elastic: the join server inherits it
                coord.close()
                coord = None
    else:
        # register-with-retry: until the real coordinator owns the port a
        # dial may reach a squatter (the same TOCTOU the coordinator's
        # bind-retry rides out) — EOF, a reset, or garbage instead of the
        # addrs reply just means "not the coordinator yet, try again"
        world = None
        while world is None:
            if time.monotonic() >= deadline:
                raise RuntimeError(
                    f"bootstrap: no coordinator reply from {coord_addr}")
            c = _dial(coord_addr, deadline)
            c.settimeout(max(0.1, min(timeout,
                                      deadline - time.monotonic())))
            try:
                frames.send_frame(c, (frames.HELLO, rank, ranks, my_addr))
                got = frames.recv_frame(c)
                if (isinstance(got, tuple) and len(got) == 2
                        and got[0] == "addrs" and isinstance(got[1], dict)):
                    world = {int(l): ((str(a[0]), int(a[1])),
                                      tuple(int(r) for r in rs))
                             for l, (a, rs) in got[1].items()}
            except (OSError, TypeError, KeyError, IndexError, ValueError,
                    pickle.UnpicklingError, EOFError):
                world = None  # squatter hung up / spoke garbage: retry
            finally:
                c.close()
            if world is None:
                time.sleep(0.1)
    placement = {l: rs for l, (_, rs) in world.items()}

    # -- all-pairs process mesh: dial down, accept up -----------------------
    peers: Dict[int, socket.socket] = {}
    for q in sorted(world):
        if q >= rank:
            continue
        s = _dial(world[q][0], deadline)
        frames.send_frame(s, (frames.HELLO, rank))
        peers[q] = _configure(s)
    listener.settimeout(timeout)
    try:
        while len(peers) < len(world) - 1:
            s, _ = listener.accept()
            s.settimeout(timeout)
            try:
                frame = frames.recv_frame(s)
            except (OSError, ValueError, pickle.UnpicklingError, EOFError):
                frame = None
            if (not isinstance(frame, tuple) or len(frame) != 2
                    or frame[0] != frames.HELLO or frame[1] not in world
                    or frame[1] <= rank or frame[1] in peers):
                s.close()        # stray connection, not a mesh peer
                continue
            peers[frame[1]] = _configure(s)
    finally:
        listener.close()
    transport = SocketTransport(rank, n_ranks, peers, local_ranks=ranks,
                                placement=placement,
                                hb_interval=hb_interval,
                                hb_timeout=hb_timeout, **transport_kw)
    if coord is not None:
        t = threading.Thread(target=_join_server,
                             args=(coord, transport, timeout),
                             daemon=True, name="edat-net-join-server")
        transport._join_thread = t
        t.start()
    return transport


def _join_server(coord: socket.socket, transport: SocketTransport,
                 timeout: float) -> None:
    """Rank-0 elastic-join service: accept ``JOIN`` requests on the (kept
    alive) coordinator listener for the life of the transport.

    A JOIN is granted only for a placement entry whose ranks are ALL
    currently dead (the replacement re-hosts exactly that process's
    ranks); anything else gets ``NOJOIN`` and the newcomer retries — in
    particular a replacement that races the failure detector simply waits
    out the heartbeat timeout.  On grant: reply ``WELCOME`` with the
    placement and the set of live processes that will dial in, broadcast
    ``PEER_JOINED`` to the survivors, and dial the newcomer ourselves."""
    coord.settimeout(0.5)
    io_timeout = min(timeout, 5.0)
    try:
        while not transport._close_started:
            try:
                c, _ = coord.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            c.settimeout(io_timeout)
            try:
                frame = frames.recv_frame(c)
            except (OSError, ValueError, pickle.UnpicklingError, EOFError):
                frame = None
            if (not isinstance(frame, tuple) or len(frame) != 4
                    or frame[0] != frames.JOIN):
                c.close()        # stray dial on the well-known port
                continue
            _, lead, jranks, addr = frame
            try:
                lead = int(lead)
                jranks = tuple(sorted(int(r) for r in jranks))
                addr = (str(addr[0]), int(addr[1]))
            except (TypeError, ValueError, IndexError):
                c.close()
                continue
            if (transport.placement.get(lead) != jranks
                    or not all(transport.is_dead(r) for r in jranks)):
                try:
                    frames.send_frame(c, (frames.NOJOIN,
                                          f"ranks {jranks} are not a dead "
                                          f"process of this world"))
                except OSError:
                    pass
                c.close()
                continue
            dialers = [l for l, rs in transport.placement.items()
                       if l != lead
                       and not all(transport.is_dead(r) for r in rs)]
            dead = [l for l, rs in transport.placement.items()
                    if l != lead
                    and all(transport.is_dead(r) for r in rs)]
            try:
                frames.send_frame(c, (frames.WELCOME, {
                    "placement": dict(transport.placement),
                    "dead": dead, "dialers": dialers}))
            except OSError:
                c.close()
                continue
            c.close()
            # survivors dial the newcomer concurrently with our own dial
            transport.announce_join(lead, addr)
            transport.dial_peer(lead, addr, timeout=timeout)
    finally:
        try:
            coord.close()
        except OSError:
            pass


def bootstrap_join(rank: int, n_ranks: int, coord_addr: Addr, *,
                   local_ranks: Optional[Sequence[int]] = None,
                   host: str = "127.0.0.1", timeout: float = 30.0,
                   hb_interval: float = 0.5, hb_timeout: float = 5.0,
                   **transport_kw) -> SocketTransport:
    """Elastically join a *running* world as a replacement process.

    The counterpart of :func:`bootstrap` for a process launched after the
    original rendezvous: it re-hosts the ranks of a process that died
    (``local_ranks`` must exactly match a placement entry).  Protocol:
    listen first (so the advertised address is always accepting), send
    ``JOIN`` to the still-open coordinator, retry while it answers
    ``NOJOIN`` (the failure detector may not have declared the dead
    process yet), then accept one HELLO dial from every live process and
    hand the assembled mesh to :class:`SocketTransport` — with any other
    still-dead processes pre-marked via ``dead_procs``."""
    ranks = tuple(sorted(set(local_ranks))) if local_ranks else (rank,)
    assert rank == ranks[0], \
        f"bootstrap_join rank {rank} must be the lead of {ranks}"
    deadline = time.monotonic() + timeout
    listener = _listener(host)
    my_addr: Addr = (host, listener.getsockname()[1])
    info = None
    try:
        while info is None:
            if time.monotonic() >= deadline:
                raise RuntimeError(
                    f"bootstrap_join: no WELCOME from {coord_addr} for "
                    f"ranks {ranks} within {timeout}s")
            c = _dial(coord_addr, deadline)
            c.settimeout(max(0.1, min(timeout,
                                      deadline - time.monotonic())))
            try:
                frames.send_frame(c, (frames.JOIN, rank, ranks, my_addr))
                got = frames.recv_frame(c)
                if (isinstance(got, tuple) and len(got) == 2
                        and got[0] == frames.WELCOME
                        and isinstance(got[1], dict)):
                    info = got[1]
                # NOJOIN / garbage / EOF: not joinable yet, retry below
            except (OSError, TypeError, KeyError, IndexError, ValueError,
                    pickle.UnpicklingError, EOFError):
                info = None
            finally:
                c.close()
            if info is None:
                time.sleep(0.2)
        placement = {int(l): tuple(int(r) for r in rs)
                     for l, rs in info["placement"].items()}
        dialers = {int(l) for l in info["dialers"]}
        dead = {int(l) for l in info["dead"]}
        assert placement.get(rank) == ranks, \
            f"WELCOME placement {placement} does not host {ranks} at {rank}"
        peers: Dict[int, socket.socket] = {}
        listener.settimeout(1.0)
        while set(peers) != dialers:
            if time.monotonic() >= deadline:
                missing = sorted(dialers - set(peers))
                raise RuntimeError(
                    f"bootstrap_join: processes {missing} never dialed in")
            try:
                s, _ = listener.accept()
            except socket.timeout:
                continue
            s.settimeout(timeout)
            try:
                frame = frames.recv_frame(s)
            except (OSError, ValueError, pickle.UnpicklingError, EOFError):
                frame = None
            if (not isinstance(frame, tuple) or len(frame) != 2
                    or frame[0] != frames.HELLO or frame[1] not in dialers
                    or frame[1] in peers):
                s.close()        # stray connection, not an expected dialer
                continue
            peers[int(frame[1])] = _configure(s)
    finally:
        listener.close()
    return SocketTransport(rank, n_ranks, peers, local_ranks=ranks,
                           placement=placement, dead_procs=sorted(dead),
                           hb_interval=hb_interval, hb_timeout=hb_timeout,
                           **transport_kw)


def bootstrap_from_env(**kw) -> SocketTransport:
    """Rendezvous addressed entirely by ``EDAT_*`` environment variables."""
    rank = int(os.environ["EDAT_RANK"])
    n_ranks = int(os.environ["EDAT_NRANKS"])
    host, port = os.environ["EDAT_COORD"].rsplit(":", 1)
    local = os.environ.get("EDAT_LOCAL_RANKS")
    if local:
        kw.setdefault("local_ranks",
                      tuple(int(r) for r in local.split(",")))
    kw.setdefault("host", os.environ.get("EDAT_HOST", "127.0.0.1"))
    return bootstrap(rank, n_ranks, (host, int(port)), **kw)
