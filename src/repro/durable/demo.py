"""Durable work-queue demo + chaos CLI (the CI elastic-join smoke).

A deliberately simple SPMD program exercising the whole durable stack:
rank 0 fires ``items`` work events round-robin over the worker ranks on a
durable channel, workers square the payload and reply on a second durable
channel, rank 0 collects (dedup by item id — replay is at-least-once).
One worker rank can be configured to *dawdle* (``stall_rank``) so a
SIGKILL of its process reliably strands unconsumed events in the log;
the elastic replacement of that process skips the dawdling (it sees
``EDAT_JOINED`` in its environment).

CLI — run a 4-rank/2-process world, SIGKILL the worker process mid-run,
elastically replace it, and assert the converged result is identical to
an uninterrupted run with zero tasks leaked in the durable log::

    python -m repro.durable.demo --ranks 4 --procs 2 --items 48 \
        --kill 2 --replace --timeout 60

``--no-replace`` replays onto the survivors instead (no elastic join);
``--kill -1`` (default) runs without fault injection.  Exit code 0 iff
the run converged to the exact expected result with nothing pending in
the log.
"""
from __future__ import annotations

import argparse
import os
import pickle
import sys
import tempfile
import time
from typing import Dict, Optional

from repro.core.event import ANY, RANK_FAILED


def expected(items: int) -> Dict[str, int]:
    """The uninterrupted-run reference result."""
    return {"n": items, "sum": sum(i * i + 1 for i in range(items))}


def wait_for_completions(db_path: str, rank: int, n: int = 1,
                         timeout: float = 20.0) -> bool:
    """Poll the durable log until ``rank`` has ``n`` *completed* records
    (i.e. the world is bootstrapped and the rank is consuming work) or
    the timeout passes.  Chaos drivers gate their SIGKILL on this: a kill
    delivered before the victim even registers with the coordinator
    would strand the initial rendezvous, which is launcher territory —
    durable replay protects *running* worlds."""
    import sqlite3
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if os.path.exists(db_path):
            try:
                db = sqlite3.connect(db_path, timeout=1.0)
                try:
                    row = db.execute(
                        "SELECT COUNT(*) FROM records WHERE kind=? AND "
                        "dst=?", ("completed", rank)).fetchone()
                finally:
                    db.close()
                if row and int(row[0]) >= n:
                    return True
            except sqlite3.Error:
                pass   # mid-creation / locked: retry
        time.sleep(0.05)
    return False


class WorkQueue:
    """Picklable SPMD main: durable work fan-out with a result spool.

    ``stall_rank`` sleeps ``stall_s`` before each item *in its first
    incarnation only*, giving fault injection a wide window where that
    rank holds unconsumed work.  Consumers depend on ``(ANY, ...)``
    because replayed events carry the recovery coordinator's rank as
    their source (the durable-channel contract), and the collector
    dedups by item id because replay is at-least-once."""

    def __init__(self, items: int, stall_rank: Optional[int] = None,
                 stall_s: float = 0.05, out_path: Optional[str] = None):
        self.items = items
        self.stall_rank = stall_rank
        self.stall_s = stall_s
        self.out_path = out_path
        self.results: Dict[int, int] = {}

    def __getstate__(self) -> dict:
        return {"items": self.items, "stall_rank": self.stall_rank,
                "stall_s": self.stall_s, "out_path": self.out_path}

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self.results = {}

    # -- SPMD main ----------------------------------------------------------
    def __call__(self, ctx) -> None:
        ctx.submit_persistent(self._sink, deps=[(ANY, RANK_FAILED)],
                              name="wq.sink")
        if ctx.rank == 0:
            ctx.submit_persistent(self._collect, deps=[(ANY, "wq.done")],
                                  name="wq.collect")
            n_workers = max(1, ctx.n_ranks - 1)
            for i in range(self.items):
                ctx.fire(1 + i % n_workers, "wq.work", {"id": i, "x": i})
        else:
            ctx.submit_persistent(self._work, deps=[(ANY, "wq.work")],
                                  name="wq.work")

    def _work(self, ctx, events) -> None:
        d = events[0].data
        if (ctx.rank == self.stall_rank
                and not os.environ.get("EDAT_JOINED")):
            time.sleep(self.stall_s)
        ctx.fire(0, "wq.done", {"id": d["id"], "val": d["x"] * d["x"] + 1})

    def _collect(self, ctx, events) -> None:
        d = events[0].data
        self.results.setdefault(d["id"], d["val"])   # at-least-once dedup

    def _sink(self, ctx, events) -> None:
        pass   # RANK_FAILED is handled by the durable replay coordinator

    def result(self) -> Dict[str, int]:
        return {"n": len(self.results), "sum": sum(self.results.values())}

    # launcher post-run hook: spool the rank-0 result for the parent
    def _edat_finalize(self, ranks, stats) -> None:
        if self.out_path is None or 0 not in ranks:
            return
        tmp = self.out_path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(self.result(), f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, self.out_path)


def run_chaos(ranks: int = 4, procs: int = 2, items: int = 48,
              kill: int = -1, replace: bool = True,
              kill_after: float = 0.5, stall_s: float = 0.05,
              timeout: float = 60.0, workdir: Optional[str] = None,
              verbose: bool = True) -> Dict:
    """One full chaos round; returns a report dict (see keys below).

    With ``kill >= 0`` the process hosting that rank is SIGKILLed
    ``kill_after`` seconds in; with ``replace`` a replacement is launched
    mid-run and elastically joins (otherwise survivors absorb the
    replay).  The durable log lives in ``workdir`` (a fresh tempdir by
    default) and is diffed after the run: ``pending`` must be empty."""
    from repro.durable.log import SqliteLog
    from repro.net.launch import ProcessGroup

    own_dir = workdir is None
    workdir = workdir or tempfile.mkdtemp(prefix="edat_durable_demo_")
    db_path = os.path.join(workdir, "durable.sqlite")
    out_path = os.path.join(workdir, "result.pkl")
    ready_file = os.path.join(workdir, "rejoined")
    prog = WorkQueue(items, stall_rank=kill if kill >= 0 else None,
                     stall_s=stall_s, out_path=out_path)
    pg = ProcessGroup(
        ranks, prog, n_procs=procs, run_timeout=timeout, elastic=True,
        hb_interval=0.1, hb_timeout=1.0, workers_per_rank=1,
        unconsumed="ignore",
        durable={"path": db_path,
                 "join_timeout": 15.0 if (kill >= 0 and replace) else 0.0})
    pg.start()
    if kill >= 0:
        # only kill a *running* world: wait until the victim has consumed
        # at least one item, then let kill_after more seconds of work land
        wait_for_completions(db_path, rank=kill, timeout=timeout / 2)
        time.sleep(kill_after)
        pg.kill(kill)
        if replace:
            pg.respawn(kill, ready_file=ready_file)
    stats = pg.wait(check=False)
    got = None
    if os.path.exists(out_path):
        with open(out_path, "rb") as f:
            got = pickle.load(f)
    log = SqliteLog(db_path)
    pend = log.pending()
    n_fired = log.count("fired")
    n_completed = log.count("completed")
    n_replayed = log.count("replayed")
    log.close()
    want = expected(items)
    report = {
        "ok": got == want and not pend,
        "result": got, "expected": want,
        "pending": len(pend),
        "fired": n_fired, "completed": n_completed,
        "replayed": n_replayed,
        "rejoined": os.path.exists(ready_file),
        "exitcodes": pg.exitcodes(),
        "replays": (stats.get("durable") or {}).get("replays", []),
        "workdir": workdir,
    }
    if verbose:
        print(f"[repro.durable.demo] result={got} expected={want} "
              f"pending={len(pend)} replayed={n_replayed} "
              f"rejoined={report['rejoined']} ok={report['ok']}")
    if own_dir and report["ok"]:
        import shutil
        shutil.rmtree(workdir, ignore_errors=True)
    return report


def _cli(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.durable.demo",
        description="Durable work-queue chaos demo: SIGKILL a rank "
                    "process mid-run, replay its tasks (optionally onto "
                    "an elastically-joined replacement), assert the "
                    "converged result.")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--items", type=int, default=48)
    ap.add_argument("--kill", type=int, default=-1,
                    help="rank whose process to SIGKILL (-1: no fault)")
    ap.add_argument("--replace", dest="replace", action="store_true",
                    default=True,
                    help="launch an elastic replacement (default)")
    ap.add_argument("--no-replace", dest="replace", action="store_false",
                    help="replay onto survivors only")
    ap.add_argument("--kill-after", type=float, default=0.5)
    ap.add_argument("--stall", type=float, default=0.05,
                    help="per-item dawdle of the doomed rank's first "
                         "incarnation (widens the kill window)")
    ap.add_argument("--timeout", type=float, default=60.0)
    args = ap.parse_args(argv)
    report = run_chaos(ranks=args.ranks, procs=args.procs,
                       items=args.items, kill=args.kill,
                       replace=args.replace, kill_after=args.kill_after,
                       stall_s=args.stall, timeout=args.timeout)
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(_cli())
