"""Durable task log: append-only records of fired/completed events.

Two interchangeable backends behind one tiny API:

* :class:`MemoryLog` — thread-safe dicts, for in-proc runtimes and tests;
* :class:`SqliteLog` — one sqlite file in WAL mode shared by *every*
  process of a distributed Session (each process opens its own
  connection).  ``INSERT OR IGNORE`` on the ``(key, kind)`` primary key
  makes appends idempotent, so at-least-once logging never double-counts.

Records are 6-tuples ``(key, kind, eid, src, dst, blob)``:

* ``key``  — the event's idempotency key, globally unique (minted once at
  fire time; a replay re-uses the original key).  On the hot path the key
  is a cheap ``(src, dst, eid, n, tag)`` tuple; the sqlite backend
  stringifies it deterministically at write time (off the hot path), so
  the same event always lands under the same TEXT key no matter which
  process logged it;
* ``kind`` — ``"fired"`` (blob = pickled payload), ``"completed"``
  (a task consumed the event to completion), ``"replayed"`` (the recovery
  coordinator re-fired it; ``dst`` is the new target, latest wins);
* ``eid``/``src``/``dst`` — channel and endpoints.

Nothing here runs on the fire hot path: the runtime appends through a
:class:`BatchLogger`, whose dedicated writer thread drains the queue and
lands whole batches with one backend call — the same coalescing idiom as
``SocketTransport``'s per-peer writer threads.
"""
from __future__ import annotations

import os
import pickle
import sqlite3
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

# key is a str or the hot-path (src, dst, eid, n, tag) tuple; blob is
# bytes, None, or (fired records only) a raw *immutable* payload — the
# sqlite backend pickles it at write time, the in-memory backend keeps
# it raw (immutables are safe to share; replay fires them by reference)
Record = Tuple[object, str, str, int, int, Optional[bytes]]

FIRED = "fired"
COMPLETED = "completed"
REPLAYED = "replayed"

KEY_FMT = "%d>%d/%s#%d@%s"    # deterministic tuple-key stringification


def key_str(key) -> str:
    """Canonical string form of an idempotency key (identity on str)."""
    return key if type(key) is str else KEY_FMT % key


def expand(rec) -> Record:
    """Full 6-tuple record from a possibly-compact queue item.  The
    BatchLogger hot paths enqueue compact forms whose tuple key
    ``(src, dst, eid, n, tag)`` already carries the endpoints:

    * ``(key, blob)``       — fired;
    * ``(key, rank, None)`` — completed (``rank``: the consuming rank,
      which differs from the key's dst for a replayed event);
    * anything of length 6  — already a full record.

    A fourth compact form, ``(rank, [Event, ...])`` with an *int* first
    element — a whole just-consumed batch, one completion per event
    carrying an ``_dkey`` — expands to *many* records, so the backends
    unpack it in their own loops rather than here.
    """
    n = len(rec)
    if n == 2:
        key = rec[0]
        return (key, FIRED, key[2], key[0], key[1], rec[1])
    if n == 3:
        key = rec[0]
        return (key, COMPLETED, key[2], key[0], rec[1], None)
    return rec


class MemoryLog:
    """In-memory task log (single-process durability: survives rank death,
    not process death).  Thread-safe; append-idempotent like the sqlite
    backend.

    The write side is a raw journal: ``append_many`` is one C-speed
    ``list.extend`` — no per-record Python work at all while the program
    runs.  All reconciliation (keying fired/completed/replayed into
    dicts, the pending diff) is deferred to the read side, which only
    runs at replay or inspection time — never on the steady-state path.
    This is the classic journal/recovery split: pay nothing per record
    now, pay once proportional to history when a failure actually needs
    the log.  Each scan folds the journal prefix into the dicts and
    frees it, so repeated reads stay incremental; the writer also
    compacts when the raw journal passes a size threshold, so a long
    run doesn't pin every consumed Event (and its payload) forever.
    """

    kind = "memory"

    #: raw-journal records held before the writer-side compaction scan
    COMPACT_AT = 100_000

    def __init__(self):
        self._mu = threading.Lock()
        self._recs: list = []               # raw compact-or-full items
        self._fired: Dict[object, tuple] = {}
        self._done: Dict[object, tuple] = {}
        self._replayed: Dict[object, Record] = {}
        self._targets: Dict[str, set] = {}  # eid -> ranks ever targeted

    def append_many(self, records: Sequence[Record]) -> None:
        with self._mu:
            recs = self._recs
            recs.extend(records)
            if len(recs) > self.COMPACT_AT:
                self._scan_locked()

    def _scan_locked(self) -> None:
        """Fold journalled records into the keyed dicts (caller holds
        ``_mu``).  First record wins for fired/completed (append-
        idempotent, like sqlite's INSERT OR IGNORE); latest wins for
        replayed (INSERT OR REPLACE)."""
        recs = self._recs
        if not recs:
            return
        fired = self._fired
        done = self._done
        rep = self._replayed
        targets = self._targets
        for rec in recs:
            L = len(rec)
            if L == 2:
                key = rec[0]
                if type(key) is int:          # (rank, events) consumed batch
                    for ev in rec[1]:
                        k = ev.__dict__.get("_dkey")
                        if k is None:
                            # identity-keyed (reference-delivery fire): a
                            # completion only counts for a journalled fire
                            # — other channels' events flow through the
                            # same hook and must not leave ghost records
                            k = id(ev)
                            if k not in fired and k not in rep:
                                continue
                        if k not in done:
                            done[k] = (k, COMPLETED, ev.eid, ev.source,
                                       key, None)
                    continue
                # compact fired
                if key not in fired:
                    fired[key] = rec
                    targets.setdefault(key[2], set()).add(key[1])
            elif L == 3:
                key = rec[0]
                if type(key) is tuple or type(key) is str:
                    done.setdefault(key, rec)  # compact completed
                else:
                    # identity-keyed fired: (Event, dst, blob); keep the
                    # Event in the record — it pins the id against reuse
                    k = id(key)
                    if k not in fired:
                        fired[k] = rec
                        targets.setdefault(key.eid, set()).add(rec[1])
            elif rec[1] == FIRED:
                key = rec[0]
                if key not in fired:
                    fired[key] = tuple(rec)
                    targets.setdefault(rec[2], set()).add(rec[4])
            elif rec[1] == COMPLETED:
                done.setdefault(rec[0], tuple(rec))
            else:                             # latest replay target wins
                rec = tuple(rec)
                key = rec[0]
                if rec[5] is None:            # keep the fired blob
                    prev = rep.get(key)
                    src_rec = fired.get(key, prev)
                    if src_rec is not None:
                        if (len(src_rec) == 3
                                and type(src_rec[0]) is not tuple
                                and type(src_rec[0]) is not str):
                            rec = rec[:5] + (src_rec[2],)
                        else:
                            rec = rec[:5] + (expand(src_rec)[5],)
                rep[key] = rec
                targets.setdefault(rec[2], set()).add(rec[4])
        self._recs = []

    def count(self, kind: str) -> int:
        with self._mu:
            self._scan_locked()
            return len({FIRED: self._fired, COMPLETED: self._done,
                        REPLAYED: self._replayed}[kind])

    def eid_targets(self) -> Dict[str, set]:
        """Channel -> set of ranks ever targeted on it.  Replay uses this
        to redirect a dead target onto a rank known to consume the
        channel, instead of blindly round-robining over all survivors."""
        with self._mu:
            self._scan_locked()
            return {eid: set(ts) for eid, ts in self._targets.items()}

    def pending(self, rank: Optional[int] = None) -> List[Record]:
        """Fired-or-replayed records with no completion (latest target
        wins); restricted to records touching ``rank`` when given."""
        with self._mu:
            self._scan_locked()
            done = self._done
            out: Dict[object, Record] = {}
            for key, rec in self._fired.items():
                if key not in done:
                    if (len(rec) == 3 and type(rec[0]) is not tuple
                            and type(rec[0]) is not str):
                        ev = rec[0]       # identity-keyed (Event, dst, blob)
                        out[key] = (key, FIRED, ev.eid, ev.source,
                                    rec[1], rec[2])
                    else:
                        out[key] = expand(rec)
            for key, rec in self._replayed.items():
                if key not in done:
                    out[key] = rec
            recs = list(out.values())
        if rank is not None:
            recs = [r for r in recs if r[3] == rank or r[4] == rank]
        # str() keeps the order total when tuple and string keys coexist
        recs.sort(key=lambda r: str(r[0]))
        return recs

    def close(self) -> None:
        pass


class SqliteLog:
    """Sqlite-backed task log, sharable across OS processes.

    WAL journaling + a busy timeout let every rank process append
    concurrently; one connection per :class:`SqliteLog` instance, guarded
    by a lock (the batching logger is the only steady writer anyway)."""

    kind = "sqlite"

    def __init__(self, path: str, busy_timeout_s: float = 10.0):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._mu = threading.Lock()
        self._db = sqlite3.connect(path, timeout=busy_timeout_s,
                                   check_same_thread=False)
        with self._mu:
            cur = self._db
            cur.execute("PRAGMA journal_mode=WAL")
            cur.execute("PRAGMA synchronous=NORMAL")
            cur.execute(
                "CREATE TABLE IF NOT EXISTS records ("
                " key TEXT NOT NULL, kind TEXT NOT NULL,"
                " eid TEXT NOT NULL, src INTEGER NOT NULL,"
                " dst INTEGER NOT NULL, blob BLOB,"
                " PRIMARY KEY (key, kind))")
            cur.commit()

    @staticmethod
    def _canon(rec: Record) -> Record:
        """Expanded record with a TEXT key and a BLOB-safe payload:
        compact queue items are expanded, tuple keys stringified, raw
        (deferred-snapshot) payloads pickled.  Runs on the BatchLogger
        writer thread — never on the fire hot path."""
        rec = expand(rec)
        key, blob = rec[0], rec[5]
        if type(key) is str and (blob is None or type(blob) is bytes):
            return rec
        if type(key) is not str:
            key = KEY_FMT % key
        if blob is not None and type(blob) is not bytes:
            blob = pickle.dumps(blob, pickle.HIGHEST_PROTOCOL)
        return (key,) + tuple(rec[1:5]) + (blob,)

    def append_many(self, records: Sequence[Record]) -> None:
        canon = []
        for rec in records:
            if len(rec) == 2 and type(rec[0]) is int:
                # (rank, events) consumed batch: one completion per event
                # that carries an idempotency key
                rank = rec[0]
                for ev in rec[1]:
                    key = ev.__dict__.get("_dkey")
                    if key is not None:
                        canon.append((key_str(key), COMPLETED, ev.eid,
                                      ev.source, rank, None))
            else:
                canon.append(self._canon(rec))
        records = canon
        plain = [r for r in records if r[1] != REPLAYED]
        replayed = [r for r in records if r[1] == REPLAYED]
        with self._mu:
            if plain:
                self._db.executemany(
                    "INSERT OR IGNORE INTO records VALUES (?,?,?,?,?,?)",
                    plain)
            if replayed:                          # latest replay target wins
                self._db.executemany(
                    "INSERT OR REPLACE INTO records VALUES (?,?,?,?,?,?)",
                    replayed)
            self._db.commit()

    def count(self, kind: str) -> int:
        with self._mu:
            row = self._db.execute(
                "SELECT COUNT(*) FROM records WHERE kind=?",
                (kind,)).fetchone()
        return int(row[0])

    def eid_targets(self) -> Dict[str, set]:
        """See :meth:`MemoryLog.eid_targets`."""
        with self._mu:
            rows = self._db.execute(
                "SELECT DISTINCT eid, dst FROM records WHERE kind IN (?, ?)",
                (FIRED, REPLAYED)).fetchall()
        out: Dict[str, set] = {}
        for eid, dst in rows:
            out.setdefault(eid, set()).add(dst)
        return out

    def pending(self, rank: Optional[int] = None) -> List[Record]:
        """See :meth:`MemoryLog.pending` — same contract, SQL diff."""
        q = ("SELECT key, kind, eid, src, dst, blob FROM records r"
             " WHERE kind IN (?, ?) AND NOT EXISTS"
             "  (SELECT 1 FROM records c WHERE c.key = r.key"
             "   AND c.kind = ?)")
        with self._mu:
            rows = self._db.execute(q, (FIRED, REPLAYED,
                                        COMPLETED)).fetchall()
        out: Dict[str, Record] = {}
        for row in rows:                          # fired first, then replayed
            if row[1] == FIRED or row[0] not in out:
                out[row[0]] = tuple(row)
        for row in rows:
            if row[1] == REPLAYED:
                blob = out[row[0]][5] if row[5] is None else row[5]
                out[row[0]] = tuple(row[:5]) + (blob,)
        recs = list(out.values())
        if rank is not None:
            recs = [r for r in recs if r[3] == rank or r[4] == rank]
        recs.sort(key=lambda r: r[0])
        return recs

    def close(self) -> None:
        with self._mu:
            try:
                self._db.commit()
                self._db.close()
            except sqlite3.Error:
                pass


def open_log(path: Optional[str]):
    """Backend factory: a shared sqlite file when ``path`` is given, else
    the in-memory backend."""
    return SqliteLog(path) if path else MemoryLog()


class BatchLogger:
    """Off-hot-path batching appender (the ``SocketTransport`` writer-thread
    idiom): :meth:`append` only enqueues — a dedicated daemon thread drains
    the queue and lands each run of records with one ``append_many`` call.
    Batches grow naturally while a backend write is in flight, so burst
    cost is amortised and the firing task never waits on sqlite."""

    def __init__(self, log):
        self.log = log
        self._q: list = []
        self._cv = threading.Condition()
        self._busy = False           # a backend write is in flight
        self._closed = False
        self.appends = 0             # records landed in the backend
        self.batches = 0             # append_many calls
        self.queue_max = 0           # high-water of the queue, at drain time
        # THE hot path: producers call the list's C methods directly —
        # no Python frame, no lock, no notify.  The journal needs
        # bandwidth, not per-record latency: the writer self-wakes on a
        # 50ms backstop and drains whatever accumulated, so sustained
        # load lands in big batches instead of lock-stepping producer
        # and writer (a notify-per-append variant measured ~24% on the
        # fire A/B).  Only flush() — the replay coordinator's barrier —
        # wakes the writer eagerly.  A list, not a deque: the writer
        # drains with one slice + one del (both single C ops, atomic
        # under the GIL against concurrent appends) instead of a
        # per-record popleft loop.
        self.append = self._q.append
        self.append_many = self._q.extend
        self._t = threading.Thread(target=self._writer, daemon=True,
                                   name="edat-durable-log")
        self._t.start()

    def _writer(self) -> None:
        q = self._q
        while True:
            with self._cv:
                while not q and not self._closed:
                    self._cv.wait(0.05)   # flush()/close() wake it early
                if not q and self._closed:
                    return
                self._busy = True
            n = len(q)
            if n > self.queue_max:
                self.queue_max = n
            batch = q[:n]                 # appends past n are next round's
            del q[:n]
            try:
                if batch:
                    self.log.append_many(batch)
            finally:
                with self._cv:
                    self._busy = False
                    self.appends += len(batch)
                    self.batches += 1 if batch else 0
                    self._cv.notify_all()

    def flush(self, timeout: float = 10.0) -> bool:
        """Block until every record enqueued so far has landed in the
        backend (True) or the timeout passed (False)."""
        deadline = time.monotonic() + timeout
        with self._cv:
            self._cv.notify()
            while self._q or self._busy:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cv.wait(min(0.05, left))
        return True

    def close(self, timeout: float = 5.0) -> None:
        self.flush(timeout)
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._t.join(timeout)
        self.log.close()
