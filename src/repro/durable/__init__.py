"""repro.durable — durable task log, automated replay, elastic join.

Opt-in fault tolerance for any EDAT program, generalising the elastic
trainer's bespoke recovery (ROADMAP: "Durable task queue"):

* every fire on a durable channel is stamped with an idempotency key
  (``Event._dkey``) and logged *fired* through a batching writer thread;
  when a task consumes the event to completion a *completed* record
  follows;
* on ``RANK_FAILED`` a recovery coordinator (co-located with rank 0)
  diffs the log against completions and re-fires the dead rank's
  unconsumed events onto surviving ranks — or onto a replacement process
  that elastically joined the running Session (``net.bootstrap_join``);
* replay is **at-least-once**: an event consumed but SIGKILLed before
  its *completed* record flushed is re-fired, so durable consumers
  dedup by a key in the payload (see the README contract).  Replayed
  events carry the coordinator's rank as ``Event.source`` — durable
  consumers should depend on ``(ANY, eid)``, not on a pinned source.

Enable with ``Session(durable=True)`` (every user channel) or
``Channel(..., durable=True)`` (just that channel).
"""
from __future__ import annotations

import itertools
import pickle
import threading
import time
import uuid
from typing import Callable, Dict, List, Optional

from .log import (BatchLogger, COMPLETED, FIRED, MemoryLog, REPLAYED, Record,
                  SqliteLog, open_log)

__all__ = [
    "DurableState", "BatchLogger", "MemoryLog", "SqliteLog", "open_log",
    "FIRED", "COMPLETED", "REPLAYED",
]


class DurableState:
    """Per-runtime durable-mode state: the log + logger, the set of
    durable channels, and the recovery coordinator.

    One instance per :class:`~repro.core.runtime.Runtime`; in a
    distributed Session every process has one (they share the sqlite
    file) but only the process hosting rank 0 runs replay.
    """

    def __init__(self, rt, spec: Optional[dict] = None):
        spec = dict(spec or {})
        self.rt = rt
        self.eids = {str(c) for c in spec.get("channels") or ()}
        self._wcache: Dict[str, bool] = {}   # eid -> wants() verdict
        self.all = bool(spec.get("all", not self.eids))
        self.join_timeout = float(spec.get("join_timeout", 0.0))
        self.settle = float(spec.get("settle", 0.3))
        self.log = open_log(spec.get("path"))
        self.logger = BatchLogger(self.log)
        self._counter = itertools.count()
        # Distinguishes incarnations: a replacement process restarts the
        # counter for the same ranks, so bare (src,dst,eid,n) would collide.
        self._tag = uuid.uuid4().hex[:6]
        # Prebound hot-path quint for Runtime._fire's durable branch:
        # (counter next, incarnation tag, queue append, dead probe,
        # identity-keys flag).  Both transports keep rank liveness in a
        # plain in-place-mutated list, so the probe can be the list's C
        # __getitem__ instead of a Python method frame.  When the
        # transport delivers events by reference (no serialisation) and
        # the log lives in this process, the fire path skips key minting
        # entirely: the journal item carries the Event itself and the
        # object's identity is the idempotency key (see MemoryLog) —
        # explicit keys are only stamped on replayed re-fires.
        dl = getattr(rt.transport, "_dead", None)
        dead = dl.__getitem__ if type(dl) is list else rt.transport.is_dead
        idkeys = (not rt.transport.serializes) and self.log.kind == "memory"
        self._hot = (self._counter.__next__, self._tag, self.logger.append,
                     dead, idkeys)
        self._join_cv = threading.Condition()
        self._busy = 0               # live replay threads (termination veto)
        self._handled: set = set()   # dead ranks already being replayed
        self.replays: List[Dict] = []  # [{dead_rank, channel, events}, ...]
        self._replay_cbs: List[Callable] = []

    # ---------------------------------------------------------------- fire
    def wants(self, eid: str) -> bool:
        w = self._wcache.get(eid)
        if w is None:
            w = self._wcache[eid] = (
                eid in self.eids
                or (self.all and not eid.startswith("__")))
        return w

    def add_eids(self, eids) -> None:
        self.eids.update(str(e) for e in eids)
        self._wcache.clear()

    def next_key(self, src: int, dst: int, eid: str):
        """Idempotency key: a cheap tuple on the hot path (the sqlite
        backend stringifies deterministically at write time — see
        ``log.key_str``)."""
        return (src, dst, eid, next(self._counter), self._tag)

    def on_fired(self, key, eid: str, src: int, dst: int, blob) -> None:
        self.logger.append((key, FIRED, eid, src, dst, blob))

    def on_consumed(self, rank: int, events) -> None:
        """Scheduler hook: events just consumed to completion on ``rank``."""
        self.consumed_hook(rank)(events)

    def consumed_hook(self, rank: int):
        """Per-scheduler completion hook (a closure, not a bound method:
        this runs once per task, so every attribute hop it doesn't take
        matters).  It enqueues the whole just-consumed batch as one
        ``(rank, events)`` item — no per-event loop, no key extraction,
        no record tuples on the task thread; the log backends unpack
        ``Event._dkey`` per event at scan/write time instead.  The
        dead-rank guard keeps a zombie task on a simulated-dead rank
        (kill_rank lets the in-flight task finish) from logging its
        inputs completed — its output fires are dropped, so its inputs
        must stay *pending* or the in-flight item silently vanishes from
        the replay diff."""
        ap = self.logger.append
        dead = self._hot[3]
        def hook(events, _ap=ap, _dead=dead, _rank=rank):
            if not _dead(_rank):
                _ap((_rank, events))
        return hook

    # -------------------------------------------------------------- replay
    def add_replay_callback(self, fn: Callable[[int, bool, int], None]):
        """``fn(dead_rank, revived, n_events)`` runs after each replay."""
        self._replay_cbs.append(fn)

    def busy(self) -> bool:
        return self._busy > 0

    def note_rank_failed(self, dead: int) -> None:
        """Called synchronously from the failure-detection path; spawns the
        replay thread.  The ``_busy`` bump happens *before* the caller
        pokes the termination detector, so the run can't be declared
        quiescent between detection and replay."""
        if 0 not in self.rt._sched:      # coordinator lives beside rank 0
            return
        with self._join_cv:
            if dead in self._handled:
                return
            self._handled.add(dead)
            self._busy += 1
        threading.Thread(target=self._replay, args=(dead,), daemon=True,
                         name="edat-durable-replay-%d" % dead).start()

    def note_joined(self, rank: int) -> None:
        """A replacement process re-hosted ``rank``; unblock any replay
        waiting out ``join_timeout`` and re-arm failure handling for it."""
        with self._join_cv:
            self._handled.discard(rank)
            self._join_cv.notify_all()

    def _replay(self, dead: int) -> None:
        rt = self.rt
        revived = False
        try:
            self.logger.flush()
            if self.join_timeout > 0:
                deadline = time.monotonic() + self.join_timeout
                with self._join_cv:
                    while dead in self._handled:
                        left = deadline - time.monotonic()
                        if left <= 0:
                            break
                        self._join_cv.wait(min(0.1, left))
                    revived = dead not in self._handled
            if self.settle > 0:
                # Survivors' completed-batches need a beat to land in the
                # shared log before we diff it.
                time.sleep(self.settle)
            self.logger.flush()
            pend = self.log.pending(rank=dead)
            if pend:
                plan = rt._durable_plan(
                    pend, prefer=dead if revived else None,
                    targets=self.log.eid_targets())
                if plan:
                    # Journal the replay BEFORE re-firing: a record that is
                    # replayed-but-not-yet-sent when this process dies is
                    # still pending in the log, so the next replay pass
                    # re-fires it — the reverse order could send an event
                    # whose replay record never landed.
                    src0 = min(rt._sched)
                    self.logger.append_many(
                        [(key, REPLAYED, eid, src0, dst, None)
                         for key, eid, dst, _blob in plan])
                    self.logger.flush()
                    rt._durable_send(plan)
                    per_ch: Dict[str, int] = {}
                    for _key, eid, _dst, _blob in plan:
                        per_ch[eid] = per_ch.get(eid, 0) + 1
                    for eid, n in sorted(per_ch.items()):
                        self.replays.append(
                            {"dead_rank": dead, "channel": eid,
                             "events": n})
            for cb in list(self._replay_cbs):
                cb(dead, revived, len(pend))
        except Exception as exc:        # surface through the run, don't hang
            rt._durable_error(exc)
        finally:
            with self._join_cv:
                self._busy -= 1
                self._join_cv.notify_all()
            try:
                rt._poke(force=True)
            except Exception:
                pass

    # ------------------------------------------------------------- export
    def snapshot(self) -> Dict:
        return {
            "log": self.log.kind,
            "appends": self.logger.appends,
            "batches": self.logger.batches,
            "queue_max": self.logger.queue_max,
            "replays": [dict(r) for r in self.replays],
        }

    def close(self) -> None:
        self.logger.close()

    @staticmethod
    def blob(data) -> bytes:
        """Eager payload snapshot — durable payloads must pickle."""
        return pickle.dumps(data, pickle.HIGHEST_PROTOCOL)
