"""repro.insights — turn runtime counters into actionable findings.

The runtime's always-on metrics (``Session.stats()``) say *what*
happened per channel, rank, and peer; this package says *why it was
slow* and *what to change* — the Drishti-style counters-to-
recommendations pipeline, specialised to the EDAT runtime's failure
modes.  The programmer fires events and is abstracted from the
mechanism (the paper's pitch), so when a channel backpressures or a
rank straggles the mechanism has to diagnose itself.

Usage::

    from repro.insights import analyze, render

    with edat.Session(ranks=4, transport="socket") as s:
        s.run(program)
        for finding in analyze(s.stats()):
            print(finding)          # [backpressure] channel 'grad': ...

Rules (each reports the triggering numbers in its message):

* **backpressure** — a channel's delivered-but-unconsumed queue grew past
  ``backpressure_depth``: consumers are not keeping up with producers.
  Suggests raising ``max_batch_bytes`` / ``flush_interval`` (socket),
  adding ``workers_per_rank``, or throttling the producer.
* **scalar-spam** — many fires averaging a tiny payload: the per-event
  overhead dominates.  Suggests batching at the call site
  (``ctx.fire_batch`` or aggregating payloads).  A channel that trips
  this rule is skipped by the backpressure rule — the spam *is* the
  root cause of its queue depth.
* **straggler** — one rank owns a dominant share of the total quorum
  wait (time multi-dependency frames spent waiting for their last
  event, attributed to the rank that fired it).
* **chatty-no-coalesce** — coalescing was disabled while many events
  crossed sockets: every event paid a frame + syscall.
* **admission-backpressure** — a serving program fired on a
  ``backpressure`` channel: its admission queue exceeded the configured
  bound and clients were throttled.  Reported against the ``request``
  channel (the producer side that outran admission).  Suggests more
  decode slots, a lower offered rate, or a larger queue bound.
* **tasks-replayed** — the durable recovery coordinator re-fired a dead
  rank's unconsumed events (``Session(durable=True)``); one finding per
  (dead rank, channel) naming the replayed-event count.  Informational:
  the run *survived* a failure — verify results account for
  at-least-once delivery, and consider an elastic replacement
  (``Session.respawn``) if survivor load is a concern.

Machine-generated channels (``__``-prefixed eids) are exempt from the
per-channel rules.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping

__all__ = ["Finding", "analyze", "render"]


@dataclass
class Finding:
    """One rule match: which rule fired, an actionable message carrying
    the triggering numbers, and the raw numbers for programmatic use."""

    rule: str
    message: str
    data: Dict[str, Any] = field(default_factory=dict)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.message}"


def analyze(stats: Mapping[str, Any], *,
            backpressure_depth: int = 512,
            spam_fires: int = 500,
            spam_bytes_per_fire: int = 16,
            straggler_share: float = 0.5,
            straggler_min_s: float = 0.05,
            chatty_wire_events: int = 1000) -> List[Finding]:
    """Pattern-match the run's counters into a list of findings.

    ``stats`` is a ``Session.stats()`` mapping (or any dict with the
    ``channels`` / ``ranks`` / ``transport`` sections produced by
    :func:`repro.core.metrics.merge_metrics`).  Returns ``[]`` for a
    clean run — and for a run with metrics disabled, which has no
    counters to analyze.  Thresholds are keyword-tunable."""
    channels: Mapping[str, Mapping[str, int]] = stats.get("channels") or {}
    ranks: Mapping[Any, Mapping[str, Any]] = stats.get("ranks") or {}
    transport: Mapping[str, Any] = stats.get("transport") or {}
    findings: List[Finding] = []

    for eid in sorted(channels):
        if eid.startswith("__"):
            continue  # machine-generated / session-internal traffic
        ch = channels[eid]
        fires = ch.get("fires", 0)
        nbytes = ch.get("bytes", 0)
        qmax = ch.get("queued_max", 0)
        if fires >= spam_fires and nbytes <= fires * spam_bytes_per_fire:
            avg = nbytes / fires if fires else 0.0
            findings.append(Finding(
                "scalar-spam",
                f"channel {eid!r}: {fires} fires averaging {avg:.0f} B of "
                f"payload — per-event overhead dominates tiny payloads; "
                f"batch at the call site (ctx.fire_batch, or aggregate "
                f"values into one payload before firing)",
                {"eid": eid, "fires": fires, "bytes": nbytes,
                 "avg_bytes": avg}))
            # the spam is the root cause of any queue depth on this
            # channel: don't double-report it as backpressure
            continue
        if qmax >= backpressure_depth:
            if transport.get("kind") == "socket":
                hint = ("raise max_batch_bytes / flush_interval so the "
                        "writer drains larger batches, add "
                        "workers_per_rank, or throttle the producer")
            else:
                hint = "add workers_per_rank or throttle the producer"
            findings.append(Finding(
                "backpressure",
                f"channel {eid!r} backpressured: up to {qmax} events sat "
                f"delivered-but-unconsumed (fires={fires}, "
                f"deliveries={ch.get('deliveries', 0)}) — consumers are "
                f"not keeping up; {hint}",
                {"eid": eid, "queued_max": qmax, "fires": fires,
                 "deliveries": ch.get("deliveries", 0)}))

    bp = channels.get("backpressure") or {}
    bp_fires = bp.get("fires", 0)
    if bp_fires:
        req = channels.get("request") or {}
        findings.append(Finding(
            "admission-backpressure",
            f"channel 'request' outran admission: the server fired "
            f"{bp_fires} backpressure signal(s) because its admission "
            f"queue exceeded the configured bound "
            f"(requests fired={req.get('fires', 0)}, admission queue "
            f"peak={req.get('queued_max', 0)}) — clients were throttled; "
            f"add decode slots, lower the offered rate, or raise the "
            f"queue bound",
            {"eid": "request", "bp_fires": bp_fires,
             "request_fires": req.get("fires", 0),
             "queued_max": req.get("queued_max", 0)}))

    for rep in (stats.get("durable") or {}).get("replays") or ():
        eid = rep.get("channel")
        n = rep.get("events", 0)
        dead = rep.get("dead_rank")
        findings.append(Finding(
            "tasks-replayed",
            f"channel {eid!r}: {n} event(s) fired at dead rank {dead} "
            f"were replayed onto survivors by the durable recovery "
            f"coordinator — the run survived the failure; verify results "
            f"tolerate at-least-once delivery (dedup by an id in the "
            f"payload), and consider an elastic replacement "
            f"(Session.respawn) if survivor load is a concern",
            {"eid": eid, "events": n, "dead_rank": dead}))

    waits = {r: rk.get("quorum_wait_s", 0.0) for r, rk in ranks.items()}
    total_wait = sum(waits.values())
    if len(ranks) >= 3 and total_wait >= straggler_min_s:
        worst = max(waits, key=waits.get)  # type: ignore[arg-type]
        share = waits[worst] / total_wait
        if share >= straggler_share:
            findings.append(Finding(
                "straggler",
                f"rank {worst} is a straggler: {waits[worst]:.3f}s of the "
                f"{total_wait:.3f}s total quorum wait ({share:.0%}) was "
                f"spent waiting for its events to complete multi-"
                f"dependency frames — rebalance its work or overlap it "
                f"with more independent tasks",
                {"rank": worst, "wait_s": waits[worst],
                 "total_wait_s": total_wait, "share": share}))

    if (transport.get("kind") == "socket"
            and transport.get("coalesce") is False):
        n_wire = transport.get("wire_events_sent", 0)
        if n_wire >= chatty_wire_events:
            findings.append(Finding(
                "chatty-no-coalesce",
                f"{n_wire} events crossed sockets with coalescing "
                f"disabled — every event paid one frame + one syscall "
                f"({transport.get('writes', 0)} writes for "
                f"{transport.get('wire_bytes', 0)} B); enable "
                f"coalesce=True (the default) to pack many events per "
                f"syscall",
                {"wire_events_sent": n_wire,
                 "writes": transport.get("writes", 0),
                 "wire_bytes": transport.get("wire_bytes", 0)}))

    return findings


def render(findings: List[Finding]) -> str:
    """Markdown rendering of a findings list (``benchmarks/report.py``)."""
    if not findings:
        return "_no findings — the counters look healthy_\n"
    return "".join(f"- **{f.rule}** — {f.message}\n" for f in findings)
