from .trainer import EventDrivenTrainer, TrainerCfg
