"""Fault-tolerant elastic trainer coordinated by EDAT events."""

__all__ = ["EventDrivenTrainer", "QuorumCollector", "TrainerCfg",
           "distributed_train", "flatten_params",
           "load_distributed_results", "trainer_program"]


def __getattr__(name):
    # lazy: `python -m repro.runtime_dist.trainer` must be able to import
    # the package without the package importing the module first (runpy
    # double-import warning) — same pattern as repro.net / its launch CLI
    if name in __all__:
        from . import trainer
        return getattr(trainer, name)
    raise AttributeError(name)
