"""Event-driven distributed trainer: EDAT as the coordination layer.

Every JAX host is an EDAT rank.  The trainer *attaches* to any runtime via
:meth:`EventDrivenTrainer.start` — the same code runs threads-as-ranks in
one process (:meth:`EventDrivenTrainer.run`, the in-proc convenience) or
SPMD across OS processes over ``repro.net.SocketTransport``
(:func:`distributed_train`, which wraps ``edat.launch_processes``).  Each
process hosts ``transport.local_ranks`` trainer ranks; co-located ranks
exchange gradient events in-process (no socket frames), remote ranks over
the coalescing socket transport.  All inter-rank interactions are events —
the paper's model:

  * ``grad``    gradient exchange (data-parallel all-to-all of grad events;
                optionally int8-compressed), collected by a
                :class:`QuorumCollector`: K-of-N with a straggler timeout —
                bounded-staleness async DP; quorum=1.0 == synchronous DP.
  * ``ckpt``    async checkpointing: the step task fires a snapshot event
                to a persistent checkpoint task on rank 0; the write
                happens on another worker while the next step computes.
                ``ckpt_dir`` must be shared storage (all processes read it
                during recovery — process memory dies with the rank).
  * ``metric``  in-situ analytics pipeline (MONC pattern, §VI); history
                accumulates on rank 0's process.
  * ``final``   each rank ships its converged parameters to rank 0 on
                completion (the cross-process replacement for reading
                trainer state from shared memory).
  * RANK_FAILED machine-generated failure event (paper §VII).  In-proc it
                comes from ``Runtime.kill_rank``; across processes from
                the socket transport's heartbeat/EOF detector — a
                SIGKILLed process surfaces one RANK_FAILED per rank it
                hosted.  The handler sweeps *every* transport-dead rank
                out of the alive set in one go (so a multi-rank process
                death triggers exactly one coordinated recovery), then the
                leader broadcasts ``recover``: survivors roll back to the
                last durable checkpoint, re-shard the data stream
                (elastic), and continue.

The trainer is deliberately pure data-parallel at the EDAT level; inside a
rank the step is a jitted JAX function (which on a real pod is itself
pjit-sharded — see launch/).  The jitted functions are shared by all
co-located rank threads of a process.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import edat
from repro import checkpoint as ckpt_store
from repro.data import DataCfg, SyntheticLM
from repro.optim import OptCfg, make_optimizer


@dataclasses.dataclass
class TrainerCfg:
    steps: int = 20
    n_ranks: int = 2
    workers_per_rank: int = 2
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    quorum: float = 1.0          # fraction of alive ranks' grads required
    collect_timeout: float = 10.0  # straggler bound (s)
    stale_discount: float = 0.5  # weight applied to late gradient events
    compress: str = "none"       # none | int8
    seed: int = 0
    start_step: int = 0          # resume support
    # heartbeat failure detector (timer events, paper §VII): 0 = off.
    # A rank silent for hb_timeout is *suspected*: survivors treat it as
    # failed (roll back + re-shard); the suspect fences itself on waking.
    # (Across processes the socket transport's own heartbeat detector
    # additionally catches dead *processes* regardless of this knob.)
    hb_interval: float = 0.0
    hb_timeout: float = 3.0
    # test hook: {rank: (step, seconds)} injected stall
    stall: Optional[Dict[int, tuple]] = None


# ------------------------------------------------------- gradient payloads
def _q8_tree(tree):
    def q(x):
        x = np.asarray(x, np.float32)
        amax = float(np.max(np.abs(x))) + 1e-12
        return (np.round(x / amax * 127.0).astype(np.int8), amax)
    return jax.tree.map(q, tree)


def _dq8_tree(tree):
    def dq(leaf):
        q, amax = leaf
        return q.astype(np.float32) * (amax / 127.0)
    return jax.tree.map(dq, tree, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[1], float))


def flatten_params(tree) -> Dict[str, np.ndarray]:
    """Flatten a parameter tree to ``{path: numpy array}`` — the on-disk
    form of the distributed trainer's final parameters, and the common
    currency for comparing trainers across transports."""
    flat = ckpt_store.store._flatten(jax.tree.map(np.asarray, tree))
    return {k.lstrip("/"): v for k, v in flat.items()}


# ----------------------------------------------------------- quorum logic
class QuorumCollector:
    """K-of-N gradient quorum with bounded-staleness fold-in.

    Pure accumulation logic, factored out of the step task so it can be
    property-tested directly: ``offer`` payloads in *any* arrival order,
    and :meth:`reduce` yields the weighted mean

        (sum(fresh) + discount * sum(stale)) / (n_fresh + discount*n_stale)

    independent of that order (fresh gradients fold in ascending rank
    order, stale ones in ascending (step, rank) order, so the
    floating-point result is deterministic).

    * a payload from the collector's epoch at exactly ``step`` is *fresh*;
    * an earlier step from the same epoch is *stale* (discounted fold-in,
      the bounded-staleness rule);
    * other epochs (pre-recovery leftovers) and future steps are ignored.
    """

    def __init__(self, *, step: int, epoch: int, need: int,
                 stale_discount: float,
                 unpack: Callable[[Any], Any] = lambda g: g):
        self.step = step
        self.epoch = epoch
        self.need = need
        self.stale_discount = stale_discount
        self.unpack = unpack
        self.got: Dict[int, Any] = {}
        self.stale: List[tuple] = []    # (step, rank, grads)

    def offer(self, payload: Dict[str, Any]) -> bool:
        """Consider one grad-event payload; True iff it was accepted."""
        if payload["epoch"] != self.epoch:
            return False
        if payload["step"] == self.step:
            self.got[payload["rank"]] = self.unpack(payload["grads"])
            return True
        if payload["step"] < self.step:
            self.stale.append((payload["step"], payload["rank"],
                               self.unpack(payload["grads"])))
            return True
        return False

    @property
    def complete(self) -> bool:
        return len(self.got) >= self.need

    def ensure_own(self, rank: int, grads) -> None:
        """Own grads must participate even if the loopback event lost a
        race with the timeout (no-op when already collected)."""
        self.got.setdefault(rank, grads)

    def reduce(self):
        """Weighted mean over fresh + discounted stale gradients.
        Returns ``(gavg, n_fresh, n_stale)``; ``gavg`` leaves are jnp."""
        gsum = None
        weight = 0.0
        for r in sorted(self.got):      # deterministic fold order
            g = self.got[r]
            gsum = g if gsum is None else jax.tree.map(np.add, gsum, g)
            weight += 1.0
        for _, _, g in sorted(self.stale,   # bounded staleness: discounted,
                              key=lambda t: t[:2]):   # deterministic order
            gsum = jax.tree.map(
                lambda a, b: a + self.stale_discount * b, gsum, g)
            weight += self.stale_discount
        gavg = jax.tree.map(lambda x: jnp.asarray(x / weight), gsum)
        return gavg, len(self.got), len(self.stale)


class _RankState:
    def __init__(self, rank):
        self.rank = rank
        self.mu = threading.Lock()  # serialises commit vs recovery rollback
        self.params = None
        self.opt_state = None
        self.step = 0
        self.epoch = 0            # bumped on every recovery
        self.alive: List[int] = []
        self.done = False
        self.stepping = False     # exactly one live step chain per rank
        self.chain_dropped = None # epoch of a "go" token eaten by the flag
        self.hb_mute = False      # test hook: simulated hang
        self.stale_used = 0
        self.timeouts = 0


class EventDrivenTrainer:
    """Elastic data-parallel trainer coordinated purely by EDAT events.

    One instance serves every rank of its process: :meth:`start` is the
    SPMD attach point (called once per local rank by ``Runtime.run``),
    :meth:`run` the in-proc convenience that owns a threads-as-ranks
    runtime.  State that crosses ranks does so *only* via events — the
    instance keeps per-rank state for the ranks it hosts, rank 0's
    process additionally accumulating ``history`` (metric events),
    ``final_params`` (final events) and ``recoveries``."""

    def __init__(self, model, data_cfg: DataCfg, opt_cfg: OptCfg,
                 cfg: TrainerCfg):
        self.model = model
        self.data = SyntheticLM(data_cfg)
        self.opt = make_optimizer(opt_cfg)
        self.cfg = cfg
        self.history: List[Dict[str, Any]] = []
        self._hist_mu = threading.Lock()
        self.states = [_RankState(r) for r in range(cfg.n_ranks)]
        self.runtime: Optional[edat.Runtime] = None
        self.ckpt_writes = 0
        #: rollbacks executed by local ranks: {"rank", "step", "epoch"}
        self.recoveries: List[Dict[str, int]] = []
        #: rank -> final parameter tree, gathered on rank 0's process
        self.final_params: Dict[int, Any] = {}
        #: called (on rank 0's process) with each rank's final payload
        self.on_final: Optional[Callable[[Dict[str, Any]], None]] = None
        #: called (on rank 0's process) after each metric is recorded
        self.on_metric: Optional[Callable[[Dict[str, Any]], None]] = None

        # jitted per-host functions (shared across co-located rank threads)
        def loss_fn(p, batch):
            loss, m = model.loss(p, batch)
            return loss, m

        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        def apply_fn(params, opt_state, grads, step):
            return self.opt.update(grads, opt_state, params, step)

        self._apply_fn = jax.jit(apply_fn)

    # ----------------------------------------------------------- event glue
    def _pack_grads(self, grads):
        host = jax.tree.map(np.asarray, grads)
        if self.cfg.compress == "int8":
            return _q8_tree(host)
        return host

    def _unpack_grads(self, payload):
        if self.cfg.compress == "int8":
            return _dq8_tree(payload)
        return payload

    # ------------------------------------------------------------ main SPMD
    def run(self, timeout: float = 300.0) -> Dict[str, Any]:
        """In-proc convenience: all ranks as threads in one Runtime."""
        cfg = self.cfg
        rt = edat.Runtime(cfg.n_ranks, workers_per_rank=cfg.workers_per_rank,
                          unconsumed="ignore")
        self.runtime = rt
        rt.run(self.start, timeout=timeout)
        return {
            "history": sorted(self.history, key=lambda m: m["step"]),
            "final_params": [s.params for s in self.states],
            "final_by_rank": dict(self.final_params),
            "recoveries": list(self.recoveries),
            "stale_used": sum(s.stale_used for s in self.states),
            "timeouts": sum(s.timeouts for s in self.states),
            "ckpt_writes": self.ckpt_writes,
        }

    def _init_state(self, st: _RankState):
        cfg = self.cfg
        st.params = self.model.init(jax.random.PRNGKey(cfg.seed))
        st.opt_state = self.opt.init(st.params)
        st.step = cfg.start_step
        st.alive = list(range(cfg.n_ranks))
        if cfg.ckpt_dir and cfg.start_step > 0:
            proto = {"params": st.params, "opt": st.opt_state}
            step, tree, _ = ckpt_store.restore(cfg.ckpt_dir, proto)
            st.params = jax.tree.map(jnp.asarray, tree["params"])
            st.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            st.step = step

    def start(self, ctx: edat.Context) -> None:
        """Attach one rank of the trainer to any (in-proc or distributed)
        runtime: initialise that rank's replica, submit its persistent
        tasks, and fire the first chain token.  Rank 0 (wherever its
        process lives) additionally hosts the metric/checkpoint/final
        collectors and the heartbeat monitor."""
        cfg = self.cfg
        self.runtime = ctx._rt
        st = self.states[ctx.rank]
        self._init_state(st)

        # persistent tasks: the step engine, failure handling, recovery
        ctx.submit_persistent(self._step_task, deps=[(edat.SELF, "go")],
                              name="step")
        ctx.submit_persistent(self._on_rank_failed,
                              deps=[(edat.ANY, edat.RANK_FAILED)],
                              name="faildet")
        ctx.submit_persistent(self._on_recover, deps=[(edat.ANY, "recover")],
                              name="recover")
        if ctx.rank == 0:
            ctx.submit_persistent(self._metric_task,
                                  deps=[(edat.ANY, "metric")], name="metrics")
            ctx.submit_persistent(self._final_task,
                                  deps=[(edat.ANY, "final")], name="final")
            if cfg.ckpt_dir:
                ctx.submit_persistent(self._ckpt_task,
                                      deps=[(edat.SELF, "ckpt")], name="ckpt")
            if cfg.hb_interval > 0:
                self._hb_seen = {r: time.monotonic()
                                 for r in range(cfg.n_ranks)}
                self._hb_done: set = set()
                ctx.submit_persistent(self._hb_monitor,
                                      deps=[(edat.SELF, "__hbtick")],
                                      name="hbmon")
                ctx.fire_after(cfg.hb_interval, edat.SELF, "__hbtick")
        if cfg.hb_interval > 0:
            ctx.submit_persistent(self._on_suspect,
                                  deps=[(edat.ANY, "suspect")],
                                  name="suspect")
            # heartbeat pump: timer-driven, independent of the step task
            # (a jit compile or long step must NOT look like a hang)
            ctx.submit_persistent(self._hb_pump,
                                  deps=[(edat.SELF, "__hbself")],
                                  name="hbpump")
            ctx.fire_after(cfg.hb_interval / 2, edat.SELF, "__hbself")
        # durable initial checkpoint: the recovery anchor
        if ctx.rank == 0 and cfg.ckpt_dir and cfg.start_step == 0:
            snap = {"params": jax.tree.map(np.asarray, st.params),
                    "opt": jax.tree.map(np.asarray, st.opt_state)}
            ckpt_store.save(cfg.ckpt_dir, st.step, snap)
        ctx.fire(edat.SELF, "go")

    # ---------------------------------------------------------------- tasks
    def _step_task(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if st.done or self.runtime.is_dead(ctx.rank):
            return
        token = events[0].data     # chain token: the epoch it was fired for
        with st.mu:
            if token is not None and token != st.epoch:
                return             # stale chain token from before a recovery
            if st.stepping:
                # a duplicate "go" (e.g. two recoveries racing): exactly one
                # step chain may run per rank, or concurrent instances would
                # steal each other's grad events and diverge the replicas.
                # Remember the eaten token so the running instance can revive
                # the chain when it exits.
                st.chain_dropped = st.epoch
                return
            st.stepping = True
        again = False
        try:
            again = self._step_body(ctx, st)
        finally:
            with st.mu:
                st.stepping = False
                revive = (st.chain_dropped is not None
                          and st.chain_dropped == st.epoch and not st.done)
                st.chain_dropped = None
                epoch_now = st.epoch
        if again or revive:
            ctx.fire(edat.SELF, "go", epoch_now)

    def _step_body(self, ctx: edat.Context, st: "_RankState") -> bool:
        """One training step.  Returns True iff the chain should continue
        (the caller fires the next "go" after releasing the chain flag)."""
        cfg = self.cfg
        if cfg.stall and ctx.rank in cfg.stall:
            at, secs = cfg.stall[ctx.rank]
            if st.step == at:
                st.hb_mute = True    # a true hang silences the pump too
                time.sleep(secs)     # injected hang (straggler simulation)
                st.hb_mute = False
        epoch = st.epoch
        alive = sorted(st.alive)
        if ctx.rank not in alive:    # fenced while stalled
            st.done = True
            return False
        shard = alive.index(ctx.rank)
        batch = self.data.batch(st.step, shard, len(alive))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, metrics), grads = self._grad_fn(st.params, batch)

        payload = {"rank": ctx.rank, "step": st.step, "epoch": epoch,
                   "grads": self._pack_grads(grads)}
        # ref=True: the packed tree is freshly materialised and never
        # mutated — co-located ranks share it in-process, remote ranks get
        # the zero-copy out-of-band encode
        ctx.fire(edat.ALL, "grad", payload, ref=True)

        # K-of-N quorum collection with straggler timeout (async DP)
        coll = QuorumCollector(
            step=st.step, epoch=epoch,
            need=max(1, int(np.ceil(cfg.quorum * len(alive)))),
            stale_discount=cfg.stale_discount, unpack=self._unpack_grads)
        deadline = time.monotonic() + cfg.collect_timeout
        while not coll.complete:
            if st.epoch != epoch or st.done:
                # recovery happened under us: abandon this step; the
                # recovery's own chain token (re)starts the stepping
                return False
            evs = ctx.retrieve_any([(edat.ANY, "grad")])
            for ev in evs:
                coll.offer(ev.data)
            if not evs:
                if time.monotonic() > deadline:
                    st.timeouts += 1
                    break
                time.sleep(0.002)
        coll.ensure_own(ctx.rank, jax.tree.map(np.asarray, grads))
        gavg, n_got, n_stale = coll.reduce()
        st.stale_used += n_stale

        snap = None
        with st.mu:
            if st.epoch != epoch or st.done:
                # a rollback landed after collection: committing now would
                # silently clobber the restored checkpoint state
                return False
            st.params, st.opt_state, om = self._apply_fn(
                st.params, st.opt_state, gavg, jnp.asarray(st.step))
            st.step += 1
            step_now = st.step
            if (cfg.ckpt_dir and ctx.rank == min(alive)
                    and step_now % cfg.ckpt_every == 0):
                snap = {"params": jax.tree.map(np.asarray, st.params),
                        "opt": jax.tree.map(np.asarray, st.opt_state)}
            if step_now >= cfg.steps:
                st.done = True

        ctx.fire(0, "metric", {"rank": ctx.rank, "step": step_now,
                               "loss": float(loss),
                               "n_grads": n_got, "n_stale": n_stale})
        if snap is not None:
            ctx.fire(0, "ckpt", {"step": step_now, "snap": snap}, ref=True)

        if step_now < cfg.steps:
            return True
        # trained to completion: ship the converged replica to rank 0
        ctx.fire(0, "final",
                 {"rank": ctx.rank, "step": step_now,
                  "params": jax.tree.map(np.asarray, st.params)}, ref=True)
        if cfg.hb_interval > 0:
            ctx.fire(0, "__hbdone", ctx.rank)
        return False

    def _ckpt_task(self, ctx: edat.Context, events):
        p = events[0].data
        ckpt_store.save(self.cfg.ckpt_dir, p["step"], p["snap"])
        self.ckpt_writes += 1

    def _metric_task(self, ctx: edat.Context, events):
        with self._hist_mu:
            self.history.append(events[0].data)
        hook = self.on_metric
        if hook is not None:
            hook(events[0].data)

    def _final_task(self, ctx: edat.Context, events):
        """Rank 0: collect each rank's converged parameters (ranks that
        die or get fenced never report — elastic by construction)."""
        p = events[0].data
        with self._hist_mu:
            self.final_params[p["rank"]] = p["params"]
        hook = self.on_final
        if hook is not None:
            hook(p)

    def _hb_pump(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if st.done or self.runtime.is_dead(ctx.rank):
            return                   # stop beating; timer chain ends
        if not st.hb_mute:
            ctx.fire(0, "hb", ctx.rank)
        ctx.fire_after(self.cfg.hb_interval / 2, edat.SELF, "__hbself")

    def _hb_monitor(self, ctx: edat.Context, events):
        """Timer-driven failure detector on rank 0 (paper §VII: machine
        generated events drive tasks).  Reads only rank-0-local state plus
        delivered hb/__hbdone events — it never peeks at other ranks'
        memory, so it works unchanged across processes."""
        cfg = self.cfg
        st = self.states[ctx.rank]
        now = time.monotonic()
        for ev in ctx.retrieve_any([(edat.ANY, "hb")] * (4 * cfg.n_ranks)):
            self._hb_seen[ev.data] = now
        for ev in ctx.retrieve_any([(edat.ANY, "__hbdone")] * cfg.n_ranks):
            self._hb_done.add(ev.data)
        suspects = [r for r in sorted(st.alive)
                    if r not in self._hb_done
                    and now - self._hb_seen.get(r, now) > cfg.hb_timeout]
        for r in suspects:
            ctx.fire(edat.ALL, "suspect", r)
        active = [r for r in st.alive
                  if r not in self._hb_done and r not in suspects
                  and not self.runtime.is_dead(r)]
        if active:
            ctx.fire_after(cfg.hb_interval, edat.SELF, "__hbtick")

    def _on_suspect(self, ctx: edat.Context, events):
        suspected = events[0].data
        st = self.states[ctx.rank]
        if suspected == ctx.rank:
            st.done = True          # fence myself: fail-stop enforcement
            return
        with st.mu:
            if suspected not in st.alive:
                return
            st.alive.remove(suspected)
            lead = st.alive and ctx.rank == min(st.alive)
        if ctx.rank == 0:
            self._hb_done.add(suspected)
        if lead and self.cfg.ckpt_dir:
            step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
            ctx.fire(edat.ALL, "recover", {"step": step})

    def _on_rank_failed(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        dead = events[0].data
        with st.mu:
            if dead not in st.alive:
                # already handled: the heartbeat-suspect path beat this
                # event, or an earlier RANK_FAILED's sweep took it (one
                # SIGKILLed process surfaces one event per hosted rank).
                # Re-firing "recover" here was the known duplicate-recovery
                # flake — two rollbacks racing the restarted step chain
                # could diverge the replicas.
                return
            # process-granularity sweep: every rank the transport already
            # knows to be dead leaves `alive` NOW, so a multi-rank process
            # death triggers exactly one coordinated recovery instead of
            # one per hosted rank.
            swept = [d for d in list(st.alive)
                     if d != ctx.rank and (d == dead
                                           or self.runtime.is_dead(d))]
            for d in swept:
                st.alive.remove(d)
            lead = st.alive and ctx.rank == min(st.alive)
        # leader triggers a coordinated rollback to the last durable ckpt
        if lead and self.cfg.ckpt_dir:
            step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
            ctx.fire(edat.ALL, "recover", {"step": step})

    def _on_recover(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if self.runtime.is_dead(ctx.rank) or st.done:
            return
        info = events[0].data
        cfg = self.cfg
        proto = {"params": st.params, "opt": st.opt_state}
        try:
            step, tree, _ = ckpt_store.restore(cfg.ckpt_dir, proto,
                                               step=info["step"])
        except FileNotFoundError:
            return
        with st.mu:
            st.params = jax.tree.map(jnp.asarray, tree["params"])
            st.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            st.step = step
            st.epoch += 1        # invalidates in-flight grads
            epoch_now = st.epoch
        with self._hist_mu:
            self.recoveries.append({"rank": ctx.rank, "step": step,
                                    "epoch": epoch_now})
        ctx.fire(edat.SELF, "go", epoch_now)


# ------------------------------------------------- distributed (processes)
_SPAWN_MU = threading.Lock()
_SPAWN_TRAINER: Optional[EventDrivenTrainer] = None


def _write_json(path: str, obj) -> None:
    # unique temp name: concurrent final events (one per finishing rank,
    # possibly on different workers) must not steal each other's rename
    import tempfile
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _attach_savers(trainer: EventDrivenTrainer, out_dir: str) -> None:
    """Persistence hooks for spawned runs (rank-0's process): every final
    event writes that rank's params as a flat .npz, and every metric OR
    final event rewrites history/recoveries.  The metric-side rewrite
    matters: _metric_task and _final_task are independent persistent
    tasks, so with >1 worker a rank's final can execute before its last
    metric — the metric's own rewrite then repairs the file.  Metrics
    only trigger a rewrite once finals have started (the repair window):
    the steady-state training path stays free of per-step file I/O."""
    def write_logs() -> None:
        with trainer._hist_mu:
            hist = sorted(trainer.history, key=lambda m: m["step"])
            rec = list(trainer.recoveries)
        _write_json(os.path.join(out_dir, "history.json"), hist)
        _write_json(os.path.join(out_dir, "recoveries.json"), rec)

    def on_final(p: Dict[str, Any]) -> None:
        np.savez(os.path.join(out_dir, f"final_rank{p['rank']}.npz"),
                 step=np.int64(p["step"]), **flatten_params(p["params"]))
        write_logs()

    def on_metric(_m: Dict[str, Any]) -> None:
        if trainer.final_params:
            write_logs()

    trainer.on_final = on_final
    trainer.on_metric = on_metric


def _spawned_trainer_main(ctx: edat.Context, *, model_cfg, data_cfg,
                          opt_cfg, trainer_cfg,
                          out_dir: Optional[str] = None) -> None:
    """SPMD entry point for ``edat.launch_processes``: one shared
    :class:`EventDrivenTrainer` per process (built lazily by whichever
    local rank thread arrives first), attached per rank.  The process
    hosting rank 0 persists history/recoveries/final params to
    ``out_dir`` as they arrive, so the launcher parent can read the
    results even though the trainer object dies with the child."""
    global _SPAWN_TRAINER
    with _SPAWN_MU:
        tr = _SPAWN_TRAINER
        if tr is None:
            from repro.models import build_model
            model = build_model(model_cfg)
            tr = EventDrivenTrainer(model, data_cfg, opt_cfg, trainer_cfg)
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                _attach_savers(tr, out_dir)
            _SPAWN_TRAINER = tr
    tr.start(ctx)


def load_distributed_results(out_dir: str) -> Dict[str, Any]:
    """Read what a spawned trainer run left in ``out_dir``: ``history``,
    ``recoveries``, and ``final_params`` ({rank: {path: array}})."""
    out: Dict[str, Any] = {"history": [], "recoveries": [],
                           "final_params": {}}
    hist = os.path.join(out_dir, "history.json")
    if os.path.exists(hist):
        with open(hist) as f:
            out["history"] = json.load(f)
    rec = os.path.join(out_dir, "recoveries.json")
    if os.path.exists(rec):
        with open(rec) as f:
            out["recoveries"] = json.load(f)
    for name in sorted(os.listdir(out_dir)):
        if name.startswith("final_rank") and name.endswith(".npz"):
            r = int(name[len("final_rank"):-len(".npz")])
            with np.load(os.path.join(out_dir, name)) as z:
                out["final_params"][r] = {k: z[k] for k in z.files
                                          if k != "step"}
    return out


def distributed_train(n_ranks: int, model_cfg, data_cfg, opt_cfg,
                      trainer_cfg: TrainerCfg, *,
                      n_procs: Optional[int] = None,
                      timeout: float = 300.0,
                      out_dir: Optional[str] = None,
                      **launch_kwargs) -> Dict[str, Any]:
    """Run the elastic trainer SPMD across OS processes over
    ``SocketTransport`` and return ``{"history", "recoveries",
    "final_params", "stats"}``.  ``n_procs`` packs several ranks per
    process (co-located gradient exchange stays in-process); the model is
    rebuilt from ``model_cfg`` inside each child.  ``trainer_cfg.ckpt_dir``
    must be on storage every process can reach — it is both the async
    checkpoint sink and the recovery source when a process dies.  Extra
    kwargs go to :func:`repro.net.launch.launch_processes` (e.g.
    ``hb_interval``, ``hb_timeout``, ``check``)."""
    import functools
    import tempfile
    from repro.net.launch import launch_processes

    cfg = dataclasses.replace(trainer_cfg, n_ranks=n_ranks)
    own_tmp = out_dir is None
    if own_tmp:
        tmp_ctx = tempfile.TemporaryDirectory(prefix="edat_train_out_")
        out_dir = tmp_ctx.name
    try:
        stats = launch_processes(
            n_ranks,
            functools.partial(_spawned_trainer_main, model_cfg=model_cfg,
                              data_cfg=data_cfg, opt_cfg=opt_cfg,
                              trainer_cfg=cfg, out_dir=out_dir),
            timeout=timeout, n_procs=n_procs,
            workers_per_rank=cfg.workers_per_rank, unconsumed="ignore",
            **launch_kwargs)
        res = load_distributed_results(out_dir)
        res["stats"] = stats
        return res
    finally:
        if own_tmp:
            tmp_ctx.cleanup()


# ------------------------------------------------------ module-level main
def _demo_cfgs(n_ranks: int, steps: int, ckpt_dir: Optional[str],
               ckpt_every: int = 4):
    """Small default model/data/opt/trainer configs for the CLI and the
    ``repro.net.launch`` module-spec entry point."""
    from repro.models import ModelCfg
    model_cfg = ModelCfg(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
        dtype="float32", remat="none", max_target_length=64)
    data_cfg = DataCfg(vocab=128, seq=32, global_batch=12, seed=7)
    opt_cfg = OptCfg(name="adamw", peak_lr=3e-2, warmup=5, total_steps=200,
                     clip_norm=1.0)
    trainer_cfg = TrainerCfg(steps=steps, n_ranks=n_ranks,
                             ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                             collect_timeout=60.0)
    return model_cfg, data_cfg, opt_cfg, trainer_cfg


def main(ctx: edat.Context) -> None:
    """Module-level SPMD main, runnable as::

        python -m repro.net.launch -n 4 --procs 2 --unconsumed ignore \\
            repro.runtime_dist.trainer:main

    Configured by environment (shared across the launched processes):
    ``EDAT_TRAIN_STEPS`` (default 8), ``EDAT_TRAIN_CKPT_EVERY`` (4), and
    ``EDAT_TRAIN_CKPT`` — the shared checkpoint/result directory (default:
    a temp path derived from the coordinator address, which every process
    of one launch shares)."""
    import tempfile
    steps = int(os.environ.get("EDAT_TRAIN_STEPS", "8"))
    every = int(os.environ.get("EDAT_TRAIN_CKPT_EVERY", "4"))
    base = os.environ.get("EDAT_TRAIN_CKPT")
    if not base:
        # EDAT_LAUNCH_ID is unique per launch (a reused coordinator port
        # must not resurrect a previous run's checkpoints); the coord
        # address is the fallback for externally-managed process groups
        tag = (os.environ.get("EDAT_LAUNCH_ID")
               or os.environ.get("EDAT_COORD", "local").replace(":", "_"))
        base = os.path.join(tempfile.gettempdir(), f"edat_trainer_{tag}")
    model_cfg, data_cfg, opt_cfg, trainer_cfg = _demo_cfgs(
        ctx.n_ranks, steps, os.path.join(base, "ckpt"), every)
    _spawned_trainer_main(ctx, model_cfg=model_cfg, data_cfg=data_cfg,
                          opt_cfg=opt_cfg, trainer_cfg=trainer_cfg,
                          out_dir=os.path.join(base, "out"))


def _cli(argv=None) -> int:
    """Distributed-trainer smoke: spawn ranks over SocketTransport,
    optionally SIGKILL one process mid-training, and verify elastic
    recovery — CI runs this with ``--kill``."""
    import argparse
    import tempfile
    from repro.checkpoint import latest_step
    from repro.net.launch import ProcessGroup
    import functools

    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime_dist.trainer",
        description="Distributed elastic trainer smoke test.")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--kill", action="store_true",
                    help="SIGKILL the last process once the first real "
                         "checkpoint exists; survivors must recover and "
                         "finish")
    ap.add_argument("--timeout", type=float, default=240.0)
    a = ap.parse_args(argv)

    with tempfile.TemporaryDirectory(prefix="edat_trainer_smoke_") as td:
        ckdir = os.path.join(td, "ck")
        outdir = os.path.join(td, "out")
        os.makedirs(outdir)
        model_cfg, data_cfg, opt_cfg, trainer_cfg = _demo_cfgs(
            a.ranks, a.steps, ckdir, a.ckpt_every)
        pg = ProcessGroup(
            a.ranks,
            functools.partial(_spawned_trainer_main, model_cfg=model_cfg,
                              data_cfg=data_cfg, opt_cfg=opt_cfg,
                              trainer_cfg=trainer_cfg, out_dir=outdir),
            n_procs=a.procs, run_timeout=a.timeout,
            workers_per_rank=trainer_cfg.workers_per_rank,
            unconsumed="ignore", hb_interval=0.2, hb_timeout=1.5)
        pg.start()
        if a.kill:
            deadline = time.monotonic() + a.timeout
            while ((latest_step(ckdir) or 0) < a.ckpt_every
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            got = latest_step(ckdir) or 0
            if got < a.ckpt_every:
                pg.wait(5, check=False)
                print(f"smoke FAILED: no checkpoint appeared (latest={got})")
                return 1
            pg.kill(a.ranks - 1)
            print(f"[smoke] killed the process hosting rank {a.ranks - 1} "
                  f"at checkpoint step {got}")
        pg.wait(a.timeout, check=not a.kill)
        res = load_distributed_results(outdir)
        top = max((m["step"] for m in res["history"]), default=0)
        print(f"[smoke] steps reached: {top}/{a.steps}; "
              f"recoveries: {res['recoveries']}; "
              f"finals from ranks {sorted(res['final_params'])}")
        if top < a.steps:
            print("smoke FAILED: training did not reach the target step")
            return 1
        if a.kill and not res["recoveries"]:
            print("smoke FAILED: no elastic recovery was recorded")
            return 1
        if a.kill:
            survivors = set(range(a.ranks)) - set(
                pg._proc_of(a.ranks - 1)[1])
            if not survivors.issubset(set(res["final_params"])):
                print(f"smoke FAILED: missing finals "
                      f"{survivors - set(res['final_params'])}")
                return 1
        print("[smoke] OK")
        return 0


if __name__ == "__main__":
    import sys
    sys.exit(_cli())
