"""Event-driven distributed trainer: EDAT as the coordination layer.

Every JAX host is an EDAT rank.  The trainer is a v2 ``edat.Program``:
it declares its typed event channels, *attaches* to any runtime via
:meth:`EventDrivenTrainer.start`, and reports gathered results through
:meth:`EventDrivenTrainer.result` — the same code runs threads-as-ranks
in one process (:meth:`EventDrivenTrainer.run`, the in-proc convenience)
or SPMD across OS processes::

    res = edat.run(edat.deferred(trainer_program, model_cfg, data_cfg,
                                 opt_cfg, trainer_cfg),
                   ranks=4, procs=2, transport="socket",
                   unconsumed="ignore")

(``edat.deferred`` builds one shared trainer per spawned process —
co-located rank threads share the jitted step functions.)  Each
process hosts ``transport.local_ranks`` trainer ranks; co-located ranks
exchange gradient events in-process (no socket frames), remote ranks over
the coalescing socket transport.  All inter-rank interactions are events —
the paper's model:

  * ``grad``    gradient exchange (data-parallel all-to-all of grad events;
                optionally int8-compressed), collected by a
                :class:`QuorumCollector`: K-of-N with a straggler timeout —
                bounded-staleness async DP; quorum=1.0 == synchronous DP.
  * ``ckpt``    async checkpointing: the step task fires a snapshot event
                to a persistent checkpoint task on rank 0; the write
                happens on another worker while the next step computes.
                ``ckpt_dir`` must be shared storage (all processes read it
                during recovery — process memory dies with the rank).
  * ``metric``  in-situ analytics pipeline (MONC pattern, §VI); history
                accumulates on rank 0's process.
  * ``final``   each rank ships its converged parameters to rank 0 on
                completion (the cross-process replacement for reading
                trainer state from shared memory).
  * RANK_FAILED machine-generated failure event (paper §VII).  In-proc it
                comes from ``Runtime.kill_rank``; across processes from
                the socket transport's heartbeat/EOF detector — a
                SIGKILLed process surfaces one RANK_FAILED per rank it
                hosted.  The handler sweeps *every* transport-dead rank
                out of the alive set in one go (so a multi-rank process
                death triggers exactly one coordinated recovery), then the
                leader broadcasts ``recover``: survivors roll back to the
                last durable checkpoint, re-shard the data stream
                (elastic), and continue.  Under a durable-mode runtime
                (``Session(durable=True)``, :mod:`repro.durable`) that
                broadcast instead comes from the replay coordinator's
                callback, after the dead rank's logged events are
                re-homed — same rollback, coordinated ordering.

The trainer is deliberately pure data-parallel at the EDAT level; inside a
rank the step is a jitted JAX function (which on a real pod is itself
pjit-sharded — see launch/).  The jitted functions are shared by all
co-located rank threads of a process.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import edat
from repro import checkpoint as ckpt_store
from repro.core.deprecation import warn_deprecated
from repro.data import DataCfg, SyntheticLM
from repro.optim import OptCfg, make_optimizer

#: typed event channels of the trainer program (v2 API); the runtime's
#: ``__``-prefixed heartbeat plumbing eids are exempt from declaration
CHANNELS = (edat.Channel("go", payload=int),
            edat.Channel("grad", payload=dict),
            edat.Channel("metric", payload=dict),
            edat.Channel("ckpt", payload=dict),
            edat.Channel("final", payload=dict),
            edat.Channel("recover", payload=dict),
            edat.Channel("suspect", payload=int),
            edat.Channel("hb", payload=int))


@dataclasses.dataclass
class TrainerCfg:
    steps: int = 20
    n_ranks: int = 2
    workers_per_rank: int = 2
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    quorum: float = 1.0          # fraction of alive ranks' grads required
    collect_timeout: float = 10.0  # straggler bound (s)
    stale_discount: float = 0.5  # weight applied to late gradient events
    compress: str = "none"       # none | int8
    seed: int = 0
    start_step: int = 0          # resume support
    # heartbeat failure detector (timer events, paper §VII): 0 = off.
    # A rank silent for hb_timeout is *suspected*: survivors treat it as
    # failed (roll back + re-shard); the suspect fences itself on waking.
    # (Across processes the socket transport's own heartbeat detector
    # additionally catches dead *processes* regardless of this knob.)
    hb_interval: float = 0.0
    hb_timeout: float = 3.0
    # test hook: {rank: (step, seconds)} injected stall
    stall: Optional[Dict[int, tuple]] = None


# ------------------------------------------------------- gradient payloads
def _q8_tree(tree):
    def q(x):
        x = np.asarray(x, np.float32)
        amax = float(np.max(np.abs(x))) + 1e-12
        return (np.round(x / amax * 127.0).astype(np.int8), amax)
    return jax.tree.map(q, tree)


def _dq8_tree(tree):
    def dq(leaf):
        q, amax = leaf
        return q.astype(np.float32) * (amax / 127.0)
    return jax.tree.map(dq, tree, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[1], float))


def flatten_params(tree) -> Dict[str, np.ndarray]:
    """Flatten a parameter tree to ``{path: numpy array}`` — the on-disk
    form of the distributed trainer's final parameters, and the common
    currency for comparing trainers across transports."""
    flat = ckpt_store.store._flatten(jax.tree.map(np.asarray, tree))
    return {k.lstrip("/"): v for k, v in flat.items()}


# ----------------------------------------------------------- quorum logic
class QuorumCollector:
    """K-of-N gradient quorum with bounded-staleness fold-in.

    Pure accumulation logic, factored out of the step task so it can be
    property-tested directly: ``offer`` payloads in *any* arrival order,
    and :meth:`reduce` yields the weighted mean

        (sum(fresh) + discount * sum(stale)) / (n_fresh + discount*n_stale)

    independent of that order (fresh gradients fold in ascending rank
    order, stale ones in ascending (step, rank) order, so the
    floating-point result is deterministic).

    * a payload from the collector's epoch at exactly ``step`` is *fresh*;
    * an earlier step from the same epoch is *stale* (discounted fold-in,
      the bounded-staleness rule);
    * other epochs (pre-recovery leftovers) and future steps are ignored.
    """

    def __init__(self, *, step: int, epoch: int, need: int,
                 stale_discount: float,
                 unpack: Callable[[Any], Any] = lambda g: g):
        self.step = step
        self.epoch = epoch
        self.need = need
        self.stale_discount = stale_discount
        self.unpack = unpack
        self.got: Dict[int, Any] = {}
        self.stale: List[tuple] = []    # (step, rank, grads)

    def offer(self, payload: Dict[str, Any]) -> bool:
        """Consider one grad-event payload; True iff it was accepted."""
        if payload["epoch"] != self.epoch:
            return False
        if payload["step"] == self.step:
            self.got[payload["rank"]] = self.unpack(payload["grads"])
            return True
        if payload["step"] < self.step:
            self.stale.append((payload["step"], payload["rank"],
                               self.unpack(payload["grads"])))
            return True
        return False

    @property
    def complete(self) -> bool:
        return len(self.got) >= self.need

    def ensure_own(self, rank: int, grads) -> None:
        """Own grads must participate even if the loopback event lost a
        race with the timeout (no-op when already collected)."""
        self.got.setdefault(rank, grads)

    def reduce(self):
        """Weighted mean over fresh + discounted stale gradients.
        Returns ``(gavg, n_fresh, n_stale)``; ``gavg`` leaves are jnp."""
        gsum = None
        weight = 0.0
        for r in sorted(self.got):      # deterministic fold order
            g = self.got[r]
            gsum = g if gsum is None else jax.tree.map(np.add, gsum, g)
            weight += 1.0
        for _, _, g in sorted(self.stale,   # bounded staleness: discounted,
                              key=lambda t: t[:2]):   # deterministic order
            gsum = jax.tree.map(
                lambda a, b: a + self.stale_discount * b, gsum, g)
            weight += self.stale_discount
        gavg = jax.tree.map(lambda x: jnp.asarray(x / weight), gsum)
        return gavg, len(self.got), len(self.stale)


class _RankState:
    def __init__(self, rank):
        self.rank = rank
        self.mu = threading.Lock()  # serialises commit vs recovery rollback
        self.params = None
        self.opt_state = None
        self.step = 0
        self.epoch = 0            # bumped on every recovery
        self.alive: List[int] = []
        self.done = False
        self.stepping = False     # exactly one live step chain per rank
        self.chain_dropped = None # epoch of a "go" token eaten by the flag
        self.hb_mute = False      # test hook: simulated hang
        self.stale_used = 0
        self.timeouts = 0


class EventDrivenTrainer:
    """Elastic data-parallel trainer coordinated purely by EDAT events.

    One instance serves every rank of its process: :meth:`start` is the
    SPMD attach point (called once per local rank by ``Runtime.run``),
    :meth:`run` the in-proc convenience that owns a threads-as-ranks
    runtime.  State that crosses ranks does so *only* via events — the
    instance keeps per-rank state for the ranks it hosts, rank 0's
    process additionally accumulating ``history`` (metric events),
    ``final_params`` (final events) and ``recoveries``."""

    def __init__(self, model, data_cfg: DataCfg, opt_cfg: OptCfg,
                 cfg: TrainerCfg):
        self.model = model
        self.data = SyntheticLM(data_cfg)
        self.opt = make_optimizer(opt_cfg)
        self.cfg = cfg
        self.history: List[Dict[str, Any]] = []
        self._hist_mu = threading.Lock()
        self._world_mu = threading.Lock()
        self.states = [_RankState(r) for r in range(cfg.n_ranks)]
        self.runtime: Optional[edat.Runtime] = None
        self.ckpt_writes = 0
        #: rollbacks executed by local ranks: {"rank", "step", "epoch"}
        self.recoveries: List[Dict[str, int]] = []
        #: rank -> final parameter tree, gathered on rank 0's process
        self.final_params: Dict[int, Any] = {}
        #: rank -> step its final event reported (same gather path)
        self.final_steps: Dict[int, int] = {}
        #: called (on rank 0's process) with each rank's final payload
        self.on_final: Optional[Callable[[Dict[str, Any]], None]] = None
        #: called (on rank 0's process) after each metric is recorded
        self.on_metric: Optional[Callable[[Dict[str, Any]], None]] = None
        #: True once the durable replay coordinator owns the recovery
        #: trigger (runtime in durable mode; see _arm_durable_recovery)
        self._durable_recovery = False

        # jitted per-host functions (shared across co-located rank threads)
        def loss_fn(p, batch):
            loss, m = model.loss(p, batch)
            return loss, m

        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        def apply_fn(params, opt_state, grads, step):
            return self.opt.update(grads, opt_state, params, step)

        self._apply_fn = jax.jit(apply_fn)

    # ----------------------------------------------------------- event glue
    def _pack_grads(self, grads):
        host = jax.tree.map(np.asarray, grads)
        if self.cfg.compress == "int8":
            return _q8_tree(host)
        return host

    def _unpack_grads(self, payload):
        if self.cfg.compress == "int8":
            return _dq8_tree(payload)
        return payload

    # ------------------------------------------------------------ main SPMD
    channels = CHANNELS

    def result(self) -> Dict[str, Any]:
        """Gathered output (rank 0's process), in transport-independent
        currency: metric history, recoveries, and each reporting rank's
        final parameters flattened to ``{path: numpy array}``."""
        with self._hist_mu:
            return {
                "history": sorted(self.history, key=lambda m: m["step"]),
                "recoveries": list(self.recoveries),
                "final_params": {r: flatten_params(p)
                                 for r, p in self.final_params.items()},
                "final_steps": dict(self.final_steps),
            }

    def run(self, timeout: float = 300.0) -> Dict[str, Any]:
        """In-proc convenience: all ranks as threads in one Session."""
        cfg = self.cfg
        with edat.Session(cfg.n_ranks,
                          workers_per_rank=cfg.workers_per_rank,
                          unconsumed="ignore", timeout=timeout) as s:
            self.runtime = s.runtime
            s.run(self)
        return {
            "history": sorted(self.history, key=lambda m: m["step"]),
            "final_params": [s.params for s in self.states],
            "final_by_rank": dict(self.final_params),
            "recoveries": list(self.recoveries),
            "stale_used": sum(s.stale_used for s in self.states),
            "timeouts": sum(s.timeouts for s in self.states),
            "ckpt_writes": self.ckpt_writes,
        }

    def _init_state(self, st: _RankState):
        cfg = self.cfg
        st.params = self.model.init(jax.random.PRNGKey(cfg.seed))
        st.opt_state = self.opt.init(st.params)
        st.step = cfg.start_step
        st.alive = list(range(cfg.n_ranks))
        if cfg.ckpt_dir and cfg.start_step > 0:
            proto = {"params": st.params, "opt": st.opt_state}
            step, tree, _ = ckpt_store.restore(cfg.ckpt_dir, proto)
            st.params = jax.tree.map(jnp.asarray, tree["params"])
            st.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            st.step = step

    def _ensure_world(self, n_ranks: int) -> None:
        """Reconcile ``cfg.n_ranks`` with the session's actual rank count
        (the session is authoritative — the v1 ``distributed_train``
        helper did the same via ``dataclasses.replace``).  Must run
        before any rank touches its state; racing rank threads are
        serialised by the lock and later arrivals see a match."""
        with self._world_mu:
            if self.cfg.n_ranks != n_ranks:
                self.cfg = dataclasses.replace(self.cfg, n_ranks=n_ranks)
                self.states = [_RankState(r) for r in range(n_ranks)]

    def start(self, ctx: edat.Context) -> None:
        """Attach one rank of the trainer to any (in-proc or distributed)
        runtime: initialise that rank's replica, submit its persistent
        tasks, and fire the first chain token.  Rank 0 (wherever its
        process lives) additionally hosts the metric/checkpoint/final
        collectors and the heartbeat monitor."""
        self._ensure_world(ctx.n_ranks)
        cfg = self.cfg
        self.runtime = ctx._rt
        if ctx.rank == 0:
            self._arm_durable_recovery()
        st = self.states[ctx.rank]
        self._init_state(st)

        # persistent tasks: the step engine, failure handling, recovery
        ctx.submit_persistent(self._step_task, deps=[(edat.SELF, "go")],
                              name="step")
        ctx.submit_persistent(self._on_rank_failed,
                              deps=[(edat.ANY, edat.RANK_FAILED)],
                              name="faildet")
        ctx.submit_persistent(self._on_recover, deps=[(edat.ANY, "recover")],
                              name="recover")
        if ctx.rank == 0:
            ctx.submit_persistent(self._metric_task,
                                  deps=[(edat.ANY, "metric")], name="metrics")
            ctx.submit_persistent(self._final_task,
                                  deps=[(edat.ANY, "final")], name="final")
            if cfg.ckpt_dir:
                ctx.submit_persistent(self._ckpt_task,
                                      deps=[(edat.SELF, "ckpt")], name="ckpt")
            if cfg.hb_interval > 0:
                self._hb_seen = {r: time.monotonic()
                                 for r in range(cfg.n_ranks)}
                self._hb_done: set = set()
                ctx.submit_persistent(self._hb_monitor,
                                      deps=[(edat.SELF, "__hbtick")],
                                      name="hbmon")
                ctx.fire_after(cfg.hb_interval, edat.SELF, "__hbtick")
        if cfg.hb_interval > 0:
            ctx.submit_persistent(self._on_suspect,
                                  deps=[(edat.ANY, "suspect")],
                                  name="suspect")
            # heartbeat pump: timer-driven, independent of the step task
            # (a jit compile or long step must NOT look like a hang)
            ctx.submit_persistent(self._hb_pump,
                                  deps=[(edat.SELF, "__hbself")],
                                  name="hbpump")
            ctx.fire_after(cfg.hb_interval / 2, edat.SELF, "__hbself")
        # durable initial checkpoint: the recovery anchor
        if ctx.rank == 0 and cfg.ckpt_dir and cfg.start_step == 0:
            snap = {"params": jax.tree.map(np.asarray, st.params),
                    "opt": jax.tree.map(np.asarray, st.opt_state)}
            ckpt_store.save(cfg.ckpt_dir, st.step, snap)
        ctx.fire(edat.SELF, "go")

    # ---------------------------------------------------------------- tasks
    def _step_task(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if st.done or self.runtime.is_dead(ctx.rank):
            return
        token = events[0].data     # chain token: the epoch it was fired for
        with st.mu:
            if token is not None and token != st.epoch:
                return             # stale chain token from before a recovery
            if st.stepping:
                # a duplicate "go" (e.g. two recoveries racing): exactly one
                # step chain may run per rank, or concurrent instances would
                # steal each other's grad events and diverge the replicas.
                # Remember the eaten token so the running instance can revive
                # the chain when it exits.
                st.chain_dropped = st.epoch
                return
            st.stepping = True
        again = False
        try:
            again = self._step_body(ctx, st)
        finally:
            with st.mu:
                st.stepping = False
                revive = (st.chain_dropped is not None
                          and st.chain_dropped == st.epoch and not st.done)
                st.chain_dropped = None
                epoch_now = st.epoch
        if again or revive:
            ctx.fire(edat.SELF, "go", epoch_now)

    def _step_body(self, ctx: edat.Context, st: "_RankState") -> bool:
        """One training step.  Returns True iff the chain should continue
        (the caller fires the next "go" after releasing the chain flag)."""
        cfg = self.cfg
        if cfg.stall and ctx.rank in cfg.stall:
            at, secs = cfg.stall[ctx.rank]
            if st.step == at:
                st.hb_mute = True    # a true hang silences the pump too
                time.sleep(secs)     # injected hang (straggler simulation)
                st.hb_mute = False
        epoch = st.epoch
        alive = sorted(st.alive)
        if ctx.rank not in alive:    # fenced while stalled
            st.done = True
            return False
        shard = alive.index(ctx.rank)
        batch = self.data.batch(st.step, shard, len(alive))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, metrics), grads = self._grad_fn(st.params, batch)

        payload = {"rank": ctx.rank, "step": st.step, "epoch": epoch,
                   "grads": self._pack_grads(grads)}
        # ref=True: the packed tree is freshly materialised and never
        # mutated — co-located ranks share it in-process, remote ranks get
        # the zero-copy out-of-band encode
        ctx.fire(edat.ALL, "grad", payload, ref=True)

        # K-of-N quorum collection with straggler timeout (async DP)
        coll = QuorumCollector(
            step=st.step, epoch=epoch,
            need=max(1, int(np.ceil(cfg.quorum * len(alive)))),
            stale_discount=cfg.stale_discount, unpack=self._unpack_grads)
        deadline = time.monotonic() + cfg.collect_timeout
        while not coll.complete:
            if st.epoch != epoch or st.done:
                # recovery happened under us: abandon this step; the
                # recovery's own chain token (re)starts the stepping
                return False
            evs = ctx.retrieve_any([(edat.ANY, "grad")])
            for ev in evs:
                coll.offer(ev.data)
            if not evs:
                if time.monotonic() > deadline:
                    st.timeouts += 1
                    break
                time.sleep(0.002)
        coll.ensure_own(ctx.rank, jax.tree.map(np.asarray, grads))
        gavg, n_got, n_stale = coll.reduce()
        st.stale_used += n_stale

        snap = None
        with st.mu:
            if st.epoch != epoch or st.done:
                # a rollback landed after collection: committing now would
                # silently clobber the restored checkpoint state
                return False
            st.params, st.opt_state, om = self._apply_fn(
                st.params, st.opt_state, gavg, jnp.asarray(st.step))
            st.step += 1
            step_now = st.step
            if (cfg.ckpt_dir and ctx.rank == min(alive)
                    and step_now % cfg.ckpt_every == 0):
                snap = {"params": jax.tree.map(np.asarray, st.params),
                        "opt": jax.tree.map(np.asarray, st.opt_state)}
            if step_now >= cfg.steps:
                st.done = True

        ctx.fire(0, "metric", {"rank": ctx.rank, "step": step_now,
                               "loss": float(loss),
                               "n_grads": n_got, "n_stale": n_stale})
        if snap is not None:
            ctx.fire(0, "ckpt", {"step": step_now, "snap": snap}, ref=True)

        if step_now < cfg.steps:
            return True
        # trained to completion: ship the converged replica to rank 0
        ctx.fire(0, "final",
                 {"rank": ctx.rank, "step": step_now,
                  "params": jax.tree.map(np.asarray, st.params)}, ref=True)
        if cfg.hb_interval > 0:
            ctx.fire(0, "__hbdone", ctx.rank)
        return False

    def _ckpt_task(self, ctx: edat.Context, events):
        p = events[0].data
        ckpt_store.save(self.cfg.ckpt_dir, p["step"], p["snap"])
        self.ckpt_writes += 1

    def _metric_task(self, ctx: edat.Context, events):
        with self._hist_mu:
            self.history.append(events[0].data)
        hook = self.on_metric
        if hook is not None:
            hook(events[0].data)

    def _final_task(self, ctx: edat.Context, events):
        """Rank 0: collect each rank's converged parameters (ranks that
        die or get fenced never report — elastic by construction)."""
        p = events[0].data
        with self._hist_mu:
            self.final_params[p["rank"]] = p["params"]
            self.final_steps[p["rank"]] = int(p["step"])
        hook = self.on_final
        if hook is not None:
            hook(p)

    def _hb_pump(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if st.done or self.runtime.is_dead(ctx.rank):
            return                   # stop beating; timer chain ends
        if not st.hb_mute:
            ctx.fire(0, "hb", ctx.rank)
        ctx.fire_after(self.cfg.hb_interval / 2, edat.SELF, "__hbself")

    def _hb_monitor(self, ctx: edat.Context, events):
        """Timer-driven failure detector on rank 0 (paper §VII: machine
        generated events drive tasks).  Reads only rank-0-local state plus
        delivered hb/__hbdone events — it never peeks at other ranks'
        memory, so it works unchanged across processes."""
        cfg = self.cfg
        st = self.states[ctx.rank]
        now = time.monotonic()
        for ev in ctx.retrieve_any([(edat.ANY, "hb")] * (4 * cfg.n_ranks)):
            self._hb_seen[ev.data] = now
        for ev in ctx.retrieve_any([(edat.ANY, "__hbdone")] * cfg.n_ranks):
            self._hb_done.add(ev.data)
        suspects = [r for r in sorted(st.alive)
                    if r not in self._hb_done
                    and now - self._hb_seen.get(r, now) > cfg.hb_timeout]
        for r in suspects:
            ctx.fire(edat.ALL, "suspect", r)
        active = [r for r in st.alive
                  if r not in self._hb_done and r not in suspects
                  and not self.runtime.is_dead(r)]
        if active:
            ctx.fire_after(cfg.hb_interval, edat.SELF, "__hbtick")

    def _on_suspect(self, ctx: edat.Context, events):
        suspected = events[0].data
        st = self.states[ctx.rank]
        if suspected == ctx.rank:
            st.done = True          # fence myself: fail-stop enforcement
            return
        with st.mu:
            if suspected not in st.alive:
                return
            st.alive.remove(suspected)
            lead = st.alive and ctx.rank == min(st.alive)
        if ctx.rank == 0:
            self._hb_done.add(suspected)
        if lead and self.cfg.ckpt_dir:
            step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
            ctx.fire(edat.ALL, "recover", {"step": step})

    def _on_rank_failed(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        dead = events[0].data
        with st.mu:
            if dead not in st.alive:
                # already handled: the heartbeat-suspect path beat this
                # event, or an earlier RANK_FAILED's sweep took it (one
                # SIGKILLed process surfaces one event per hosted rank).
                # Re-firing "recover" here was the known duplicate-recovery
                # flake — two rollbacks racing the restarted step chain
                # could diverge the replicas.
                return
            # process-granularity sweep: every rank the transport already
            # knows to be dead leaves `alive` NOW, so a multi-rank process
            # death triggers exactly one coordinated recovery instead of
            # one per hosted rank.
            swept = [d for d in list(st.alive)
                     if d != ctx.rank and (d == dead
                                           or self.runtime.is_dead(d))]
            for d in swept:
                st.alive.remove(d)
            lead = st.alive and ctx.rank == min(st.alive)
        # leader triggers a coordinated rollback to the last durable ckpt
        if lead and self.cfg.ckpt_dir:
            if self._durable_recovery and not self.runtime.is_dead(0):
                # durable mode with the replay coordinator alive: the
                # rollback broadcast comes from the replay callback,
                # *after* the dead rank's events are re-homed (and after
                # an elastic replacement had its join window)
                return
            step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
            ctx.fire(edat.ALL, "recover", {"step": step})

    # ------------------------------------------------- durable-mode recovery
    def _arm_durable_recovery(self) -> None:
        """Runtime in durable mode (``Session(durable=True)``): hand the
        recovery *trigger* to the replay coordinator.  The coordinator
        already diffs the task log on RANK_FAILED and re-homes the dead
        rank's unconsumed events; this callback then broadcasts the
        coordinated ``recover`` rollback exactly once, *after* replay —
        replacing the bespoke leader fire in :meth:`_on_rank_failed`
        (which stays armed as the fallback for the one failure replay
        cannot coordinate: the death of rank 0's own process).  While
        rank 0 is alive it is always ``min(st.alive)``, so no other
        leader races the callback.

        The trainer's own channels stay epoch-scoped rather than durable:
        a replayed gradient from before the rollback is discarded by the
        collector's epoch check anyway, so journaling them would buy
        nothing.  What durable mode contributes here is ordering (replay
        settles, an elastic replacement gets its join window, then one
        rollback) — the fair-weather path is byte-identical."""
        rt = self.runtime
        dur = getattr(rt, "_durable", None)
        if dur is None:
            return
        self._durable_recovery = True

        def _recover_after_replay(dead: int, revived: bool, n: int) -> None:
            if not self.cfg.ckpt_dir or rt.is_dead(0):
                return      # no rollback anchor / coordinator rank itself
            step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
            rt._fire(min(rt._sched), edat.ALL, "recover", {"step": step},
                     persistent=False, ref=False)

        dur.add_replay_callback(_recover_after_replay)

    def _on_recover(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if self.runtime.is_dead(ctx.rank) or st.done:
            return
        info = events[0].data
        cfg = self.cfg
        proto = {"params": st.params, "opt": st.opt_state}
        try:
            step, tree, _ = ckpt_store.restore(cfg.ckpt_dir, proto,
                                               step=info["step"])
        except FileNotFoundError:
            return
        with st.mu:
            st.params = jax.tree.map(jnp.asarray, tree["params"])
            st.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            st.step = step
            st.epoch += 1        # invalidates in-flight grads
            epoch_now = st.epoch
        with self._hist_mu:
            self.recoveries.append({"rank": ctx.rank, "step": step,
                                    "epoch": epoch_now})
        ctx.fire(edat.SELF, "go", epoch_now)


# ------------------------------------------------- distributed (processes)
def trainer_program(model_cfg, data_cfg, opt_cfg,
                    trainer_cfg: TrainerCfg) -> EventDrivenTrainer:
    """Program factory for ``edat.run``/``Session``: builds the model
    and one :class:`EventDrivenTrainer`.  Wrap in ``edat.deferred`` so
    each spawned process constructs its own trainer — co-located rank
    threads then share the jitted step functions, and the unpicklable
    parts (jit caches, locks) never cross a process boundary.
    ``trainer_cfg.ckpt_dir`` must be on storage every process can reach —
    it is both the async checkpoint sink and the recovery source when a
    process dies."""
    from repro.models import build_model
    return EventDrivenTrainer(build_model(model_cfg), data_cfg, opt_cfg,
                              trainer_cfg)


def distributed_train(n_ranks: int, model_cfg, data_cfg, opt_cfg,
                      trainer_cfg: TrainerCfg, *,
                      n_procs: Optional[int] = None,
                      timeout: float = 300.0,
                      out_dir: Optional[str] = None,
                      **launch_kwargs) -> Dict[str, Any]:
    """Deprecated v1 helper — use the v2 Session API::

        res = edat.run(edat.deferred(trainer_program, model_cfg, data_cfg,
                                     opt_cfg, trainer_cfg),
                       ranks=n_ranks, procs=n_procs, transport="socket",
                       unconsumed="ignore")

    Returns ``{"history", "recoveries", "final_params", "stats"}``
    exactly as before (``final_params`` is ``{rank: {path: array}}``).
    With ``out_dir`` the results are additionally persisted in the old
    on-disk layout (history.json / recoveries.json / final_rank*.npz) —
    written after a successful run; a run that fails before rank 0's
    process finalizes leaves ``out_dir`` untouched (v1 wrote
    incrementally and could leave partial files)."""
    warn_deprecated(
        "distributed_train is deprecated: use edat.run(edat.deferred("
        "trainer_program, ...), ranks=..., procs=..., transport='socket')")
    cfg = dataclasses.replace(trainer_cfg, n_ranks=n_ranks)
    # v1 launcher kwargs that moved in v2: keep the old contract working
    check = launch_kwargs.pop("check", True)
    join_timeout = launch_kwargs.pop("join_timeout", None)
    with edat.Session(n_ranks, procs=n_procs, transport="socket",
                      timeout=timeout,
                      workers_per_rank=cfg.workers_per_rank,
                      unconsumed="ignore", **launch_kwargs) as s:
        s.start(edat.deferred(trainer_program, model_cfg, data_cfg,
                              opt_cfg, cfg))
        s.wait(join_timeout, check=check)
        gathered = s.gather()
        res = dict(gathered or {"history": [], "recoveries": [],
                                "final_params": {}})
        res["stats"] = dict(s.stats)
    # persist only real results: never clobber a previous run's files
    # with empties when rank 0's process died before finalizing
    if out_dir and gathered is not None:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "history.json"), "w") as f:
            json.dump(res["history"], f)
        with open(os.path.join(out_dir, "recoveries.json"), "w") as f:
            json.dump(res["recoveries"], f)
        steps_by_rank = res.get("final_steps", {})
        for r, flat in res["final_params"].items():
            np.savez(os.path.join(out_dir, f"final_rank{r}.npz"),
                     step=np.int64(steps_by_rank.get(r, 0)), **flat)
    return res


def load_distributed_results(out_dir: str) -> Dict[str, Any]:
    """Deprecated v1 helper — results now come straight from
    ``Session.gather()``.  Reads the old on-disk layout (which
    ``distributed_train(out_dir=...)`` still writes): ``history``,
    ``recoveries``, and ``final_params`` ({rank: {path: array}})."""
    warn_deprecated(
        "load_distributed_results is deprecated: read results from "
        "Session.gather() (edat.run returns them directly)")
    out: Dict[str, Any] = {"history": [], "recoveries": [],
                           "final_params": {}}
    hist = os.path.join(out_dir, "history.json")
    if os.path.exists(hist):
        with open(hist) as f:
            out["history"] = json.load(f)
    rec = os.path.join(out_dir, "recoveries.json")
    if os.path.exists(rec):
        with open(rec) as f:
            out["recoveries"] = json.load(f)
    for name in sorted(os.listdir(out_dir)):
        if name.startswith("final_rank") and name.endswith(".npz"):
            r = int(name[len("final_rank"):-len(".npz")])
            with np.load(os.path.join(out_dir, name)) as z:
                out["final_params"][r] = {k: z[k] for k in z.files
                                          if k != "step"}
    return out


# --------------------------------------------------------------- smoke CLI
def _demo_cfgs(n_ranks: int, steps: int, ckpt_dir: Optional[str],
               ckpt_every: int = 4):
    """Small default model/data/opt/trainer configs for the smoke CLI and
    the examples."""
    from repro.models import ModelCfg
    model_cfg = ModelCfg(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
        dtype="float32", remat="none", max_target_length=64)
    data_cfg = DataCfg(vocab=128, seq=32, global_batch=12, seed=7)
    opt_cfg = OptCfg(name="adamw", peak_lr=3e-2, warmup=5, total_steps=200,
                     clip_norm=1.0)
    trainer_cfg = TrainerCfg(steps=steps, n_ranks=n_ranks,
                             ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                             collect_timeout=60.0)
    return model_cfg, data_cfg, opt_cfg, trainer_cfg


def _cli(argv=None) -> int:
    """Distributed-trainer smoke: run the trainer program over a socket
    :class:`edat.Session`, optionally SIGKILL one process mid-training,
    and verify elastic recovery — CI runs this with ``--kill``."""
    import argparse
    import tempfile
    from repro.checkpoint import latest_step

    ap = argparse.ArgumentParser(
        prog="python -m repro.runtime_dist.trainer",
        description="Distributed elastic trainer smoke test (v2 Session).")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--procs", type=int, default=2)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--kill", action="store_true",
                    help="SIGKILL the last process once the first real "
                         "checkpoint exists; survivors must recover and "
                         "finish")
    ap.add_argument("--timeout", type=float, default=240.0)
    a = ap.parse_args(argv)

    with tempfile.TemporaryDirectory(prefix="edat_trainer_smoke_") as td:
        ckdir = os.path.join(td, "ck")
        model_cfg, data_cfg, opt_cfg, trainer_cfg = _demo_cfgs(
            a.ranks, a.steps, ckdir, a.ckpt_every)
        with edat.Session(a.ranks, procs=a.procs, transport="socket",
                          timeout=a.timeout,
                          workers_per_rank=trainer_cfg.workers_per_rank,
                          unconsumed="ignore", hb_interval=0.2,
                          hb_timeout=1.5) as s:
            s.start(edat.deferred(trainer_program, model_cfg, data_cfg,
                                  opt_cfg, trainer_cfg))
            victim_ranks: set = set()
            if a.kill:
                deadline = time.monotonic() + a.timeout
                while ((latest_step(ckdir) or 0) < a.ckpt_every
                       and time.monotonic() < deadline):
                    time.sleep(0.05)
                got = latest_step(ckdir) or 0
                if got < a.ckpt_every:
                    s.wait(5, check=False)
                    print(f"smoke FAILED: no checkpoint appeared "
                          f"(latest={got})")
                    return 1
                victim = a.ranks - 1
                victim_ranks = {r for rs in s.placement for r in rs
                                if victim in rs}
                s.kill(victim)
                print(f"[smoke] killed the process hosting rank {victim} "
                      f"at checkpoint step {got}")
            s.wait(a.timeout, check=not a.kill)
            res = s.gather() or {"history": [], "recoveries": [],
                                 "final_params": {}}
        top = max((m["step"] for m in res["history"]), default=0)
        print(f"[smoke] steps reached: {top}/{a.steps}; "
              f"recoveries: {res['recoveries']}; "
              f"finals from ranks {sorted(res['final_params'])}")
        if top < a.steps:
            print("smoke FAILED: training did not reach the target step")
            return 1
        if a.kill and not res["recoveries"]:
            print("smoke FAILED: no elastic recovery was recorded")
            return 1
        if a.kill:
            survivors = set(range(a.ranks)) - victim_ranks
            if not survivors.issubset(set(res["final_params"])):
                print(f"smoke FAILED: missing finals "
                      f"{survivors - set(res['final_params'])}")
                return 1
        print("[smoke] OK")
        return 0


if __name__ == "__main__":
    import sys
    sys.exit(_cli())
