"""Event-driven distributed trainer: EDAT as the coordination layer.

Every JAX host is an EDAT rank (simulated in-proc here; the transport is
pluggable).  All inter-host interactions are events — the paper's model:

  * ``grad``    gradient exchange (data-parallel all-to-all of grad events;
                optionally int8-compressed), collected by a quorum
                collector: K-of-N with a straggler timeout — bounded-
                staleness async DP; quorum=1.0 == synchronous DP.
  * ``ckpt``    async checkpointing: the step task fires a snapshot event
                to a persistent checkpoint task; the write happens on
                another worker while the next step computes.
  * ``metric``  in-situ analytics pipeline (MONC pattern, §VI).
  * RANK_FAILED machine-generated failure event (paper §VII): the leader
                broadcasts ``recover``; survivors roll back to the last
                durable checkpoint, re-shard the data stream (elastic),
                and continue.

The trainer is deliberately pure data-parallel at the EDAT level; inside a
rank the step is a jitted JAX function (which on a real pod is itself
pjit-sharded — see launch/).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import edat
from repro import checkpoint as ckpt_store
from repro.data import DataCfg, SyntheticLM
from repro.optim import OptCfg, make_optimizer


@dataclasses.dataclass
class TrainerCfg:
    steps: int = 20
    n_ranks: int = 2
    workers_per_rank: int = 2
    ckpt_every: int = 10
    ckpt_dir: Optional[str] = None
    quorum: float = 1.0          # fraction of alive ranks' grads required
    collect_timeout: float = 10.0  # straggler bound (s)
    stale_discount: float = 0.5  # weight applied to late gradient events
    compress: str = "none"       # none | int8
    seed: int = 0
    start_step: int = 0          # resume support
    # heartbeat failure detector (timer events, paper §VII): 0 = off.
    # A rank silent for hb_timeout is *suspected*: survivors treat it as
    # failed (roll back + re-shard); the suspect fences itself on waking.
    hb_interval: float = 0.0
    hb_timeout: float = 3.0
    # test hook: {rank: (step, seconds)} injected stall
    stall: Optional[Dict[int, tuple]] = None


# ------------------------------------------------------- gradient payloads
def _q8_tree(tree):
    def q(x):
        x = np.asarray(x, np.float32)
        amax = float(np.max(np.abs(x))) + 1e-12
        return (np.round(x / amax * 127.0).astype(np.int8), amax)
    return jax.tree.map(q, tree)


def _dq8_tree(tree):
    def dq(leaf):
        q, amax = leaf
        return q.astype(np.float32) * (amax / 127.0)
    return jax.tree.map(dq, tree, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[1], float))


class _RankState:
    def __init__(self, rank):
        self.rank = rank
        self.mu = threading.Lock()  # serialises commit vs recovery rollback
        self.params = None
        self.opt_state = None
        self.step = 0
        self.epoch = 0            # bumped on every recovery
        self.alive: List[int] = []
        self.done = False
        self.stepping = False     # exactly one live step chain per rank
        self.chain_dropped = None # epoch of a "go" token eaten by the flag
        self.hb_mute = False      # test hook: simulated hang
        self.stale_used = 0
        self.timeouts = 0


class EventDrivenTrainer:
    def __init__(self, model, data_cfg: DataCfg, opt_cfg: OptCfg,
                 cfg: TrainerCfg):
        self.model = model
        self.data = SyntheticLM(data_cfg)
        self.opt = make_optimizer(opt_cfg)
        self.cfg = cfg
        self.history: List[Dict[str, Any]] = []
        self._hist_mu = threading.Lock()
        self.states = [_RankState(r) for r in range(cfg.n_ranks)]
        self.runtime: Optional[edat.Runtime] = None
        self.ckpt_writes = 0

        # jitted per-host functions (shared across rank threads)
        def loss_fn(p, batch):
            loss, m = model.loss(p, batch)
            return loss, m

        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        def apply_fn(params, opt_state, grads, step):
            return self.opt.update(grads, opt_state, params, step)

        self._apply_fn = jax.jit(apply_fn)

    # ----------------------------------------------------------- event glue
    def _pack_grads(self, grads):
        host = jax.tree.map(np.asarray, grads)
        if self.cfg.compress == "int8":
            return _q8_tree(host)
        return host

    def _unpack_grads(self, payload):
        if self.cfg.compress == "int8":
            return _dq8_tree(payload)
        return payload

    # ------------------------------------------------------------ main SPMD
    def run(self, timeout: float = 300.0) -> Dict[str, Any]:
        cfg = self.cfg
        rt = edat.Runtime(cfg.n_ranks, workers_per_rank=cfg.workers_per_rank,
                          unconsumed="ignore")
        self.runtime = rt
        rt.run(self._main, timeout=timeout)
        return {
            "history": sorted(self.history, key=lambda m: m["step"]),
            "final_params": [s.params for s in self.states],
            "stale_used": sum(s.stale_used for s in self.states),
            "timeouts": sum(s.timeouts for s in self.states),
            "ckpt_writes": self.ckpt_writes,
        }

    def _init_state(self, st: _RankState):
        cfg = self.cfg
        st.params = self.model.init(jax.random.PRNGKey(cfg.seed))
        st.opt_state = self.opt.init(st.params)
        st.step = cfg.start_step
        st.alive = list(range(cfg.n_ranks))
        if cfg.ckpt_dir and cfg.start_step > 0:
            proto = {"params": st.params, "opt": st.opt_state}
            step, tree, _ = ckpt_store.restore(cfg.ckpt_dir, proto)
            st.params = jax.tree.map(jnp.asarray, tree["params"])
            st.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            st.step = step

    def _main(self, ctx: edat.Context):
        cfg = self.cfg
        st = self.states[ctx.rank]
        self._init_state(st)

        # persistent tasks: the step engine, failure handling, recovery
        ctx.submit_persistent(self._step_task, deps=[(edat.SELF, "go")],
                              name="step")
        ctx.submit_persistent(self._on_rank_failed,
                              deps=[(edat.ANY, edat.RANK_FAILED)],
                              name="faildet")
        ctx.submit_persistent(self._on_recover, deps=[(edat.ANY, "recover")],
                              name="recover")
        if ctx.rank == 0:
            ctx.submit_persistent(self._metric_task,
                                  deps=[(edat.ANY, "metric")], name="metrics")
            if cfg.ckpt_dir:
                ctx.submit_persistent(self._ckpt_task,
                                      deps=[(edat.SELF, "ckpt")], name="ckpt")
            if cfg.hb_interval > 0:
                self._hb_seen = {r: time.monotonic()
                                 for r in range(cfg.n_ranks)}
                self._hb_done: set = set()
                ctx.submit_persistent(self._hb_monitor,
                                      deps=[(edat.SELF, "__hbtick")],
                                      name="hbmon")
                ctx.fire_after(cfg.hb_interval, edat.SELF, "__hbtick")
        if cfg.hb_interval > 0:
            ctx.submit_persistent(self._on_suspect,
                                  deps=[(edat.ANY, "suspect")],
                                  name="suspect")
            # heartbeat pump: timer-driven, independent of the step task
            # (a jit compile or long step must NOT look like a hang)
            ctx.submit_persistent(self._hb_pump,
                                  deps=[(edat.SELF, "__hbself")],
                                  name="hbpump")
            ctx.fire_after(cfg.hb_interval / 2, edat.SELF, "__hbself")
        # durable initial checkpoint: the recovery anchor
        if ctx.rank == 0 and cfg.ckpt_dir and cfg.start_step == 0:
            snap = {"params": jax.tree.map(np.asarray, st.params),
                    "opt": jax.tree.map(np.asarray, st.opt_state)}
            ckpt_store.save(cfg.ckpt_dir, st.step, snap)
        ctx.fire(edat.SELF, "go")

    # ---------------------------------------------------------------- tasks
    def _step_task(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if st.done or self.runtime.is_dead(ctx.rank):
            return
        token = events[0].data     # chain token: the epoch it was fired for
        with st.mu:
            if token is not None and token != st.epoch:
                return             # stale chain token from before a recovery
            if st.stepping:
                # a duplicate "go" (e.g. two recoveries racing): exactly one
                # step chain may run per rank, or concurrent instances would
                # steal each other's grad events and diverge the replicas.
                # Remember the eaten token so the running instance can revive
                # the chain when it exits.
                st.chain_dropped = st.epoch
                return
            st.stepping = True
        again = False
        try:
            again = self._step_body(ctx, st)
        finally:
            with st.mu:
                st.stepping = False
                revive = (st.chain_dropped is not None
                          and st.chain_dropped == st.epoch and not st.done)
                st.chain_dropped = None
                epoch_now = st.epoch
        if again or revive:
            ctx.fire(edat.SELF, "go", epoch_now)

    def _step_body(self, ctx: edat.Context, st: "_RankState") -> bool:
        """One training step.  Returns True iff the chain should continue
        (the caller fires the next "go" after releasing the chain flag)."""
        cfg = self.cfg
        if cfg.stall and ctx.rank in cfg.stall:
            at, secs = cfg.stall[ctx.rank]
            if st.step == at:
                st.hb_mute = True    # a true hang silences the pump too
                time.sleep(secs)     # injected hang (straggler simulation)
                st.hb_mute = False
        epoch = st.epoch
        alive = sorted(st.alive)
        if ctx.rank not in alive:    # fenced while stalled
            st.done = True
            return False
        shard = alive.index(ctx.rank)
        batch = self.data.batch(st.step, shard, len(alive))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, metrics), grads = self._grad_fn(st.params, batch)

        payload = {"rank": ctx.rank, "step": st.step, "epoch": epoch,
                   "grads": self._pack_grads(grads)}
        ctx.fire(edat.ALL, "grad", payload)

        # K-of-N quorum collection with straggler timeout (async DP)
        need = max(1, int(np.ceil(cfg.quorum * len(alive))))
        got: Dict[int, Any] = {}
        stale: List[Any] = []
        deadline = time.monotonic() + cfg.collect_timeout
        while len(got) < need:
            if st.epoch != epoch or st.done:
                # recovery happened under us: abandon this step; the
                # recovery's own chain token (re)starts the stepping
                return False
            evs = ctx.retrieve_any([(edat.ANY, "grad")])
            for ev in evs:
                p = ev.data
                if p["epoch"] != epoch:
                    continue
                if p["step"] == st.step:
                    got[p["rank"]] = self._unpack_grads(p["grads"])
                elif p["step"] < st.step:
                    stale.append(self._unpack_grads(p["grads"]))
            if not evs:
                if time.monotonic() > deadline:
                    st.timeouts += 1
                    break
                time.sleep(0.002)
        if ctx.rank not in got:   # own grads must participate
            got[ctx.rank] = jax.tree.map(np.asarray, grads)

        gsum = None
        weight = 0.0
        for g in got.values():
            gsum = g if gsum is None else jax.tree.map(np.add, gsum, g)
            weight += 1.0
        for g in stale:           # bounded staleness: discounted fold-in
            gsum = jax.tree.map(
                lambda a, b: a + cfg.stale_discount * b, gsum, g)
            weight += cfg.stale_discount
            st.stale_used += 1
        gavg = jax.tree.map(lambda x: jnp.asarray(x / weight), gsum)

        snap = None
        with st.mu:
            if st.epoch != epoch or st.done:
                # a rollback landed after collection: committing now would
                # silently clobber the restored checkpoint state
                return False
            st.params, st.opt_state, om = self._apply_fn(
                st.params, st.opt_state, gavg, jnp.asarray(st.step))
            st.step += 1
            step_now = st.step
            if (cfg.ckpt_dir and ctx.rank == min(alive)
                    and step_now % cfg.ckpt_every == 0):
                snap = {"params": jax.tree.map(np.asarray, st.params),
                        "opt": jax.tree.map(np.asarray, st.opt_state)}
            if step_now >= cfg.steps:
                st.done = True

        ctx.fire(0, "metric", {"rank": ctx.rank, "step": step_now,
                               "loss": float(loss),
                               "n_grads": len(got), "n_stale": len(stale)})
        if snap is not None:
            ctx.fire(0, "ckpt", {"step": step_now, "snap": snap}, ref=True)

        if step_now < cfg.steps:
            return True
        if cfg.hb_interval > 0:
            ctx.fire(0, "__hbdone", ctx.rank)
        return False

    def _ckpt_task(self, ctx: edat.Context, events):
        p = events[0].data
        ckpt_store.save(self.cfg.ckpt_dir, p["step"], p["snap"])
        self.ckpt_writes += 1

    def _metric_task(self, ctx: edat.Context, events):
        with self._hist_mu:
            self.history.append(events[0].data)

    def _hb_pump(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if st.done or self.runtime.is_dead(ctx.rank):
            return                   # stop beating; timer chain ends
        if not st.hb_mute:
            ctx.fire(0, "hb", ctx.rank)
        ctx.fire_after(self.cfg.hb_interval / 2, edat.SELF, "__hbself")

    def _hb_monitor(self, ctx: edat.Context, events):
        """Timer-driven failure detector on rank 0 (paper §VII: machine
        generated events drive tasks)."""
        cfg = self.cfg
        st = self.states[ctx.rank]
        now = time.monotonic()
        for ev in ctx.retrieve_any([(edat.ANY, "hb")] * (4 * cfg.n_ranks)):
            self._hb_seen[ev.data] = now
        for ev in ctx.retrieve_any([(edat.ANY, "__hbdone")] * cfg.n_ranks):
            self._hb_done.add(ev.data)
        suspects = [r for r in sorted(st.alive)
                    if r not in self._hb_done
                    and now - self._hb_seen.get(r, now) > cfg.hb_timeout]
        for r in suspects:
            ctx.fire(edat.ALL, "suspect", r)
        active = [r for r in st.alive
                  if r not in self._hb_done and r not in suspects
                  and not self.states[r].done
                  and not self.runtime.is_dead(r)]
        if active:
            ctx.fire_after(cfg.hb_interval, edat.SELF, "__hbtick")

    def _on_suspect(self, ctx: edat.Context, events):
        suspected = events[0].data
        st = self.states[ctx.rank]
        if suspected == ctx.rank:
            st.done = True          # fence myself: fail-stop enforcement
            return
        if suspected in st.alive:
            st.alive.remove(suspected)
            if ctx.rank == 0:
                self._hb_done.add(suspected)
            if ctx.rank == min(st.alive) and self.cfg.ckpt_dir:
                step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
                ctx.fire(edat.ALL, "recover", {"step": step})

    def _on_rank_failed(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        dead = events[0].data
        if dead not in st.alive:
            # already handled: the heartbeat-suspect path beat this event
            # (or vice versa).  Firing "recover" again here was the known
            # duplicate-recovery flake — two rollbacks racing the restarted
            # step chain could diverge the replicas.
            return
        st.alive.remove(dead)
        # leader triggers a coordinated rollback to the last durable ckpt
        if ctx.rank == min(st.alive) and self.cfg.ckpt_dir:
            step = ckpt_store.latest_step(self.cfg.ckpt_dir) or 0
            ctx.fire(edat.ALL, "recover", {"step": step})

    def _on_recover(self, ctx: edat.Context, events):
        st = self.states[ctx.rank]
        if self.runtime.is_dead(ctx.rank) or st.done:
            return
        info = events[0].data
        cfg = self.cfg
        proto = {"params": st.params, "opt": st.opt_state}
        try:
            step, tree, _ = ckpt_store.restore(cfg.ckpt_dir, proto,
                                               step=info["step"])
        except FileNotFoundError:
            return
        with st.mu:
            st.params = jax.tree.map(jnp.asarray, tree["params"])
            st.opt_state = jax.tree.map(jnp.asarray, tree["opt"])
            st.step = step
            st.epoch += 1        # invalidates in-flight grads
            epoch_now = st.epoch
        ctx.fire(edat.SELF, "go", epoch_now)
