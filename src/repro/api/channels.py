"""Typed event channels (v2 API).

A :class:`Channel` replaces a raw event-id string everywhere an eid is
accepted (``submit`` / ``fire`` / ``wait`` / ``fire_batch`` deps and
targets).  It subclasses :class:`str`, so the runtime's routing tables,
wire frames and FIFO bookkeeping see exactly the interned id — channels
add *zero* hot-path cost over raw strings — while carrying an optional
payload type that is validated at ``fire`` time.

Raw strings keep working: an undeclared plain eid behaves as an
anonymous, untyped channel (unless the surrounding :class:`Program`
declares its channels, in which case a typo fails fast with
``KeyError`` instead of silently never matching).
"""
from __future__ import annotations

import sys
from typing import Any, Tuple, Type, Union

PayloadSpec = Union[Type[Any], Tuple[Type[Any], ...], None]


class Channel(str):
    """A typed event channel: an interned event id plus a payload type.

    ::

        GRAD = edat.Channel("grad", payload=dict)
        ctx.fire(edat.ALL, GRAD, {"rank": 0, "grads": g})   # type-checked
        ctx.submit(step, deps=[(edat.ANY, GRAD)])           # routes as "grad"

    ``payload`` is a type (or tuple of types) that ``fire`` payloads must
    satisfy; ``None`` (the default) accepts anything.  A ``None`` payload
    is always allowed — events without data are common (pure signals).

    ``durable=True`` opts just this channel into the durable task log
    (:mod:`repro.durable`): its fires are journaled and replayed onto
    survivors (or an elastic replacement) if the consuming rank dies.
    Durable payloads must pickle even on the inproc transport, and
    consumers should depend on ``(ANY, channel)`` — replayed events carry
    the recovery coordinator's rank as their source.
    """

    __slots__ = ("payload", "durable")

    def __new__(cls, eid: str, payload: PayloadSpec = None,
                durable: bool = False) -> "Channel":
        if eid.startswith("__"):
            raise ValueError(
                f"channel id {eid!r} is reserved (the __-prefix namespace "
                f"belongs to runtime-internal and machine-generated events)")
        self = super().__new__(cls, sys.intern(str(eid)))
        self.payload = payload
        self.durable = bool(durable)
        return self

    # -- validation -----------------------------------------------------------
    def validate(self, data: Any) -> None:
        """Raise ``TypeError`` if ``data`` does not satisfy the channel's
        payload type.  Called by ``Context.fire`` / ``fire_batch`` before
        any termination counter is touched."""
        t = self.payload
        if t is None or data is None:
            return
        if not isinstance(data, t):
            raise TypeError(
                f"channel {str.__str__(self)!r} expects payload of type "
                f"{getattr(t, '__name__', t)}, got {type(data).__name__}")

    # -- plumbing -------------------------------------------------------------
    def __reduce__(self):
        # events carry their eid across the socket transport: reconstruct
        # as a Channel (re-interning the id) rather than a bare str
        return (Channel, (str.__str__(self), self.payload, self.durable))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        extra = ""
        if self.payload is not None:
            extra += (f", payload="
                      f"{getattr(self.payload, '__name__', self.payload)}")
        if self.durable:
            extra += ", durable=True"
        return f"Channel({str.__repr__(self)}{extra})"
