"""repro.api — the v2 public surface, re-exported as ``repro.edat``.

One declarative entry point (:class:`Session` / :func:`run`), typed
event channels (:class:`Channel`), task handles and driver-side futures
over the non-blocking event core, plus re-exports of everything a
program touches: core primitives, collective patterns, timers, and the
distribution layer.  This package ships ``py.typed`` — the surface is
fully annotated for downstream type checking.

::

    from repro import edat

    TOKEN = edat.Channel("token", payload=int)

    def main(ctx: edat.Context) -> None:
        left = (ctx.rank - 1) % ctx.n_ranks
        ctx.submit_persistent(relay, deps=[(left, TOKEN)])
        if ctx.rank == 0:
            ctx.fire(1, TOKEN, 1)

    edat.run(main, ranks=4)                             # threads
    edat.run(main, ranks=4, procs=2, transport="socket")  # processes
"""
from typing import Any

# -- core primitives ---------------------------------------------------------
from repro.core import (ALL, ANY, SELF, RANK_FAILED, Context, Dep,
                        EdatDeadlockError, EdatTaskError, Event, EventRouter,
                        InProcTransport, Message, Runtime, Scheduler,
                        TaskHandle, TimerHandle, Transport, dep)
# -- collective patterns (previously deep-import only) -----------------------
from repro.core.patterns import allreduce, barrier, tree_reduce, wait_barrier
# -- distribution layer ------------------------------------------------------
from repro.net import ProcessGroup, SocketTransport, launch_processes
# -- v2 surface --------------------------------------------------------------
from .channels import Channel
from .program import DeferredProgram, Program, deferred
from .session import Future, RankDiedError, Session, run


def fire_after(ctx: Context, delay: float, target: Any, eid: str,
               data: Any = None) -> TimerHandle:
    """Machine-generated timer event (paper §VII): fire ``eid`` at
    ``target`` after ``delay`` seconds.  Facade-level convenience for
    ``ctx.fire_after`` — cancellable via the returned
    :class:`TimerHandle`."""
    return ctx.fire_after(delay, target, eid, data)


__all__ = [
    # v2 entry points
    "Session", "run", "Channel", "Program", "DeferredProgram", "deferred",
    "Future", "RankDiedError", "TaskHandle",
    # core primitives
    "ALL", "ANY", "SELF", "RANK_FAILED", "Dep", "Event", "dep",
    "Context", "Runtime", "EdatDeadlockError", "EdatTaskError",
    "TimerHandle", "Scheduler", "EventRouter",
    "InProcTransport", "Message", "Transport",
    # collectives + timers
    "barrier", "wait_barrier", "allreduce", "tree_reduce", "fire_after",
    # distribution layer
    "ProcessGroup", "SocketTransport", "launch_processes",
]
