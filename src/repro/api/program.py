"""The ``Program`` protocol: what a Session runs.

A program is any object with a ``start(ctx)`` SPMD attach point —
``start`` is called once per hosted rank by the session's runtime, and
the object may additionally declare:

* ``channels`` — an iterable of :class:`~repro.api.channels.Channel`
  (or ids) naming the program's event vocabulary.  When declared, the
  session enforces it: firing or depending on an undeclared id raises
  ``KeyError`` at the call site (``__``-prefixed internal ids exempt).
* ``result()`` — called on the process hosting rank 0 *after* clean
  global termination; whatever it returns is what
  :meth:`repro.api.session.Session.gather` hands back to the driver
  (for socket sessions it must pickle).

Plain ``main(ctx)`` callables are accepted everywhere a program is — an
anonymous program with no declared channels and no result.

For socket sessions the program must reach the spawned child processes.
Either pass a picklable program instance, or wrap a (picklable,
module-level) factory with :func:`deferred` so each child builds its own
program — once per *process*, shared by all co-located ranks — which is
how per-process state that cannot pickle (jitted functions, locks,
large regenerable graphs) gets constructed where it is used.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol, runtime_checkable

from repro.core.runtime import Context


@runtime_checkable
class Program(Protocol):
    """Structural protocol: anything with an SPMD ``start(ctx)``."""

    def start(self, ctx: Context) -> None:
        """Attach one rank of the program to the running session."""
        ...  # pragma: no cover - protocol


class DeferredProgram:
    """A program built lazily by ``factory(*args, **kwargs)``.

    For inproc sessions the factory runs once in the driver process; for
    socket sessions it runs once per spawned child process (co-located
    ranks share the instance).  The factory and its arguments must be
    picklable for socket transports (module-level callables + plain
    data), the program it returns need not be.
    """

    __slots__ = ("factory", "args", "kwargs")

    def __init__(self, factory: Callable[..., Any], args: tuple,
                 kwargs: dict):
        self.factory = factory
        self.args = args
        self.kwargs = kwargs

    def build(self) -> Any:
        return self.factory(*self.args, **self.kwargs)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        name = getattr(self.factory, "__name__", repr(self.factory))
        return f"deferred({name}, ...)"


def deferred(factory: Callable[..., Any], *args: Any,
             **kwargs: Any) -> DeferredProgram:
    """Defer program construction to the process that runs it."""
    return DeferredProgram(factory, args, kwargs)
