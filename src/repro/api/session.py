"""``Session`` — the one way EDAT programs start (v2 API).

A session owns everything the v1 surface scattered over
``Runtime(n).run(main)``, ``launch_processes``/``ProcessGroup`` and the
per-use-case ``distributed_*`` helpers: runtime construction,
bootstrap/rendezvous, process spawn, result gathering and teardown.
The same program runs on either transport::

    with edat.Session(ranks=4, procs=2, transport="socket") as s:
        s.run(edat.deferred(bfs_program, 4, scale=12))
        parents = s.gather()["parent"]

    res = edat.run(my_program, ranks=4)          # inproc one-liner

Transports:

* ``"inproc"`` — threads-as-ranks over :class:`InProcTransport` in the
  driver process.  ``run`` is synchronous; the program object is shared
  with the driver, so ``gather()`` is a direct method call.
* ``"socket"`` — one OS process per ``procs`` bucket of ranks over the
  coalescing :class:`~repro.net.SocketTransport` (``placement`` for
  explicit rank->process maps).  The program (or its
  :func:`~repro.api.program.deferred` factory) is pickled to the
  children; the process hosting rank 0 writes ``program.result()`` to a
  session-private spool file after clean termination, and ``gather()``
  reads it back — the generic replacement for the per-use-case out-dir
  persistence glue.

Driver-side futures: :meth:`Session.call` schedules ``fn`` as a task on
a rank and returns a :class:`Future` whose value is delivered by an
event fired at task return (``__sess.result`` to rank 0).  Futures
resolve when the session round runs — ``Future.result()`` triggers the
round if needed — giving blocking driver-side composition over the
non-blocking event core.
"""
from __future__ import annotations

import itertools
import os
import pickle
import shutil
import tempfile
import threading
import time
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from repro.core.event import ANY
from repro.core.metrics import RunStats, merge_metrics
from repro.core.runtime import Context, RankDiedError, Runtime

from .program import DeferredProgram, Program

ProgramLike = Union[Program, DeferredProgram, Callable[[Context], None]]
DepLike = Tuple[Any, str]

_UNSET = object()

# RankDiedError lives in repro.core.runtime (re-exported here for the
# stable ``edat.RankDiedError`` surface): the same class covers a driver
# future whose callee rank's process died AND a survivor rank observing
# the termination coordinator's death — both "the round cannot complete
# from this observer's point of view".


class Future:
    """Driver-side handle for a :meth:`Session.call` result."""

    def __init__(self, session: "Session", cid: int, rank: int = -1):
        self._session = session
        self.cid = cid
        self.rank = rank
        self._value: Any = _UNSET

    def done(self) -> bool:
        return self._value is not _UNSET

    def _set(self, value: Any) -> None:
        self._value = value

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block until the call's task has run and returned (driving the
        session round if it has not started yet).

        Raises ``TimeoutError`` if the round is still running after
        ``timeout`` seconds — the round is left in flight and the future
        stays retryable (the session is *not* torn down).  Raises
        :class:`RankDiedError` when the round is over but the process
        hosting the callee rank exited abnormally, naming the dead rank."""
        if not self.done():
            self._session._resolve(timeout)
        if not self.done():
            code = self._session._rank_exitcode(self.rank)
            if code not in (None, 0):
                raise RankDiedError(
                    f"call {self.cid} was scheduled on rank {self.rank}, "
                    f"whose process exited with code {code} before the "
                    f"call's task returned")
            raise RuntimeError(
                f"call {self.cid} produced no result (was its process "
                f"killed, or the session round skipped?)")
        return self._value


class _SessionMain:
    """The SPMD main a session hands to its runtime (picklable for
    spawned socket children).  Builds the program once per *process*
    (all co-located rank threads share it), declares its channels on
    every rank context, schedules the driver's queued calls, and — on
    the process hosting rank 0 — spools ``program.result()`` plus the
    collected call results after clean termination (``_edat_finalize``
    is invoked by the launcher post-run)."""

    def __init__(self, program: Optional[Any] = None,
                 deferred: Optional[DeferredProgram] = None,
                 mainfn: Optional[Callable[[Context], None]] = None,
                 calls: Sequence[tuple] = (),
                 result_path: Optional[str] = None):
        self.program = program
        self.deferred = deferred
        self.mainfn = mainfn
        self.calls = list(calls)
        self.result_path = result_path
        self._init_local()

    # -- pickling: per-process state stays behind ----------------------------
    def _init_local(self) -> None:
        self._mu = threading.Lock()
        self._built: Any = _UNSET       # sentinel: a program may be falsy
        self.call_results: Dict[int, Any] = {}

    def __getstate__(self) -> dict:
        return {"program": self.program, "deferred": self.deferred,
                "mainfn": self.mainfn, "calls": self.calls,
                "result_path": self.result_path}

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._init_local()

    # -- program resolution ---------------------------------------------------
    def resolved(self) -> Optional[Any]:
        """The program instance for this process (built on first use).
        ``None`` only for anonymous mains / calls-only rounds — a falsy
        program object (e.g. one subclassing a container) still counts."""
        with self._mu:
            if self._built is _UNSET:
                if self.program is not None:
                    self._built = self.program
                elif self.deferred is not None:
                    self._built = self.deferred.build()
                else:
                    self._built = None       # anonymous main / calls only
            return self._built

    # -- SPMD main ------------------------------------------------------------
    def __call__(self, ctx: Context) -> None:
        prog = self.resolved()
        if prog is not None:
            chans = getattr(prog, "channels", None)
            if chans:
                ctx.declare_channels(chans)
        if ctx.rank == 0 and self.calls:
            ctx.submit_persistent(self._collect,
                                  deps=[(ANY, "__sess.result")],
                                  name="__sess.collector")
        for cid, rank, fn, deps in self.calls:
            if rank == ctx.rank:
                ctx.submit(self._call_task(cid, fn), deps=deps)
        if prog is not None:
            prog.start(ctx)
        elif self.mainfn is not None:
            self.mainfn(ctx)

    def _call_task(self, cid: int, fn: Callable) -> Callable:
        def task(ctx: Context, events) -> None:
            val = fn(ctx, events)
            ctx.fire(0, "__sess.result", {"cid": cid, "val": val})
        return task

    def _collect(self, ctx: Context, events) -> None:
        d = events[0].data
        self.call_results[d["cid"]] = d["val"]

    # -- post-run (invoked by the launcher in the rank-0 child via the
    # collision-proof `_edat_finalize` hook name) -----------------------------
    def _edat_finalize(self, ranks: Sequence[int],
                       stats: Dict[str, Any]) -> None:
        if self.result_path is None or 0 not in ranks:
            return
        prog = None if self._built is _UNSET else self._built
        res_fn = getattr(prog, "result", None) if prog is not None else None
        payload = {"has_result": res_fn is not None,
                   "result": res_fn() if res_fn is not None else None,
                   "calls": dict(self.call_results)}
        tmp = self.result_path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, self.result_path)


class Session:
    """One EDAT execution context: ``ranks`` SPMD ranks over a chosen
    transport, with construction, spawn, gathering and teardown owned
    here.  Use as a context manager; :func:`repro.api.run` is the
    one-shot convenience.

    Parameters mirror the full v1 surface: ``procs``/``placement`` pack
    ranks into OS processes (socket only), ``coalesce`` /
    ``flush_interval`` / ``max_batch_bytes`` tune the writer-side
    coalescing fast path, ``hb_interval``/``hb_timeout`` the transport
    failure detector, ``workers_per_rank``/``progress``/``unconsumed``
    the per-rank runtime.  ``timeout`` is the default per-round run
    deadline."""

    def __init__(self, ranks: int, *,
                 procs: Optional[int] = None,
                 transport: str = "inproc",
                 workers_per_rank: int = 1,
                 progress: str = "thread",
                 unconsumed: str = "error",
                 coalesce: bool = True,
                 placement: Optional[Sequence[Sequence[int]]] = None,
                 flush_interval: float = 0.0,
                 max_batch_bytes: int = 1 << 20,
                 hb_interval: float = 0.5,
                 hb_timeout: float = 5.0,
                 host: str = "127.0.0.1",
                 timeout: float = 120.0,
                 metrics: bool = True,
                 trace: bool = False,
                 durable: Union[bool, dict, None] = None,
                 elastic: bool = False):
        if transport not in ("inproc", "socket"):
            raise ValueError(f"unknown transport {transport!r} "
                             f"(expected 'inproc' or 'socket')")
        if transport == "inproc" and (procs not in (None, 1)
                                      or placement is not None):
            # a forgotten transport="socket" must not silently run as
            # threads: process packing only exists on the socket transport
            raise ValueError(
                "procs/placement require transport='socket' (inproc "
                "sessions run every rank as a thread in this process)")
        if transport == "inproc" and elastic:
            raise ValueError(
                "elastic=True requires transport='socket' (elastic join "
                "replaces a dead OS process; inproc ranks are threads)")
        self.ranks = int(ranks)
        self.procs = procs
        self.transport = transport
        self.workers_per_rank = workers_per_rank
        self.progress = progress
        self.unconsumed = unconsumed
        self.coalesce = coalesce
        self.placement_spec = placement
        self.flush_interval = flush_interval
        self.max_batch_bytes = max_batch_bytes
        self.hb_interval = hb_interval
        self.hb_timeout = hb_timeout
        self.host = host
        self.timeout = timeout
        #: always-on per-channel/rank/transport counters (``metrics=False``
        #: disables them for A/B overhead runs); ``trace=True`` additionally
        #: records bounded per-rank task/event timelines in the stats
        self.metrics = bool(metrics)
        self.trace = bool(trace)
        #: durable task log + automated replay (:mod:`repro.durable`):
        #: ``True`` journals every user channel, a dict refines it
        #: (``path``/``channels``/``all``/``join_timeout``/``settle``).
        #: Socket rounds default the log to a session-private sqlite file
        #: shared by every rank process (``durable_log_path``).
        self.durable = durable
        #: keep the rank-0 coordinator listening after bootstrap so a
        #: replacement process can elastically join a running socket
        #: round (see :meth:`respawn`)
        self.elastic = bool(elastic)
        self.durable_log_path: Optional[str] = None
        #: rank-0 run stats of the most recent round.  A callable dict:
        #: ``s.stats["run_seconds"]`` and ``s.stats()`` both work; with
        #: metrics on it also carries the structured ``"channels"`` /
        #: ``"ranks"`` / ``"transport"`` sections (merged across processes
        #: for socket rounds)
        self.stats: RunStats = RunStats()
        self._runtime: Optional[Runtime] = None    # inproc, current round
        self._pg = None                            # socket, current round
        self._tmpdir: Optional[str] = None
        self._result_path: Optional[str] = None
        self._gathered: Any = None
        self._has_result = False
        self._calls: List[tuple] = []
        self._futures: Dict[int, Future] = {}
        self._cids = itertools.count()

    # ------------------------------------------------------------- lifecycle
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Reap any still-running spawned processes and drop spool files.
        Harmless to call twice (context-manager exit does)."""
        if self._pg is not None:
            try:
                self.wait(check=False)
            except Exception:
                pass
        self._cleanup_spool()
        self._runtime = None

    def _cleanup_spool(self) -> None:
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
            self._tmpdir = None
            self._result_path = None

    # ------------------------------------------------------------ inproc run
    @property
    def runtime(self) -> Runtime:
        """The inproc round's :class:`Runtime` (built lazily) — exposed so
        drivers can inject faults (``kill_rank``) while ``run`` is in
        flight.  Socket sessions have no in-driver runtime."""
        if self.transport != "inproc":
            raise AttributeError(
                "a socket Session has no in-driver runtime; use "
                "kill()/exitcodes() for process-level fault injection")
        if self._runtime is None:
            self._runtime = Runtime(self.ranks,
                                    workers_per_rank=self.workers_per_rank,
                                    progress=self.progress,
                                    unconsumed=self.unconsumed,
                                    metrics=self.metrics,
                                    trace=self.trace,
                                    durable=self.durable)
        return self._runtime

    def run(self, program: Optional[ProgramLike] = None, *,
            timeout: Optional[float] = None) -> Dict[str, Any]:
        """Run one round of ``program`` (a :class:`Program`, a
        :func:`deferred` factory, or a plain ``main(ctx)``) to global
        termination; returns the rank-0 run stats.  Queued
        :meth:`call`\\ s ride along.  ``gather()`` afterwards returns the
        program's result."""
        if self.transport == "inproc":
            return self._run_inproc(program, timeout or self.timeout)
        self.start(program, timeout=timeout)
        return self.wait()

    def _run_inproc(self, program: Optional[ProgramLike],
                    timeout: float) -> Dict[str, Any]:
        prog, dfr, mainfn = _split_program(program)
        if dfr is not None:
            prog, dfr = dfr.build(), None
        self._gathered, self._has_result = None, False   # round-scoped
        main = _SessionMain(program=prog, mainfn=mainfn,
                            calls=self._take_calls())
        rt = self.runtime
        t0 = time.monotonic()
        try:
            stats = RunStats(rt._run_internal(main, timeout=timeout))
        finally:
            self._runtime = None          # a Runtime is single-shot
        stats.setdefault("run_seconds", time.monotonic() - t0)
        mt = rt.metrics()
        if mt is not None:
            # same canonical shape as the cross-process socket merge
            stats.update(merge_metrics([(0, mt)]))
        self.stats = stats
        for cid, val in main.call_results.items():
            fut = self._futures.pop(cid, None)
            if fut is not None:
                fut._set(val)
        res_fn = getattr(prog, "result", None) if prog is not None else None
        self._has_result = res_fn is not None
        self._gathered = res_fn() if res_fn is not None else None
        return stats

    # ------------------------------------------------------------ socket run
    def start(self, program: Optional[ProgramLike] = None, *,
              timeout: Optional[float] = None) -> "Session":
        """Spawn the socket round without blocking (chaos tests kill
        processes mid-run); :meth:`wait` joins it.  Inproc sessions are
        synchronous — use :meth:`run`."""
        if self.transport != "socket":
            raise RuntimeError("start() is for socket sessions; inproc "
                               "sessions run synchronously via run()")
        if self._pg is not None:
            raise RuntimeError("a round is already in flight; wait() first")
        from repro.net.launch import ProcessGroup
        prog, dfr, mainfn = _split_program(program)
        self._gathered, self._has_result = None, False   # round-scoped
        self._cleanup_spool()
        self._tmpdir = tempfile.mkdtemp(prefix="edat_session_")
        self._result_path = os.path.join(self._tmpdir, "result.pkl")
        main = _SessionMain(program=prog, deferred=dfr, mainfn=mainfn,
                            calls=self._take_calls(),
                            result_path=self._result_path)
        kwargs: Dict[str, Any] = dict(
            run_timeout=timeout or self.timeout, host=self.host,
            workers_per_rank=self.workers_per_rank, progress=self.progress,
            unconsumed=self.unconsumed, coalesce=self.coalesce,
            flush_interval=self.flush_interval,
            max_batch_bytes=self.max_batch_bytes,
            hb_interval=self.hb_interval, hb_timeout=self.hb_timeout,
            metrics=self.metrics, trace=self.trace)
        if self.elastic:
            kwargs["elastic"] = True
        if self.durable:
            spec = (dict(self.durable) if isinstance(self.durable, dict)
                    else {})
            # every rank process appends to one shared sqlite file; it
            # lives beside the result spool so teardown reaps both
            spec.setdefault("path",
                            os.path.join(self._tmpdir, "durable.sqlite"))
            self.durable_log_path = spec["path"]
            kwargs["durable"] = spec
        if self.placement_spec is not None:
            kwargs["placement"] = self.placement_spec
        else:
            kwargs["n_procs"] = self.procs
        self._pg = ProcessGroup(self.ranks, main, **kwargs)
        self._pg.start()
        return self

    def wait(self, timeout: Optional[float] = None,
             check: bool = True) -> Dict[str, Any]:
        """Join the spawned round; returns rank-0 stats.  With ``check``
        (default) unexpected child failures raise; chaos tests pass
        ``check=False`` after :meth:`kill`.  The gathered result (if the
        rank-0 process terminated cleanly) is loaded here."""
        if self._pg is None:
            return self.stats
        pg, self._pg = self._pg, None
        self._last_pg = pg
        try:
            self.stats = RunStats(pg.wait(timeout, check=check) or {})
        finally:
            self._load_spool()
        return self.stats

    def _load_spool(self) -> None:
        path = self._result_path
        if path is None or not os.path.exists(path):
            return
        with open(path, "rb") as f:
            payload = pickle.load(f)
        self._cleanup_spool()
        self._has_result = payload["has_result"]
        self._gathered = payload["result"]
        for cid, val in payload["calls"].items():
            fut = self._futures.pop(cid, None)
            if fut is not None:
                fut._set(val)

    def kill(self, rank: int) -> None:
        """SIGKILL the spawned process hosting ``rank`` (socket rounds) —
        process-granular fault injection; every co-located rank dies and
        survivors see one RANK_FAILED per lost rank."""
        if self._pg is None:
            raise RuntimeError("no spawned round in flight")
        self._pg.kill(rank)

    def respawn(self, rank: int, ready_file: Optional[str] = None) -> None:
        """Launch an elastic replacement for the (dead) process that hosted
        ``rank``; requires ``Session(elastic=True)``.  The newcomer joins
        the running world mid-round, re-hosts every rank of that process
        and — in durable mode — drains the replayed backlog.  When
        ``ready_file`` is given it is touched once the mesh splice is
        complete."""
        if self._pg is None:
            raise RuntimeError("no spawned round in flight")
        self._pg.respawn(rank, ready_file=ready_file)

    @property
    def placement(self) -> Optional[List[Tuple[int, ...]]]:
        """Rank->process placement of the current/last socket round."""
        pg = self._pg or getattr(self, "_last_pg", None)
        return None if pg is None else list(pg.placement)

    def exitcodes(self) -> Dict[int, Optional[int]]:
        """Per-rank exit codes of the current/last socket round."""
        pg = self._pg or getattr(self, "_last_pg", None)
        if pg is None:
            raise RuntimeError("no spawned round to inspect")
        return pg.exitcodes()

    # -------------------------------------------------------------- results
    @property
    def has_result(self) -> bool:
        """True when the last round's program defined ``result()``."""
        return self._has_result

    def gather(self) -> Any:
        """The program's gathered result from the last completed round
        (``None`` for anonymous mains, or when the rank-0 process died
        before finalizing)."""
        if self._pg is not None:
            self.wait()
        return self._gathered

    # ---------------------------------------------------------- driver calls
    def call(self, rank: int, fn: Callable, deps: Sequence[DepLike] = ()
             ) -> Future:
        """Schedule ``fn(ctx, events)`` as a task on ``rank`` for the next
        round; the returned :class:`Future` resolves with ``fn``'s return
        value, delivered by an event fired at task return.  For socket
        sessions ``fn`` (and its return value) must pickle."""
        cid = next(self._cids)
        fut = Future(self, cid, int(rank))
        self._futures[cid] = fut
        self._calls.append((cid, int(rank), fn, list(deps)))
        return fut

    def _take_calls(self) -> List[tuple]:
        calls, self._calls = self._calls, []
        return calls

    def _rank_exitcode(self, rank: int) -> Optional[int]:
        """Exit code of the process that hosted ``rank`` in the current or
        last socket round; None for inproc sessions / unspawned rounds."""
        pg = self._pg or getattr(self, "_last_pg", None)
        if pg is None:
            return None
        return pg.exitcodes().get(rank)

    def _resolve(self, timeout: Optional[float]) -> None:
        """Drive pending futures to resolution: join an in-flight round,
        else run a calls-only round.

        With a ``timeout`` and a spawned round still in flight, the join
        is *soft*: if the deadline passes the round is left running and
        ``TimeoutError`` is raised — a slow round must stay retryable,
        not be SIGKILLed by the deadline (which the hard ``wait`` would
        do, wedging every other future of the round)."""
        if self._pg is not None:
            if timeout is not None and not self._pg.join_all(timeout):
                raise TimeoutError(
                    f"session round still running after {timeout}s; the "
                    f"round is left in flight — retry result() later")
            self.wait()
        elif self._calls:
            self.run(None, timeout=timeout)


def _split_program(program: Optional[ProgramLike]
                   ) -> Tuple[Optional[Any], Optional[DeferredProgram],
                              Optional[Callable]]:
    """Classify a program-like into (instance, deferred, plain-main)."""
    if program is None:
        return None, None, None
    if isinstance(program, DeferredProgram):
        return None, program, None
    if hasattr(program, "start"):
        return program, None, None
    if callable(program):
        return None, None, program
    raise TypeError(
        f"not a program: {program!r} (expected an object with start(ctx), "
        f"an edat.deferred(...) factory, or a main(ctx) callable)")


def run(program: ProgramLike, *, ranks: int,
        procs: Optional[int] = None, transport: str = "inproc",
        timeout: float = 120.0, **session_kwargs: Any) -> Any:
    """One-shot convenience: construct a :class:`Session`, run
    ``program`` to termination, and return its gathered result (or the
    run stats, for programs/mains that define no ``result()``)::

        edat.run(main, ranks=2)
        edat.run(edat.deferred(bfs_program, 4, scale=12),
                 ranks=4, procs=2, transport="socket")
    """
    with Session(ranks, procs=procs, transport=transport,
                 timeout=timeout, **session_kwargs) as s:
        s.run(program)
        return s.gather() if s.has_result else dict(s.stats)
