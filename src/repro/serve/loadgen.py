"""Open-loop synthetic load for the serving subsystem.

The generator is *open-loop*: request arrival times are drawn up front
from a Poisson process at the configured aggregate rate, and a client
fires each request at its scheduled instant regardless of how many
responses have come back.  Under overload the arrival schedule does not
slow down to match the server — queueing delay shows up in the measured
latency instead of being silently absorbed by a closed feedback loop,
which is the honest way to measure a saturated server (cf. the
coordinated-omission literature).

Everything is deterministic per ``(spec.seed, client)``: a benchmark can
hand the *same* schedule to the event-driven server and to the
sequential baseline, and a test can regenerate the exact request list a
spawned client fired.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """Knobs of the synthetic workload.

    ``rps`` is the *aggregate* arrival rate across all clients; each of
    ``n`` clients runs an independent Poisson process at ``rps / n``
    (the superposition of independent Poisson processes is Poisson at
    the summed rate, so the offered load is exactly ``rps``).

    Prompt lengths are drawn from the discrete ``prompt_lens`` buckets
    (weighted by ``prompt_weights`` when given) rather than a continuous
    distribution: every distinct prompt length is a fresh XLA
    compilation of the prefill step, so a handful of buckets keeps the
    compile-cache small while still exercising mixed-length admission.
    Output lengths are uniform ints in ``[max_new_lo, max_new_hi]``.
    """

    rps: float = 8.0
    requests: int = 16                    # total across all clients
    prompt_lens: Tuple[int, ...] = (4, 8, 16)
    prompt_weights: Optional[Tuple[float, ...]] = None
    max_new_lo: int = 4
    max_new_hi: int = 16
    seed: int = 0

    def split(self, n_clients: int) -> List[int]:
        """Per-client request counts (first clients absorb the remainder)."""
        base, rem = divmod(self.requests, n_clients)
        return [base + (1 if c < rem else 0) for c in range(n_clients)]


def client_schedule(spec: LoadSpec, client: int, n_clients: int,
                    vocab: int) -> List[Dict[str, Any]]:
    """The full request list for one client: ``[{id, t, prompt, max_new}]``
    with ``t`` the arrival offset (seconds from load start), sorted.

    Request ids are globally unique (``client * 1_000_000 + i``) so the
    server can attribute records without coordination.
    """
    n = spec.split(n_clients)[client]
    rng = np.random.default_rng((spec.seed, client))
    rate = spec.rps / n_clients
    gaps = rng.exponential(1.0 / rate, size=n) if rate > 0 else np.zeros(n)
    times = np.cumsum(gaps)
    if spec.prompt_weights is not None:
        w = np.asarray(spec.prompt_weights, np.float64)
        w = w / w.sum()
    else:
        w = None
    out = []
    for i in range(n):
        plen = int(rng.choice(spec.prompt_lens, p=w))
        out.append({
            "id": client * 1_000_000 + i,
            "t": float(times[i]),
            "prompt": rng.integers(0, vocab, size=plen).tolist(),
            "max_new": int(rng.integers(spec.max_new_lo,
                                        spec.max_new_hi + 1)),
        })
    return out


def all_requests(spec: LoadSpec, n_clients: int,
                 vocab: int) -> List[Dict[str, Any]]:
    """Every client's schedule merged and sorted by arrival time — the
    exact offered load, for driving the sequential baseline."""
    reqs: List[Dict[str, Any]] = []
    for c in range(n_clients):
        reqs.extend(client_schedule(spec, c, n_clients, vocab))
    reqs.sort(key=lambda r: r["t"])
    return reqs


# ------------------------------------------------------------------ summaries
def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 for an empty input."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[k])


def summarize(records: Sequence[Mapping[str, Any]],
              wall_s: float) -> Dict[str, Any]:
    """Roll per-request server records into the benchmark's headline
    numbers: requests/s, tokens/s, p50/p99 time-to-first-token and
    per-token decode latency.

    Latencies are measured from ``t_sched`` — the instant the open-loop
    schedule *wanted* to fire the request — not from the actual fire
    time, so client-side throttling (backpressure) and queueing both
    show up in TTFT instead of being hidden.
    """
    ttft = [r["t_first"] - r["t_sched"] for r in records]
    per_tok = [(r["t_done"] - r["t_first"]) / (r["n_out"] - 1)
               for r in records if r["n_out"] > 1]
    n_tokens = sum(r["n_out"] for r in records)
    wall = max(wall_s, 1e-9)
    return {
        "requests": len(records),
        "tokens": n_tokens,
        "wall_s": wall_s,
        "requests_per_s": len(records) / wall,
        "tokens_per_s": n_tokens / wall,
        "ttft_p50_ms": percentile(ttft, 50) * 1e3,
        "ttft_p99_ms": percentile(ttft, 99) * 1e3,
        "per_token_p50_ms": percentile(per_tok, 50) * 1e3,
        "per_token_p99_ms": percentile(per_tok, 99) * 1e3,
    }
