"""The naive sequential serving baseline the benchmark compares against.

One request at a time, batch of one, prefill then decode to completion —
the same jitted steps and the same greedy argmax as the event-driven
server (so tokens match token-for-token), but no continuous batching, no
prefill/decode overlap, no admission control.  Arrivals are replayed in
real time from the same open-loop schedule, so queueing delay under
overload shows up in the baseline's latency numbers exactly as it does
for the event-driven server.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Mapping, Sequence

from .engine import DEFAULT_MAX_LEN, SequentialEngine, serving_cfg


def run_sequential(cfg, requests: Sequence[Mapping[str, Any]], *,
                   max_len: int = DEFAULT_MAX_LEN,
                   seed: int = 0,
                   realtime: bool = True) -> List[Dict[str, Any]]:
    """Serve ``requests`` (a :func:`~repro.serve.loadgen.all_requests`
    list, sorted by arrival offset ``t``) strictly one at a time.
    Returns records in the same schema the event-driven server produces,
    so :func:`~repro.serve.loadgen.summarize` applies to both.

    ``realtime=False`` skips the arrival sleeps (tests that only care
    about tokens, not latency)."""
    eng = SequentialEngine(serving_cfg(cfg, max_len), max_len=max_len,
                           seed=seed)
    eng.warmup(sorted({len(r["prompt"]) for r in requests}))
    records: List[Dict[str, Any]] = []
    t0 = time.monotonic()
    for req in requests:
        target = t0 + req["t"]
        if realtime:
            delay = target - time.monotonic()
            if delay > 0:
                time.sleep(delay)
        tokens, t_first, t_done = eng.serve_one(req["prompt"],
                                                req["max_new"])
        records.append({
            "id": req["id"], "client": req.get("client", -1),
            "prompt_len": len(req["prompt"]), "tokens": tokens,
            "n_out": len(tokens), "t_sched": target, "t_send": target,
            "t_recv": target, "t_admit": target, "t_first": t_first,
            "t_done": t_done, "throttled_s": 0.0,
        })
    return records
