"""Event-driven LM serving as an EDAT :class:`~repro.api.program.Program`.

Every interaction is an event on a declared typed channel; there is no
polling loop anywhere::

    client --request--> server          (ANY-sourced, open-loop loadgen)
    server --admit--> server            (SELF: slot reserved, prefill task)
    server --decode_tick--> server      (SELF: one self-sustaining chain)
    server --response--> client         (completion, tokens + timings)
    server --backpressure--> clients    (admission queue crossed its bound)

The server rank runs four persistent tasks:

* ``serve.request`` — admission control.  Enqueues the request, fires
  ``backpressure`` on/off signals around the queue bound, and reserves
  free decode slots by firing ``admit`` events.
* ``serve.prefill`` — one ``admit`` event per reserved slot.  Runs the
  prompt-length-dependent prefill *outside* the server lock (a long
  prompt never stalls the decode batch), then takes the lock only to
  splice the prefilled cache into its slot — the per-slot KV reset that
  makes slot reuse safe.
* ``serve.decode`` — the continuous-batching tick.  Exactly one
  self-sustaining ``decode_tick`` chain exists at any time, guarded by a
  ``_ticking`` flag under the server lock: a request arriving mid-decode
  joins the running batch instead of spawning a second chain that would
  burn redundant ticks.  Each tick advances every live slot one greedy
  token; completions fire ``response`` and free their slot for the next
  queued request.
* ``serve.rank_failed`` — a dead client's queued requests are purged
  (responses to it would be dropped by the transport anyway), so the
  server drains cleanly under client SIGKILL.

Client ranks replay an open-loop :class:`~repro.serve.loadgen.LoadSpec`
schedule and throttle while the server signals backpressure.  All
latency accounting happens server-side from the ``t_sched`` stamps the
clients embed in their requests (CLOCK_MONOTONIC is system-wide on
Linux, so cross-process deltas on one box are meaningful).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence

from repro import edat

from .engine import DEFAULT_MAX_LEN, ServeEngine, serving_cfg
from .loadgen import LoadSpec, client_schedule, summarize

REQUEST = edat.Channel("request", payload=dict)
ADMIT = edat.Channel("admit", payload=dict)
DECODE_TICK = edat.Channel("decode_tick")
RESPONSE = edat.Channel("response", payload=dict)
BACKPRESSURE = edat.Channel("backpressure", payload=dict)
READY = edat.Channel("ready")

#: slot sentinel: reserved for a request whose prefill is in flight
_PENDING = "pending"


class ServeProgram:
    """Continuous-batching LM server (rank 0) + open-loop load clients
    (ranks 1..n-1) over the five declared channels above."""

    channels = (REQUEST, ADMIT, DECODE_TICK, RESPONSE, BACKPRESSURE, READY)

    def __init__(self, cfg, *, slots: int = 4,
                 max_len: int = DEFAULT_MAX_LEN,
                 load: Optional[LoadSpec] = None,
                 queue_bound: int = 8,
                 seed: int = 0,
                 throttle_timeout: float = 60.0,
                 ready_file: Optional[str] = None,
                 ready_after: int = 1):
        self.cfg = serving_cfg(cfg, max_len)
        self.slots = slots
        self.max_len = max_len
        self.load = load or LoadSpec()
        self.queue_bound = queue_bound
        self.seed = seed
        self.throttle_timeout = throttle_timeout
        self.ready_file = ready_file
        self.ready_after = ready_after
        # -- server state (rank 0's process only; guarded by the EDAT
        # named lock "server" — every mutating task takes it) ----------
        self._engine: Optional[ServeEngine] = None
        self.queue: List[Dict[str, Any]] = []
        self.live: List[Any] = [None] * slots
        self.records: List[Dict[str, Any]] = []
        self._ticking = False
        self.tick_execs = 0
        self.bp_on = False
        self.bp_signals = 0
        self.served = 0
        self.admitted = 0
        self.dead: set = set()
        self.t_start: Optional[float] = None

    # -- engine (built lazily: client-only processes never pay for the
    # model build / JIT) ----------------------------------------------------
    @property
    def engine(self) -> ServeEngine:
        if self._engine is None:
            self._engine = ServeEngine(self.cfg, slots=self.slots,
                                       max_len=self.max_len, seed=self.seed)
        return self._engine

    # ------------------------------------------------------------------ SPMD
    def start(self, ctx: edat.Context) -> None:
        if ctx.rank == 0:
            self._start_server(ctx)
        else:
            self._run_client(ctx)

    # ---------------------------------------------------------------- server
    def _start_server(self, ctx: edat.Context) -> None:
        # build + compile before any load arrives, then release the
        # clients: measured latency is serving, not XLA compile
        self.engine.warmup(self.load.prompt_lens)
        self.t_start = time.monotonic()
        ctx.submit_persistent(self._on_request, deps=[(edat.ANY, REQUEST)],
                              name="serve.request")
        ctx.submit_persistent(self._on_admit, deps=[(edat.SELF, ADMIT)],
                              name="serve.prefill")
        ctx.submit_persistent(self._on_tick, deps=[(edat.SELF, DECODE_TICK)],
                              name="serve.decode")
        ctx.submit_persistent(self._on_rank_failed,
                              deps=[(edat.ANY, edat.RANK_FAILED)],
                              name="serve.rank_failed")
        for rank in range(1, ctx.n_ranks):
            ctx.fire(rank, READY)

    def _on_request(self, ctx: edat.Context, events) -> None:
        ctx.lock("server")
        ev = events[0]
        if ev.source in self.dead:
            return
        req = dict(ev.data)
        req["client"] = ev.source
        req["t_recv"] = time.monotonic()
        self.queue.append(req)
        self._signal_backpressure(ctx)
        self._pump(ctx)

    def _pump(self, ctx: edat.Context) -> None:
        """Admission (server lock held): reserve a free slot per queued
        request and hand it to the prefill task via an ``admit`` event."""
        while self.queue:
            try:
                slot = self.live.index(None)
            except ValueError:
                return                   # every slot live or reserved
            req = self.queue.pop(0)
            self.live[slot] = _PENDING
            self.admitted += 1
            ctx.fire(edat.SELF, ADMIT, {"slot": slot, "req": req})
        self._signal_backpressure(ctx)

    def _on_admit(self, ctx: edat.Context, events) -> None:
        d = events[0].data
        req, slot = d["req"], d["slot"]
        eng = self.engine
        max_new = eng.clip_max_new(len(req["prompt"]), req["max_new"])
        t_admit = time.monotonic()
        # the expensive prompt-length-dependent phase, deliberately
        # outside the server lock: decode ticks keep running
        first, pcache = eng.prefill(req["prompt"])
        ctx.lock("server")
        eng.attach(slot, len(req["prompt"]), first, pcache)
        rec = {"id": req["id"], "client": req["client"],
               "prompt_len": len(req["prompt"]), "tokens": [first],
               "left": max_new - 1,
               "t_sched": req.get("t_sched", req["t_recv"]),
               "t_send": req.get("t_send", req["t_recv"]),
               "t_recv": req["t_recv"], "t_admit": t_admit,
               "t_first": time.monotonic(),
               "throttled_s": req.get("throttled_s", 0.0)}
        self._touch_ready()
        if rec["left"] <= 0:
            self._complete(ctx, slot, rec)
            self._pump(ctx)
        else:
            self.live[slot] = rec
            if not self._ticking:
                # single-chain guard: at most one self-sustaining
                # decode_tick chain, ever
                self._ticking = True
                ctx.fire(edat.SELF, DECODE_TICK)

    def _on_tick(self, ctx: edat.Context, events) -> None:
        ctx.lock("server")
        self.tick_execs += 1
        live_idx = [i for i, s in enumerate(self.live)
                    if isinstance(s, dict)]
        if not live_idx:
            self._ticking = False
            return
        out = self.engine.step(live_idx)
        now = time.monotonic()
        for i in live_idx:
            rec = self.live[i]
            rec["tokens"].append(int(out[i]))
            rec["left"] -= 1
            if rec["left"] <= 0:
                rec["t_done"] = now
                self._complete(ctx, i, rec)
        self._pump(ctx)
        if any(isinstance(s, dict) for s in self.live):
            ctx.fire(edat.SELF, DECODE_TICK)
        else:
            self._ticking = False

    def _complete(self, ctx: edat.Context, slot: int,
                  rec: Dict[str, Any]) -> None:
        """Server lock held: record the request, answer the client, free
        the slot (the KV reset itself happens on the *next* admit's
        splice — a freed slot is never read before it is overwritten)."""
        rec.setdefault("t_done", time.monotonic())
        rec["n_out"] = len(rec["tokens"])
        rec.pop("left", None)
        self.records.append(rec)
        self.served += 1
        self.live[slot] = None
        if rec["client"] not in self.dead:
            ctx.fire(rec["client"], RESPONSE,
                     {"id": rec["id"], "tokens": rec["tokens"],
                      "t_first": rec["t_first"], "t_done": rec["t_done"]})

    def _signal_backpressure(self, ctx: edat.Context) -> None:
        """Event-carried backpressure (server lock held): one ``on``
        signal when the admission queue exceeds its bound, one ``off``
        when it drains to half — clients gate their open-loop schedule
        on it."""
        depth = len(self.queue)
        if not self.bp_on and depth > self.queue_bound:
            self.bp_on = True
            self.bp_signals += 1
            self._fire_bp(ctx, True, depth)
        elif self.bp_on and depth <= self.queue_bound // 2:
            self.bp_on = False
            self._fire_bp(ctx, False, depth)

    def _fire_bp(self, ctx: edat.Context, on: bool, depth: int) -> None:
        for rank in range(1, ctx.n_ranks):
            if rank not in self.dead:
                ctx.fire(rank, BACKPRESSURE, {"on": on, "depth": depth})

    def _on_rank_failed(self, ctx: edat.Context, events) -> None:
        ctx.lock("server")
        dead = events[0].data
        self.dead.add(dead)
        self.queue = [r for r in self.queue if r["client"] != dead]
        self._signal_backpressure(ctx)
        # live slots for the dead client drain normally; their responses
        # are dropped by the transport's dead-peer accounting

    def _touch_ready(self) -> None:
        if self.ready_file and self.admitted >= self.ready_after:
            try:
                with open(self.ready_file, "w") as f:
                    f.write(str(self.admitted))
            except OSError:
                pass

    # ---------------------------------------------------------------- client
    def _run_client(self, ctx: edat.Context) -> None:
        sched = client_schedule(self.load, ctx.rank - 1, ctx.n_ranks - 1,
                                self.cfg.vocab)
        resume = threading.Event()
        resume.set()

        def on_backpressure(c, events):
            if events[0].data["on"]:
                resume.clear()
            else:
                resume.set()

        ctx.submit_persistent(on_backpressure, deps=[(0, BACKPRESSURE)],
                              name=f"client{ctx.rank}.bp")
        ctx.submit_persistent(lambda c, e: None, deps=[(0, RESPONSE)],
                              name=f"client{ctx.rank}.resp")
        ctx.wait([(0, READY)])       # server is built, compiled, warm
        t0 = time.monotonic()
        for req in sched:
            target = t0 + req["t"]
            delay = target - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            throttled = 0.0
            if not resume.is_set():
                tw = time.monotonic()
                resume.wait(self.throttle_timeout)
                throttled = time.monotonic() - tw
            ctx.fire(0, REQUEST,
                     {"id": req["id"], "prompt": req["prompt"],
                      "max_new": req["max_new"], "t_sched": target,
                      "t_send": time.monotonic(),
                      "throttled_s": throttled})

    # --------------------------------------------------------------- results
    def result(self) -> Dict[str, Any]:
        eng = self._engine
        return {
            "records": sorted(self.records, key=lambda r: r["id"]),
            "served": self.served,
            "steps": eng.step_count if eng else 0,
            "prefills": eng.prefill_count if eng else 0,
            "tick_execs": self.tick_execs,
            "slots_leaked": sum(1 for s in self.live if s is not None),
            "queue_left": len(self.queue),
            "bp_signals": self.bp_signals,
            "dead": sorted(self.dead),
            "slots": self.slots,
        }


# ----------------------------------------------------------------- factories
def serve_program(arch: str = "gemma3-1b", reduced: bool = True,
                  **kwargs: Any) -> ServeProgram:
    """Module-level factory for ``edat.deferred``: spawned processes
    build their own program (and only the server's process ever builds
    the model)."""
    from repro.configs import ARCHS, reduce_cfg
    spec = ARCHS[arch]
    cfg = reduce_cfg(spec.cfg) if reduced else spec.cfg
    return ServeProgram(cfg, **kwargs)


def run_serve(*, arch: str = "gemma3-1b", reduced: bool = True,
              clients: int = 2, slots: int = 4,
              max_len: int = DEFAULT_MAX_LEN,
              load: Optional[LoadSpec] = None,
              queue_bound: int = 8,
              transport: str = "inproc", procs: Optional[int] = None,
              workers_per_rank: int = 2,
              timeout: float = 600.0,
              seed: int = 0) -> Dict[str, Any]:
    """One serving round end to end: spin up a Session (server rank 0 +
    ``clients`` loadgen ranks), run the open-loop load to completion,
    and return ``{"result", "stats", "summary", "wall_s"}``.

    ``summary`` rates are computed over the *serving window* (first
    scheduled arrival to last completion), not session wall time, so
    socket spawn + per-process JIT does not pollute tokens/s."""
    load = load or LoadSpec()
    with edat.Session(1 + clients, procs=procs, transport=transport,
                      workers_per_rank=workers_per_rank,
                      unconsumed="ignore", timeout=timeout) as s:
        t0 = time.monotonic()
        s.run(edat.deferred(serve_program, arch=arch, reduced=reduced,
                            slots=slots, max_len=max_len, load=load,
                            queue_bound=queue_bound, seed=seed))
        wall = time.monotonic() - t0
        res = s.gather()
        stats = dict(s.stats)
    recs = res["records"]
    span = (max(r["t_done"] for r in recs) - min(r["t_sched"] for r in recs)
            if recs else 0.0)
    return {"result": res, "stats": stats, "wall_s": wall,
            "summary": summarize(recs, span)}
