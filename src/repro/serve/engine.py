"""The compute half of the serving subsystem: batched decode slots with a
per-slot KV-cache lifecycle.

:class:`ServeEngine` owns the model, its parameters, and one decode cache
of ``slots`` batch rows.  The two operations the event layer drives:

* :meth:`prefill` — run the prompt through the model's prefill path into
  a *fresh single-request cache* (length ``max_len``, so its per-layer
  shapes match one slot of the batch cache) and return the first greedy
  token plus that cache.  This is the long, prompt-length-dependent
  phase; it touches no shared decode state, so the event layer runs it
  concurrently with decode ticks.
* :meth:`attach` / :meth:`step` — splice a prefilled cache into a batch
  slot and advance the whole batch one greedy token.  ``attach``
  overwrites *every* cache leaf of the slot (K/V pages, cache position
  markers, recurrent states), which is what makes slot reuse safe: a
  freed slot's stale attention state can never leak into the next
  request admitted there.  ``step`` advances position counters only for
  the slots listed live — a dead slot's position stays pinned instead of
  marching unboundedly toward the cache end.

Both fixes are load-bearing (see ``tests/test_serve.py`` regressions):
the demo this subsystem replaced reused slots without resetting the KV
cache — a new request decoded against the previous occupant's attention
state — and advanced ``pos`` for dead slots on every tick.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.train import make_prefill_step, make_serve_step

DEFAULT_MAX_LEN = 128


def serving_cfg(cfg, max_len: int = DEFAULT_MAX_LEN):
    """Normalize a model config for token-in/token-out serving: no
    multimodal frontend, decoder-only, cache length ``max_len``."""
    return cfg.replace(frontend="none", n_frontend_tokens=0, encdec=False,
                       max_target_length=max_len)


def _make_splice(model, slots: int):
    """jitted ``splice(caches, pcache, slot) -> caches`` writing the
    single-request cache ``pcache`` over batch row ``slot`` of every
    cache leaf.  Stacked-layer segments carry a leading ``layers`` dim
    (``stack_spec``), so the batch axis is per-segment: 1 when the
    segment is a scan-over-layers stack, else 0."""
    reps = [r for (_, r) in model.segments]

    def splice(caches, pcache, slot):
        out = []
        for seg, pseg, rep in zip(caches, pcache, reps):
            axis = 1 if rep > 1 else 0

            def put(c, p, axis=axis):
                shp = [1] * c.ndim
                shp[axis] = c.shape[axis]
                mask = (jnp.arange(c.shape[axis]) == slot).reshape(shp)
                return jnp.where(mask, p, c)

            out.append(jax.tree.map(put, seg, pseg))
        return out

    return jax.jit(splice)


class ServeEngine:
    """Model + batched decode state for one serving process."""

    def __init__(self, cfg, *, slots: int, max_len: int = DEFAULT_MAX_LEN,
                 seed: int = 0):
        cfg = serving_cfg(cfg, max_len)
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self._decode = jax.jit(make_serve_step(self.model))
        # one jit; XLA re-specializes per distinct prompt length (the
        # loadgen draws lengths from a few buckets to bound compiles)
        self._prefill = jax.jit(make_prefill_step(self.model,
                                                  max_len=max_len))
        self._splice = _make_splice(self.model, slots)
        self.caches = self.model.init_cache(slots, max_len)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.pos = np.zeros((slots, 1), np.int32)
        #: decode-step invocation counter — the single-chain regression
        #: test asserts tick executions == steps exactly
        self.step_count = 0
        self.prefill_count = 0

    # ----------------------------------------------------------- prefill
    def clip_max_new(self, prompt_len: int, max_new: int) -> int:
        """Bound a request's output so prompt + output fits the cache."""
        return max(1, min(max_new, self.max_len - prompt_len))

    def prefill(self, prompt: Sequence[int]) -> Tuple[int, Any]:
        """Prompt -> (first greedy token, fresh single-request cache).
        Shared-state free: safe to run outside the server lock."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None, :])
        logits, pcache = self._prefill(self.params, {"tokens": toks})
        self.prefill_count += 1
        return int(jnp.argmax(logits[:, -1], axis=-1)[0]), pcache

    def warmup(self, prompt_lens: Sequence[int] = ()) -> None:
        """Pay the XLA compiles (decode step + one prefill per prompt
        bucket) up front, then reset all decode state and counters —
        so serving-latency measurements never include compile time."""
        for plen in sorted(set(prompt_lens)):
            self.prefill([0] * int(plen))
        self.step([])
        self.caches = self.model.init_cache(self.slots, self.max_len)
        self.tokens[:] = 0
        self.pos[:] = 0
        self.step_count = 0
        self.prefill_count = 0

    # ------------------------------------------------------------ decode
    def attach(self, slot: int, prompt_len: int, first_token: int,
               pcache: Any) -> None:
        """Splice a prefilled request into ``slot``: the whole slot is
        overwritten (KV pages, pos markers, recurrent state) — the
        per-slot cache reset on admit."""
        self.caches = self._splice(self.caches, pcache, slot)
        self.tokens[slot, 0] = first_token
        self.pos[slot, 0] = prompt_len

    def step(self, live: Sequence[int]) -> np.ndarray:
        """One greedy decode step over the whole batch; returns the
        next-token column (``(slots,)``).  Tokens/positions advance only
        for ``live`` slots — dead rows keep stepping through the jitted
        batch (their output is ignored) but their position is pinned, so
        an idle slot never walks its write pointer to ``max_len``."""
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(self.tokens),
                                        jnp.asarray(self.pos))
        self.step_count += 1
        out = np.asarray(nxt)
        for i in live:
            self.tokens[i, 0] = out[i, 0]
            self.pos[i, 0] += 1
        return out[:, 0]


class SequentialEngine:
    """The naive baseline: one request at a time, batch of one, prefill
    then decode to completion — no continuous batching, no overlap.
    Identical math to :class:`ServeEngine` (same builders, same greedy
    argmax), so the event-driven server's tokens must match this
    baseline's token-for-token."""

    def __init__(self, cfg, *, max_len: int = DEFAULT_MAX_LEN,
                 seed: int = 0):
        self._eng = ServeEngine(cfg, slots=1, max_len=max_len, seed=seed)

    @property
    def step_count(self) -> int:
        return self._eng.step_count

    def warmup(self, prompt_lens: Sequence[int] = ()) -> None:
        self._eng.warmup(prompt_lens)

    def serve_one(self, prompt: Sequence[int],
                  max_new: int) -> Tuple[List[int], float, float]:
        """Serve one request to completion; returns ``(tokens, t_first,
        t_done)`` with the same greedy tokens the batched engine emits
        for this prompt."""
        eng = self._eng
        max_new = eng.clip_max_new(len(prompt), max_new)
        first, pcache = eng.prefill(prompt)
        t_first = time.monotonic()
        eng.caches = pcache          # batch of one: the cache IS the slot
        eng.tokens[0, 0] = first
        eng.pos[0, 0] = len(prompt)
        out = [first]
        for _ in range(max_new - 1):
            out.append(int(eng.step([0])[0]))
        return out, t_first, time.monotonic()
