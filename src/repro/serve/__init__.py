"""repro.serve — event-driven LM serving on the EDAT runtime.

The subsystem that fuses the repo's two halves: the jax model stack
(prefill / decode steps, KV caches) driven entirely by EDAT events
(typed channels, persistent tasks, event-carried backpressure).  See
:mod:`repro.serve.program` for the channel contract and
:mod:`repro.serve.engine` for the per-slot KV-cache lifecycle.

::

    from repro.serve import LoadSpec, run_serve

    out = run_serve(arch="gemma3-1b", clients=2, slots=4,
                    load=LoadSpec(rps=8, requests=32))
    print(out["summary"])       # requests/s, tokens/s, p50/p99 TTFT ...
"""
from .engine import (DEFAULT_MAX_LEN, SequentialEngine, ServeEngine,
                     serving_cfg)
from .loadgen import (LoadSpec, all_requests, client_schedule, percentile,
                      summarize)
from .baseline import run_sequential
from .program import (ADMIT, BACKPRESSURE, DECODE_TICK, REQUEST, RESPONSE,
                      ServeProgram, run_serve, serve_program)

__all__ = [
    "ServeProgram", "serve_program", "run_serve",
    "ServeEngine", "SequentialEngine", "serving_cfg", "DEFAULT_MAX_LEN",
    "LoadSpec", "client_schedule", "all_requests", "summarize",
    "percentile", "run_sequential",
    "REQUEST", "ADMIT", "DECODE_TICK", "RESPONSE", "BACKPRESSURE",
]
