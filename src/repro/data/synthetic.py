"""Deterministic synthetic LM data: a fixed random bigram chain.

Sequences are sampled from a vocab-sized Markov chain whose transition
structure is derived from a fixed seed, so (a) every (step, shard) batch is
reproducible for checkpoint/restart tests, and (b) the distribution has
real learnable structure — training loss decreasing below the unigram
entropy proves the optimizer/model plumbing end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataCfg:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 1234
    branching: int = 4   # candidate successors per token (entropy control)


class SyntheticLM:
    """Host-sharded deterministic stream; ``batch(step, shard, n_shards)``
    is a pure function — restart at any step reproduces the batch."""

    def __init__(self, cfg: DataCfg):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # each token's successors: `branching` choices with random weights
        self.succ = rng.integers(0, cfg.vocab,
                                 size=(cfg.vocab, cfg.branching))
        w = rng.random((cfg.vocab, cfg.branching)) + 0.1
        self.w = w / w.sum(axis=1, keepdims=True)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, np.ndarray]:
        """Topology-invariant: the full global batch is generated from
        (seed, step) alone and sliced per shard, so elastic resharding and
        DP-vs-single-host equivalence hold exactly."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b = cfg.global_batch // n_shards
        g = cfg.global_batch
        rng = np.random.default_rng((cfg.seed * 1_000_003 + step) * 4096)
        toks = np.empty((g, cfg.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=g)
        # vectorised chain sampling
        for t in range(cfg.seq):
            cur = toks[:, t]
            choice = (rng.random(g)[:, None] <
                      np.cumsum(self.w[cur], axis=1)).argmax(axis=1)
            toks[:, t + 1] = self.succ[cur, choice]
        sl = slice(shard * b, (shard + 1) * b)
        return {"tokens": toks[sl, :-1], "labels": toks[sl, 1:]}

    def frontend_batch(self, step: int, shard: int, n_shards: int,
                       d_model: int, n_tokens: int,
                       key: str) -> Dict[str, np.ndarray]:
        """Stub modality embeddings for vlm/audio archs."""
        base = self.batch(step, shard, n_shards)
        b = base["tokens"].shape[0]
        rng = np.random.default_rng(
            (self.cfg.seed * 999_983 + step) * 4096 + shard)
        base[key] = rng.standard_normal(
            (b, n_tokens, d_model)).astype(np.float32)
        return base
