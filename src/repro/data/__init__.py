from .synthetic import DataCfg, SyntheticLM
