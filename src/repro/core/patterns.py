"""Collective patterns over EDAT primitives.

The paper sketches a naive all-to-one reduction (Listing 5) and notes a
"more complex collective algorithm, such as a tree-based approach, would
work equally well".  These helpers provide both, plus the non-blocking
barrier of Listing 6, as reusable library code.
"""
from __future__ import annotations

import math
from typing import Any, Callable, List, Optional

from .event import ALL, ANY, SELF, Dep, Event
from .runtime import Context


def barrier(ctx: Context, name: str, task: Callable) -> None:
    """Non-blocking barrier (paper Listing 6): ``task`` runs once every
    rank has fired its arrival event."""
    ctx.submit(task, deps=[(ALL, f"__bar.{name}")])
    ctx.fire(ALL, f"__bar.{name}")


def wait_barrier(ctx: Context, name: str) -> None:
    """Blocking barrier built on ``wait`` (pauses the calling task)."""
    ctx.fire(ALL, f"__bar.{name}")
    ctx.wait([(ALL, f"__bar.{name}")])


def allreduce(ctx: Context, name: str, value: Any, combine: Callable,
              on_result: Callable[[Context, Any], None]) -> None:
    """Naive all-to-all reduction (paper Listing 5 generalised): every rank
    fires its value to everyone; a task with an ALL dependency combines."""

    def task(ctx2, events: List[Event]):
        acc = events[0].data
        for e in events[1:]:
            acc = combine(acc, e.data)
        on_result(ctx2, acc)

    ctx.submit(task, deps=[(ALL, f"__ar.{name}")])
    # one batched fire: a single transport round-trip per destination
    ctx.fire_batch([(r, f"__ar.{name}", value) for r in range(ctx.n_ranks)])


def tree_reduce(ctx: Context, name: str, value: Any, combine: Callable,
                on_result: Callable[[Context, Any], None],
                root: int = 0) -> None:
    """Binomial-tree reduction to ``root``: O(log n) event rounds instead
    of the naive O(n) fan-in.  ``on_result`` runs on the root only."""
    n = ctx.n_ranks
    me = (ctx.rank - root) % n
    levels = max(1, math.ceil(math.log2(n))) if n > 1 else 0

    state = {"acc": value, "lvl": 0}

    def advance(ctx2):
        while True:
            lvl = state["lvl"]
            if lvl >= levels:
                if me == 0:
                    on_result(ctx2, state["acc"])
                return
            bit = 1 << lvl
            if me & bit:
                # sender at this level: fire partial to the parent and stop
                parent = ((me - bit) + root) % n
                ctx2.fire(parent, f"__tr.{name}.{lvl}", state["acc"])
                return
            if me + bit < n:
                # receiver: need the child's partial before advancing
                child = ((me + bit) + root) % n

                def on_child(ctx3, events, _lvl=lvl):
                    state["acc"] = combine(state["acc"], events[0].data)
                    state["lvl"] = _lvl + 1
                    advance(ctx3)

                ctx2.submit(on_child, deps=[(child, f"__tr.{name}.{lvl}")])
                return
            state["lvl"] = lvl + 1

    advance(ctx)
