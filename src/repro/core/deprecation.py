"""Once-per-call-site deprecation warnings for the v1 -> v2 API migration.

The v2 ``repro.edat`` facade (``Session`` / ``edat.run``) subsumes the
v1 entry points (``Runtime.run``, ``distributed_bfs``,
``distributed_insitu``, ``distributed_train``).  Those remain as thin
shims that emit a :class:`DeprecationWarning` exactly once per call
site — deduplicated here rather than by the interpreter's warning
registry, so the guarantee holds regardless of the active warning
filters (pytest, for one, rewrites them).
"""
from __future__ import annotations

import sys
import threading
import warnings

_seen: set = set()
_mu = threading.Lock()


def warn_deprecated(message: str) -> None:
    """Emit ``message`` as a DeprecationWarning, once per calling line.

    Must be called directly from the deprecated API (one frame below the
    user's call site)."""
    f = sys._getframe(2)
    key = (f.f_code.co_filename, f.f_lineno, message)
    with _mu:
        if key in _seen:
            return
        _seen.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
