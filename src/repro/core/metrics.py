"""Shared plumbing for the always-on runtime metrics layer.

The counters themselves live where the events flow — per-eid dicts inside
each :class:`~repro.core.scheduler.Scheduler` (bumped under the locks the
hot paths already hold) and per-peer vectors inside
:class:`~repro.net.SocketTransport`.  This module holds what is common to
every layer:

* :func:`payload_nbytes` — the cheap payload-size estimate the fire path
  charges to a channel (a handful of ``type`` checks, never a pickle);
* :func:`merge_metrics` — fold per-process metric snapshots (one per
  spawned rank process, or a single in-proc runtime) into the canonical
  ``{"channels", "ranks", "transport"}`` shape that ``Session.stats()``
  exposes and :func:`repro.insights.analyze` consumes;
* :class:`RunStats` — the stats mapping itself.  A plain ``dict`` in
  every respect, but *callable* (``s.stats()`` ≡ ``s.stats``) so the
  accessor idiom and the attribute idiom are both valid.

Channel entry schema (one per event id)::

    {"fires": int,        # events fired on this channel (at the source)
     "bytes": int,        # estimated payload bytes fired
     "wire_fires": int,   # fires whose target lives in another process
     "deliveries": int,   # events delivered to a rank's scheduler
     "consumed": int,     # events consumed to completion by tasks/waiters
     "queued_max": int}   # max(deliveries - consumed): backpressure depth

Rank entry schema::

    {"tasks_executed": int, "busy_s": float,
     "quorum_wait_s": float}   # seconds OTHER ranks spent waiting for the
                               # last event of a multi-dependency frame —
                               # attributed to the rank that fired it, so a
                               # straggler shows a dominant share
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Tuple

try:
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is a de-facto hard dep
    _np = None

_FIXED8 = frozenset((bool, int, float))
_SIZED = frozenset((str, bytes, bytearray))
# Payload types safe to hand to multiple consumers without a defensive
# copy: nothing can mutate them behind anyone's back.
_IMMUTABLE = frozenset((bool, int, float, complex, str, bytes, type(None)))


def payload_nbytes(data: Any) -> int:
    """Cheap (non-recursive beyond one level) payload size estimate.

    Exact for the shapes that matter to the insight rules — scalars,
    strings/bytes, numpy arrays, and shallow containers of those — and a
    flat per-item guess otherwise.  Deliberately never pickles: this runs
    on the fire hot path.
    """
    if data is None:
        return 0
    t = type(data)
    if t in _FIXED8:
        return 8
    if t is complex:
        return 16
    if t in _SIZED:
        return len(data)
    if _np is not None:
        if t is _np.ndarray:
            return int(data.nbytes)
        if isinstance(data, _np.generic):
            return int(data.nbytes)
    if t in (list, tuple, set, frozenset):
        n = 0
        for v in data:
            tv = type(v)
            if tv in _FIXED8:
                n += 8
            elif tv in _SIZED:
                n += len(v)
            elif _np is not None and tv is _np.ndarray:
                n += int(v.nbytes)
            else:
                n += 64
        return n
    if t is dict:
        n = 0
        for v in data.values():
            tv = type(v)
            if tv in _FIXED8:
                n += 8
            elif tv in _SIZED:
                n += len(v)
            elif _np is not None and tv is _np.ndarray:
                n += int(v.nbytes)
            else:
                n += 64
        return n
    return 64


class RunStats(dict):
    """Run statistics: a plain dict that is also callable.

    ``Session.stats`` has always been indexable (``s.stats["run_seconds"]``);
    making it callable lets the structured accessor read naturally
    (``s.stats()["channels"]``) without breaking a single existing caller.
    """

    def __call__(self) -> "RunStats":
        return self


def _empty_channel() -> Dict[str, int]:
    return {"fires": 0, "bytes": 0, "wire_fires": 0,
            "deliveries": 0, "consumed": 0, "queued_max": 0}


def _empty_rank() -> Dict[str, Any]:
    return {"tasks_executed": 0, "busy_s": 0.0, "quorum_wait_s": 0.0}


def merge_metrics(parts: Iterable[Tuple[int, Dict[str, Any]]]
                  ) -> Dict[str, Any]:
    """Fold per-process metric snapshots into one canonical view.

    ``parts`` is ``[(lead_rank, snapshot)]`` — one snapshot per process
    (from :meth:`repro.core.runtime.Runtime.metrics`), keyed by the
    process's lead rank so per-peer transport detail stays attributable.
    Counters sum, high-water marks take the max, and per-rank entries
    (each rank executes in exactly one process, but quorum-wait seconds
    are *attributed* to remote ranks by their consumers) sum field-wise.
    """
    channels: Dict[str, Dict[str, int]] = {}
    ranks: Dict[int, Dict[str, Any]] = {}
    transport: Dict[str, Any] = {}
    durable: Dict[str, Any] = {}
    for lead, m in parts:
        if not m:
            continue
        d = m.get("durable")
        if d:
            durable.setdefault("log", d.get("log"))
            for k in ("appends", "batches"):
                durable[k] = durable.get(k, 0) + d.get(k, 0)
            durable["queue_max"] = max(durable.get("queue_max", 0),
                                       d.get("queue_max", 0))
            durable.setdefault("replays", []).extend(d.get("replays") or ())
        for eid, ch in (m.get("channels") or {}).items():
            agg = channels.setdefault(eid, _empty_channel())
            for k in ("fires", "bytes", "wire_fires", "deliveries",
                      "consumed"):
                agg[k] += ch.get(k, 0)
            agg["queued_max"] = max(agg["queued_max"],
                                    ch.get("queued_max", 0))
        for r, rk in (m.get("ranks") or {}).items():
            agg = ranks.setdefault(int(r), _empty_rank())
            agg["tasks_executed"] += rk.get("tasks_executed", 0)
            agg["busy_s"] += rk.get("busy_s", 0.0)
            agg["quorum_wait_s"] += rk.get("quorum_wait_s", 0.0)
            if "trace" in rk:
                agg.setdefault("trace", []).extend(rk["trace"])
                agg["trace_dropped"] = (agg.get("trace_dropped", 0)
                                        + rk.get("trace_dropped", 0))
        t = m.get("transport")
        if t:
            transport.setdefault("kind", t.get("kind"))
            if "coalesce" in t:
                transport.setdefault("coalesce", t["coalesce"])
            for k in ("wire_events_sent", "wire_events_recv",
                      "loopback_events", "wire_bytes", "writes", "dropped"):
                if k in t:
                    transport[k] = transport.get(k, 0) + t[k]
            if "sendq_max" in t:
                transport["sendq_max"] = max(transport.get("sendq_max", 0),
                                             t["sendq_max"])
            for p, pm in (t.get("peers") or {}).items():
                transport.setdefault("peers", {})[f"{lead}->{p}"] = dict(pm)
    out = {"channels": channels, "ranks": ranks, "transport": transport}
    if durable:
        out["durable"] = durable
    return out
