"""Events — the unit of interaction in EDAT (paper §II.B).

An event is fired from a source rank to a target rank, labelled with a string
event identifier (EID), optionally carrying payload data.  Firing is
*fire-and-forget*: the payload is copied at fire time so the caller may reuse
its buffers immediately (paper §II.B).  ``ref=True`` reproduces the paper's
``EDAT_ADDRESS`` type: the reference itself is the payload (used for the
shared-local-data pattern of paper Listing 10).
"""
from __future__ import annotations

import copy as _copy
import dataclasses
import itertools
from typing import Any

import numpy as np


class _Wildcard:
    """Singleton wildcard ranks (paper: EDAT_SELF / EDAT_ANY / EDAT_ALL)."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):  # pragma: no cover - cosmetic
        return f"EDAT_{self.name}"


#: Event originates from / targets the calling rank itself.
SELF = _Wildcard("SELF")
#: Dependency wildcard: matching EID from any source rank.
ANY = _Wildcard("ANY")
#: Broadcast target / all-ranks dependency (collectives, barriers; paper §II.D).
ALL = _Wildcard("ALL")

#: Reserved EID prefix for machine-generated events (paper §VII further work:
#: timers, resource/hardware events).  User code may *consume* these but the
#: runtime is the only producer.
SYS_PREFIX = "__edat."
RANK_FAILED = SYS_PREFIX + "rank_failed"
TIMER_CANCELLED = SYS_PREFIX + "timer_cancelled"

_uid = itertools.count()


def copy_payload(data: Any) -> Any:
    """Deep-copy an event payload (fire-and-forget semantics).

    Arrays (numpy or anything exposing ``__array__``, e.g. ``jax.Array``) are
    materialised as fresh host numpy arrays; containers recurse; immutable
    scalars pass through.
    """
    if data is None or isinstance(data, (bool, int, float, complex, str, bytes, frozenset)):
        return data
    if isinstance(data, np.ndarray):
        return data.copy()
    if hasattr(data, "__array__") and not isinstance(data, (list, tuple, dict)):
        return np.asarray(data).copy()
    if isinstance(data, tuple):
        return tuple(copy_payload(x) for x in data)
    if isinstance(data, list):
        return [copy_payload(x) for x in data]
    if isinstance(data, dict):
        return {k: copy_payload(v) for k, v in data.items()}
    return _copy.deepcopy(data)


@dataclasses.dataclass
class Event:
    """A delivered event (paper's ``EDAT_Event``): payload + metadata."""

    data: Any
    source: int
    eid: str
    persistent: bool = False
    #: per-(src,dst) monotonically increasing sequence, for FIFO assertions
    seq: int = -1
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))

    @property
    def n_elements(self) -> int:
        d = self.data
        if d is None:
            return 0
        if isinstance(d, np.ndarray):
            return int(d.size)
        if isinstance(d, (list, tuple)):
            return len(d)
        return 1

    @property
    def dtype(self) -> str:
        d = self.data
        if d is None:
            return "none"
        if isinstance(d, np.ndarray):
            return str(d.dtype)
        return type(d).__name__

    def clone(self) -> "Event":
        return Event(
            data=copy_payload(self.data),
            source=self.source,
            eid=self.eid,
            persistent=self.persistent,
            seq=self.seq,
        )


@dataclasses.dataclass(frozen=True)
class Dep:
    """A task's event dependency: ``(source, eid)`` (paper §II.A).

    ``source`` is an int rank, :data:`ANY`, :data:`ALL` or :data:`SELF`
    (resolved to the submitting rank at submission time).

    After wildcard expansion (SELF resolved, ALL expanded per-rank) a dep is
    either *exact* — indexable under the stable ``key`` ``(source, eid)`` —
    or an ANY-source *wildcard*, indexable under ``eid`` alone.  The event
    router uses this split to route deliveries without scanning every
    registered consumer.
    """

    source: Any
    eid: str

    @property
    def key(self) -> tuple:
        """Stable index key for exact deps: ``(source, eid)``."""
        return (self.source, self.eid)

    @property
    def is_any(self) -> bool:
        """True for an ANY-source wildcard dep (matches every source)."""
        return self.source is ANY

    def matches(self, ev: Event) -> bool:
        if self.eid != ev.eid:
            return False
        return self.source is ANY or self.source == ev.source


def dep(source: Any, eid: str) -> Dep:
    """Convenience constructor mirroring the paper's ``<source, id>`` pairs."""
    return Dep(source, eid)
