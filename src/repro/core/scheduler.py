"""Per-rank scheduler: dependency matching, ready queue, workers, locks.

Implements the paper's semantics precisely:

* FIFO task execution policy (paper §II.F);
* earlier-registered consumers have precedence in consuming events
  (paper §II.B "a task submitted before another task ... has a higher
  precedence in the consumption of events");
* events delivered to a task in *dependency order*, not arrival order
  (paper §II.A);
* persistent tasks keep multiple partially-filled dependency *frames* in
  flight (paper §IV.A);
* persistent events re-fire locally upon consumption (paper §IV.A);
* ``wait`` parks the task, frees the worker (a replacement worker thread is
  spawned so the configured concurrency is preserved) and releases/reacquires
  named locks (paper §IV.B/C);
* named locks auto-release at task end (paper §IV.C).

Delivery is routed through an :class:`~repro.core.router.EventRouter`
index — O(matching consumers) per event instead of O(all consumers) — and
every blocked path (``wait``, named locks, idle workers, slot re-acquisition)
blocks on a condition variable that is notified on the exact state change,
rather than sleep-polling.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from .event import ALL, ANY, SELF, Dep, Event
from .router import EventRouter

_inst_uid = itertools.count()

#: per-rank cap on opt-in trace records; beyond it, records are counted
#: (``trace_dropped``) instead of stored, bounding memory on long runs
TRACE_CAP = 50_000


class Slot:
    """One dependency slot of a consumer (one expected event)."""

    __slots__ = ("dep", "event")

    def __init__(self, dep: Dep):
        self.dep = dep
        self.event: Optional[Event] = None

    @property
    def filled(self) -> bool:
        return self.event is not None


def expand_deps(deps: List[Dep], rank: int, n_ranks: int) -> List[Dep]:
    """Resolve SELF and expand ALL into one dep per rank (paper §II.D)."""
    out: List[Dep] = []
    for d in deps:
        if d.source is SELF:
            out.append(Dep(rank, d.eid))
        elif d.source is ALL:
            out.extend(Dep(r, d.eid) for r in range(n_ranks))
        else:
            out.append(d)
    return out


class Frame:
    """A (possibly partial) set of dependency slots (paper §IV.A)."""

    __slots__ = ("slots", "birth", "t_first", "last_src")
    _birth = itertools.count()

    def __init__(self, deps: List[Dep]):
        self.slots = [Slot(d) for d in deps]
        self.birth = next(Frame._birth)
        # quorum tracking (multi-slot frames only): when the first slot
        # filled, and which source rank filled the most recent slot — the
        # metrics layer charges the frame's completion lag to that rank
        self.t_first: Optional[float] = None
        self.last_src = -1

    def note(self, ev: Event) -> None:
        if len(self.slots) > 1:
            if self.t_first is None:
                self.t_first = time.monotonic()
            self.last_src = ev.source

    def try_fill(self, ev: Event) -> bool:
        for s in self.slots:
            if not s.filled and s.dep.matches(ev):
                s.event = ev
                if len(self.slots) > 1:     # note(), inlined: hot path
                    if self.t_first is None:
                        self.t_first = time.monotonic()
                    self.last_src = ev.source
                return True
        return False

    @property
    def complete(self) -> bool:
        return all(s.filled for s in self.slots)

    def events(self) -> List[Event]:
        return [s.event for s in self.slots]  # dependency order (paper §II.A)


class Consumer:
    """Base: an ordered claim on future events (task or waiter)."""

    __slots__ = ("deps", "name", "reg_order", "quorum")

    def __init__(self, deps: List[Dep], name: Optional[str]):
        self.deps = deps
        self.name = name
        self.reg_order = -1
        # (t_first, last_src) of the most recently popped frame — read by
        # the scheduler's metrics layer right after pop_ready()
        self.quorum: Optional[Tuple[Optional[float], int]] = None

    def try_fill(self, ev: Event) -> bool:  # pragma: no cover - abstract
        raise NotImplementedError

    def pop_ready(self) -> Optional[List[Event]]:  # pragma: no cover
        raise NotImplementedError

    @property
    def done(self) -> bool:  # transitory consumers leave the registry when done
        raise NotImplementedError


class TaskConsumer(Consumer):
    """A submitted task (transitory or persistent)."""

    __slots__ = ("fn", "persistent", "frames", "fired")

    def __init__(self, fn, deps, name, persistent):
        super().__init__(deps, name)
        self.fn = fn
        self.persistent = persistent
        self.frames: List[Frame] = [Frame(deps)] if deps else []
        self.fired = False  # transitory + zero-dep: executes exactly once

    def try_fill(self, ev: Event) -> bool:
        # earliest frame missing a matching slot (paper §IV.A)
        for f in self.frames:
            if f.try_fill(ev):
                return True
        if self.persistent:
            f = Frame(self.deps)
            if f.try_fill(ev):
                self.frames.append(f)
                return True
        return False

    def pop_ready(self) -> Optional[List[Event]]:
        for i, f in enumerate(self.frames):
            if f.complete:
                self.frames.pop(i)
                if self.persistent and not self.frames:
                    self.frames.append(Frame(self.deps))
                # only multi-slot frames stamp t_first; skip the tuple
                # allocation for the common single-dep case
                self.quorum = (None if f.t_first is None
                               else (f.t_first, f.last_src))
                return f.events()
        return None

    @property
    def done(self) -> bool:
        return not self.persistent and not self.frames

    def unmet(self) -> bool:
        """True if a transitory task still awaits events (deadlock check)."""
        return not self.persistent and bool(self.frames)


class Waiter(Consumer):
    """A parked task inside ``wait`` (paper §IV.B)."""

    __slots__ = ("frame", "cv", "woken", "parked")

    def __init__(self, deps, cv: threading.Condition):
        super().__init__(deps, None)
        self.frame = Frame(deps)
        self.cv = cv
        self.woken = False
        self.parked = False

    def try_fill(self, ev: Event) -> bool:
        return self.frame.try_fill(ev)

    def pop_ready(self) -> Optional[List[Event]]:
        if self.frame.complete and not self.woken:
            self.woken = True
            f = self.frame
            self.quorum = (None if f.t_first is None
                           else (f.t_first, f.last_src))
            return f.events()
        return None

    @property
    def done(self) -> bool:
        return self.woken


class Instance:
    """A task execution instance on the ready queue."""

    __slots__ = ("fn", "events", "name", "uid", "mrec")

    def __init__(self, fn, events, name, mrec=None):
        self.fn = fn
        self.events = events
        self.name = name
        self.uid = next(_inst_uid)
        # the delivery-time metrics record ([deliv, consumed, pending,
        # qmax]) for single-dep instances dispatched straight from a
        # delivery: _run consume-counts through it without re-probing
        self.mrec = mrec


class _TaskTLS(threading.local):
    def __init__(self):
        self.locks: Optional[set] = None       # names held by current task
        self.exit_after_task = False           # replacement-worker shedding
        self.in_task = False


class Scheduler:
    """One rank's scheduler (paper: one 'process')."""

    def __init__(self, rank: int, n_ranks: int, runtime, target_workers: int,
                 progress_mode: str = "thread", metrics: bool = True,
                 trace: bool = False):
        self.rank = rank
        self.n_ranks = n_ranks
        self.runtime = runtime
        self.target = max(1, target_workers)
        self.progress_mode = progress_mode

        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)

        self._consumers: List[Consumer] = []   # registration order (enumeration)
        self._router = EventRouter()           # (source, eid) -> consumers
        self._reg_counter = itertools.count()
        self._store: Dict[Tuple[int, str], deque] = {}
        self._store_eids: Dict[str, set] = {}  # eid -> non-empty store keys
        self._arrival = itertools.count()      # store-arrival order (for ANY)
        self._ready: deque = deque()

        self._running = 0
        self._parked = 0
        self._resuming = 0                     # woken waiters not yet resumed
        self._loops = 0                        # worker threads in their loop
        self._mail = False                     # transport notify (worker mode)
        self._mail_hooked = False              # transport has a real notify
        self._shutdown = False
        self._main_done = False

        # termination counters (user events only)
        self.sent = 0
        self.received = 0

        # named locks: name -> (owner thread id | None)
        self._locks: Dict[str, Any] = {}
        self._lock_cv = threading.Condition(self._mu)

        self._tls = _TaskTLS()
        self._threads: List[threading.Thread] = []
        self._executed = 0  # stats

        # -- metrics (always-on by default; every bump happens under a lock
        # the hot path already holds, so "off" only saves the dict ops) --
        self.metrics_on = metrics
        self.trace_on = trace
        self._m_fires: Dict[str, List[int]] = {}   # eid -> [n, bytes, wire]
        self._m_deliv: Dict[str, List[int]] = {}   # eid -> [deliv, consumed,
        #                                                    pending, qmax]
        self._m_quorum: Dict[int, float] = {}      # src rank -> wait seconds
        self._busy_s = 0.0
        self._trace: List[tuple] = []
        self._trace_dropped = 0

        #: durable-mode consume hook (repro.durable): called OUTSIDE the
        #: scheduler lock with the just-consumed events, on every path that
        #: retires them — task completion (_run), wait() returns, and
        #: retrieve_any.  None when durable mode is off (zero hot-path cost).
        self.on_consumed: Optional[Callable[[List[Event]], None]] = None

    # ------------------------------------------------------------------ util
    def _spawn_worker(self):
        t = threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"edat-w{self.rank}")
        self._threads.append(t)
        t.start()

    def start(self):
        for _ in range(self.target):
            self._spawn_worker()

    def stop(self):
        with self._mu:
            self._shutdown = True
            self._cv.notify_all()
            self._lock_cv.notify_all()
        for c in list(self._consumers):
            if isinstance(c, Waiter):
                with c.cv:
                    c.cv.notify_all()

    def join(self, timeout: float = 5.0):
        for t in self._threads:
            t.join(timeout)

    def _idle_locked(self) -> bool:
        return (not self._ready and self._running == 0
                and self._resuming == 0 and self._main_done)

    def _notify_mail(self):
        """Transport notify hook (worker-poll mode): a message arrived.

        The flag-up fast path is safe without the lock: if we observe
        ``_mail`` already set, the worker that will clear it polls *after*
        clearing, and our message was enqueued *before* this check — so that
        poll cannot miss it.  This keeps senders off the receiving
        scheduler's mutex during bursts."""
        if self._mail:
            return
        with self._mu:
            self._mail = True
            self._cv.notify_all()

    # -------------------------------------------------------------- delivery
    def deliver(self, ev: Event) -> None:
        self.deliver_many((ev,))

    def deliver_many(self, evs) -> None:
        """Process arriving events under one lock round-trip: offer each to
        the router (precedence order), else store.  Caller: progress thread,
        polling worker, or a distributed transport's reader thread
        (push-mode delivery) — thread-safe under the scheduler lock."""
        ready: List[Instance] = []
        wake: List[Waiter] = []
        refires: List[Event] = []
        with self._mu:
            self.received += len(evs)
            if self.trace_on:
                self._trace_add_locked(
                    ("recv", time.monotonic(), len(evs), evs[0].eid))
            if self.metrics_on:
                # account runs of equal eids and offer their events in one
                # pass: coalesced deliveries are near-always single-channel
                # batches, so this costs one dict probe per run — and the
                # run's record rides along to _offer_locked so single-dep
                # task instances consume-count in _run without re-probing
                md = self._m_deliv
                if len(evs) == 1:          # single event: the common case
                    ev = evs[0]
                    rec = md.get(ev.eid)
                    if rec is None:
                        rec = md[ev.eid] = [0, 0, 0, 0]
                    rec[0] += 1
                    rec[2] += 1
                    if rec[2] > rec[3]:
                        rec[3] = rec[2]
                    self._offer_locked(ev, ready, wake, refires, rec)
                else:
                    i, n = 0, len(evs)
                    while i < n:
                        eid = evs[i].eid
                        j = i + 1
                        while j < n and evs[j].eid == eid:
                            j += 1
                        rec = md.get(eid)
                        if rec is None:
                            rec = md[eid] = [0, 0, 0, 0]
                        k = j - i
                        rec[0] += k
                        rec[2] += k
                        if rec[2] > rec[3]:
                            rec[3] = rec[2]
                        while i < j:
                            self._offer_locked(evs[i], ready, wake,
                                               refires, rec)
                            i += 1
            else:
                for ev in evs:
                    self._offer_locked(ev, ready, wake, refires)
            if ready:
                self._ready.extend(ready)
                self._cv.notify_all()
            # count refires as sent while still holding the lock so the
            # termination detector never sees balanced counters with a
            # re-fire still pending (Mattern consistency)
            self.sent += len(refires)
            idle = self._idle_locked()
        for w in wake:
            with w.cv:
                w.cv.notify_all()
        for ev in refires:
            self.runtime._send_refire(self.rank, ev)
        if idle and not refires:
            self.runtime._poke()

    def _offer_locked(self, ev: Event, ready: List[Instance],
                      wake: List[Waiter], refires: List[Event],
                      mrec: Optional[List[int]] = None) -> None:
        c = self._router.offer(ev)
        if c is not None:
            if ev.persistent:
                refires.append(ev)  # re-fires locally on consumption (§IV.A)
            self._drain_consumer_locked(c, ready, wake, mrec)
            if isinstance(c, TaskConsumer) and c.persistent:
                # a dispatched frame opened fresh slots (paper §IV.A refill):
                # top them up from stored events, which would otherwise sit
                # unconsumed until another matching event happened to arrive
                self._fill_from_store_locked(c, ready, wake, refires)
            return
        self._store_put_locked(ev)

    def _drain_consumer_locked(self, c: Consumer, ready: List[Instance],
                               wake: List[Waiter],
                               mrec: Optional[List[int]] = None) -> None:
        while True:
            evs = c.pop_ready()
            if evs is None:
                break
            if self.metrics_on:
                q = c.quorum        # set only for multi-slot frames
                if q is not None:
                    # charge the frame's completion lag (first slot filled ->
                    # last slot filled, i.e. now) to the rank whose event
                    # arrived last: a straggler accumulates a dominant share
                    lag = time.monotonic() - q[0]
                    if lag > 0.0:
                        self._m_quorum[q[1]] = (
                            self._m_quorum.get(q[1], 0.0) + lag)
            if isinstance(c, TaskConsumer):
                # a single-slot frame's event eid equals the offered eid, so
                # the delivery record (if any) is the right consume record
                ready.append(Instance(c.fn, evs, c.name,
                                      mrec if len(evs) == 1 else None))
            else:
                # waiters resume immediately: their events are consumed now
                # (task instances are counted at completion in _run)
                if self.metrics_on:
                    self._count_consumed_locked(evs)
                if c.parked:
                    # keep the rank non-idle until the woken thread resumes
                    self._resuming += 1
                wake.append(c)  # Waiter: events already in its frame
        if c.done:
            self._remove_consumer_locked(c)

    def _remove_consumer_locked(self, c: Consumer) -> None:
        try:
            self._consumers.remove(c)
        except ValueError:
            pass  # satisfied from store before registration
        self._router.unregister(c)

    # ----------------------------------------------------------------- store
    def _store_put_locked(self, ev: Event) -> None:
        key = (ev.source, ev.eid)
        ev.seq_store = next(self._arrival)  # type: ignore[attr-defined]
        dq = self._store.get(key)
        if dq is None:
            dq = self._store[key] = deque()
            self._store_eids.setdefault(ev.eid, set()).add(key)
        dq.append(ev)

    def _store_pop_locked(self, key: Tuple[int, str]) -> Event:
        dq = self._store[key]
        ev = dq.popleft()
        if not dq:
            del self._store[key]
            keys = self._store_eids.get(key[1])
            if keys is not None:
                keys.discard(key)
                if not keys:
                    del self._store_eids[key[1]]
        return ev

    def _take_from_store_locked(self, dep: Dep) -> Optional[Event]:
        """Oldest stored event matching ``dep`` (ANY scans only the store
        keys carrying its eid, via the eid side-index)."""
        best_key, best_seq = None, None
        if dep.source is ANY:
            for key in self._store_eids.get(dep.eid, ()):
                dq = self._store.get(key)
                if dq:
                    seq = dq[0].seq_store  # type: ignore[attr-defined]
                    if best_seq is None or seq < best_seq:
                        best_key, best_seq = key, seq
        else:
            if self._store.get(dep.key):
                best_key = dep.key
        if best_key is None:
            return None
        return self._store_pop_locked(best_key)

    def _fill_from_store_locked(self, c: Consumer, ready: List[Instance],
                                wake: List[Waiter],
                                refires: List[Event]) -> None:
        """Greedily satisfy a new consumer from stored events (keeps firing
        new frames for persistent tasks until the store runs dry)."""
        progress = True
        while progress:
            progress = False
            if isinstance(c, TaskConsumer):
                frames = c.frames if c.frames else (
                    [Frame(c.deps)] if c.persistent and c.deps else [])
                if c.persistent and c.deps and not c.frames:
                    c.frames = frames
            for f in (c.frames if isinstance(c, TaskConsumer) else [c.frame]):
                for s in f.slots:
                    if s.filled:
                        continue
                    ev = self._take_from_store_locked(s.dep)
                    if ev is not None:
                        s.event = ev
                        f.note(ev)
                        if ev.persistent:
                            refires.append(ev)
                        progress = True
            self._drain_consumer_locked(c, ready, wake)
            if c.done or not isinstance(c, TaskConsumer) or not c.persistent:
                break

    # ------------------------------------------------------------ submission
    def submit(self, fn: Callable, deps: List[Dep], name: Optional[str],
               persistent: bool) -> None:
        deps = expand_deps(deps, self.rank, self.n_ranks)
        c = TaskConsumer(fn, deps, name, persistent)
        ready: List[Instance] = []
        wake: List[Waiter] = []
        refires: List[Event] = []
        with self._mu:
            c.reg_order = next(self._reg_counter)
            if not deps and not persistent:
                # zero-dependency transitory task: immediately eligible
                ready.append(Instance(fn, [], name))
            else:
                self._fill_from_store_locked(c, ready, wake, refires)
                if not c.done:
                    self._consumers.append(c)
                    self._router.register(c)
            for inst in ready:
                self._ready.append(inst)
            if ready:
                self._cv.notify_all()
            self.sent += len(refires)
        for w in wake:
            with w.cv:
                w.cv.notify_all()
        for ev in refires:
            self.runtime._send_refire(self.rank, ev)

    def remove_task(self, name: str) -> bool:
        """Remove a named (typically persistent) task (paper §IV.A)."""
        with self._mu:
            for c in self._consumers:
                if c.name == name:
                    self._remove_consumer_locked(c)
                    return True
        return False

    # ------------------------------------------------------- wait / retrieve
    def wait(self, deps: List[Dep]) -> List[Event]:
        """Paper §IV.B ``edatWait``: pause task until deps satisfied.

        Blocks on a per-waiter condition variable that ``deliver`` notifies
        when the frame completes — no poll quantum on the wake path.
        """
        deps = expand_deps(deps, self.rank, self.n_ranks)
        cv = threading.Condition()
        w = Waiter(deps, cv)
        ready: List[Instance] = []
        wake: List[Waiter] = []
        refires: List[Event] = []
        evs: Optional[List[Event]] = None
        in_task = False
        with self._mu:
            self._fill_from_store_locked(w, ready, wake, refires)
            assert not ready
            self.sent += len(refires)
            if w.frame.complete:
                w.woken = True
                evs = w.frame.events()
            else:
                w.reg_order = next(self._reg_counter)
                self._consumers.append(w)
                self._router.register(w)
                in_task = self._tls.in_task
                if in_task:
                    # park: free the running slot; spawn a replacement worker
                    # so the configured concurrency is preserved (paper
                    # §IV.B).  The parking thread leaves the pool permanently
                    # (it exits after its task completes) — only on the first
                    # park.
                    self._running -= 1
                    if not self._tls.exit_after_task:
                        self._tls.exit_after_task = True
                        self._loops -= 1
                        self._spawn_worker()
                w.parked = True
                self._parked += 1
                self._cv.notify_all()
        for ev in refires:
            self.runtime._send_refire(self.rank, ev)
        if evs is not None:
            oc = self.on_consumed
            if oc is not None:
                oc(evs)
            return evs
        held = self._release_all_locks()
        with cv:
            while not w.frame.complete and not self._shutdown:
                cv.wait()
        with self._mu:
            if in_task:
                # re-acquire a running slot before resuming (paper: "a worker
                # will continue to run the task"); woken by task completions
                while self._running >= self.target and not self._shutdown:
                    self._cv.wait()
                self._running += 1
            self._parked -= 1
            if w.woken:
                self._resuming -= 1
        self._reacquire_locks(held)
        if self._shutdown and not w.frame.complete:
            raise RuntimeError("EDAT shut down while task was waiting")
        evs = w.frame.events()
        oc = self.on_consumed
        if oc is not None:
            oc(evs)
        return evs

    def retrieve_any(self, deps: List[Dep]) -> List[Event]:
        """Paper §IV.B ``edatRetrieveAny``: non-blocking subset retrieval."""
        deps = expand_deps(deps, self.rank, self.n_ranks)
        got: List[Event] = []
        refires: List[Event] = []
        with self._mu:
            for d in deps:
                ev = self._take_from_store_locked(d)
                if ev is not None:
                    if ev.persistent:
                        refires.append(ev)
                    got.append(ev)
            self.sent += len(refires)
            if self.metrics_on and got:
                self._count_consumed_locked(got)
        for ev in refires:
            self.runtime._send_refire(self.rank, ev)
        if got:
            oc = self.on_consumed
            if oc is not None:
                oc(got)
        return got

    # ----------------------------------------------------------------- locks
    def lock(self, name: str, blocking: bool = True) -> bool:
        me = threading.get_ident()
        with self._mu:
            if self._locks.get(name) == me:
                # reentrant acquisition: still record it so the lock is
                # auto-released at task end (paper §IV.C)
                if self._tls.locks is not None:
                    self._tls.locks.add(name)
                return True
            while self._locks.get(name) is not None:
                if not blocking:
                    return False
                self._lock_cv.wait()  # notified by unlock / shutdown
                if self._shutdown:
                    return False
            self._locks[name] = me
        if self._tls.locks is not None:
            self._tls.locks.add(name)
        return True

    def unlock(self, name: str) -> None:
        with self._mu:
            if self._locks.get(name) == threading.get_ident():
                self._locks[name] = None
                self._lock_cv.notify_all()
        if self._tls.locks is not None:
            self._tls.locks.discard(name)

    def test_lock(self, name: str) -> bool:
        return self.lock(name, blocking=False)

    def _release_all_locks(self) -> List[str]:
        held = sorted(self._tls.locks) if self._tls.locks else []
        for n in held:
            self.unlock(n)
        return held

    def _reacquire_locks(self, names: List[str]) -> None:
        for n in names:  # sorted order: deterministic, reduces deadlock risk
            self.lock(n)

    # --------------------------------------------------------------- workers
    def _worker_loop(self):
        with self._mu:
            self._loops += 1
        poll = self.progress_mode == "worker"
        busy_t0 = 0.0       # busy-span start stamp; 0.0 = currently idle
        while True:
            inst = None
            with self._mu:
                if self._loops > self.target or (
                        self._shutdown and not self._ready):
                    self._loops -= 1
                    if busy_t0:
                        self._busy_s += time.monotonic() - busy_t0
                    return
                if self._ready and self._running < self.target:
                    inst = self._ready.popleft()
                    self._running += 1
            if inst is None:
                if poll and self._poll_once():
                    continue
                with self._mu:
                    if busy_t0:
                        # idle transition: close the busy span (spans keep
                        # per-task timestamps off the execution hot path)
                        self._busy_s += time.monotonic() - busy_t0
                        busy_t0 = 0.0
                    if self._mail:
                        self._mail = False  # message raced our last poll
                    elif not self._ready and not self._shutdown:
                        # woken by: ready work, task completion, shutdown,
                        # or the transport notify hook (worker-poll mode).
                        # A poll-mode transport without a notify hook can't
                        # wake us on arrival: keep the seed's timed poll.
                        if poll and not self._mail_hooked:
                            self._cv.wait(0.002)
                        else:
                            self._cv.wait()
                continue
            if busy_t0 == 0.0 and self.metrics_on:
                busy_t0 = time.monotonic()
            self._run(inst)
            if self._tls.exit_after_task:
                # this thread left the pool when it parked (loops already
                # decremented); a replacement is looping in its stead
                self._tls.exit_after_task = False
                if busy_t0:
                    with self._mu:
                        self._busy_s += time.monotonic() - busy_t0
                return

    def _poll_once(self) -> bool:
        """Idle-worker progress polling (paper §II.F alternative mode)."""
        return self.runtime._progress_poll(self.rank)

    def _run(self, inst: Instance):
        ctx = self.runtime._ctx(self.rank)
        self._tls.locks = set()
        self._tls.in_task = True
        # busy time is span-based (idle->busy transitions in _worker_loop),
        # so per-task timestamps are only taken for the opt-in trace
        t0 = time.monotonic() if self.trace_on else 0.0
        try:
            inst.fn(ctx, inst.events)
        except Exception as e:  # noqa: BLE001 - report any task failure
            self.runtime._task_failed(self.rank, inst, e)
        finally:
            self._tls.in_task = False
            for n in sorted(self._tls.locks):
                self.unlock(n)  # auto-release (paper §IV.C)
            self._tls.locks = None
            dur = (time.monotonic() - t0) if self.trace_on else 0.0
            with self._mu:
                self._running -= 1
                self._executed += 1
                if self.metrics_on:
                    rec = inst.mrec       # consume accounting: the delivery
                    if rec is not None:   # record rode in on the instance
                        rec[1] += 1
                        rec[2] -= 1
                    else:                 # multi-dep / store-filled / 0-dep
                        md = self._m_deliv
                        for ev in inst.events:
                            rec = md.get(ev.eid)
                            if rec is None:
                                rec = md[ev.eid] = [0, 0, 0, 0]
                            rec[1] += 1
                            rec[2] -= 1
                if self.trace_on:
                    self._trace_add_locked(
                        ("task", t0, dur,
                         inst.name or getattr(inst.fn, "__name__", "?"),
                         len(inst.events)))
                self._cv.notify_all()
                idle = self._idle_locked()
            oc = self.on_consumed
            if oc is not None and inst.events:
                # completion record even if the task raised: the event WAS
                # consumed; the error aborts the whole run regardless
                oc(inst.events)
            if idle:
                self.runtime._poke()

    # --------------------------------------------------------------- metrics
    def count_fire_locked(self, eid: str, n: int, nbytes: int,
                          wire: int) -> None:
        """Charge ``n`` fires on channel ``eid`` (caller holds ``_mu`` —
        the fire paths bump this alongside ``sent``)."""
        rec = self._m_fires.get(eid)
        if rec is None:
            rec = self._m_fires[eid] = [0, 0, 0]
        rec[0] += n
        rec[1] += nbytes
        rec[2] += wire

    def _count_consumed_locked(self, evs) -> None:
        md = self._m_deliv
        for ev in evs:
            rec = md.get(ev.eid)
            if rec is None:
                rec = md[ev.eid] = [0, 0, 0, 0]
            rec[1] += 1
            rec[2] -= 1

    def _trace_add_locked(self, rec: tuple) -> None:
        if len(self._trace) < TRACE_CAP:
            self._trace.append(rec)
        else:
            self._trace_dropped += 1

    def metrics_snapshot(self) -> dict:
        """Consistent snapshot of this rank's counters (takes ``_mu``)."""
        with self._mu:
            out = {
                "fires": {e: tuple(v) for e, v in self._m_fires.items()},
                "deliveries": {e: tuple(v)
                               for e, v in self._m_deliv.items()},
                "quorum_wait_s": dict(self._m_quorum),
                "tasks_executed": self._executed,
                "busy_s": self._busy_s,
            }
            if self.trace_on:
                out["trace"] = list(self._trace)
                out["trace_dropped"] = self._trace_dropped
            return out

    # ---------------------------------------------------------- termination
    def set_main_done(self):
        with self._mu:
            self._main_done = True
            idle = self._idle_locked()
        if idle:
            self.runtime._poke()

    def status(self) -> dict:
        with self._mu:
            unmet = sum(1 for c in self._consumers
                        if isinstance(c, TaskConsumer) and c.unmet())
            stored_transitory = sum(
                sum(1 for e in dq if not e.persistent)
                for dq in self._store.values())
            return dict(
                sent=self.sent, received=self.received,
                idle=self._idle_locked(),
                parked=self._parked, unmet=unmet,
                stored=stored_transitory, executed=self._executed,
            )
