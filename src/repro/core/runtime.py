"""EDAT runtime: ranks, progress, distributed termination, timers, failures.

``Runtime`` plays the role of the paper's library init/finalise pair
(§II, §II.E): it spawns one SPMD main thread per rank, runs progress (a
dedicated progress thread per rank, or idle-worker polling — both modes of
paper §II.F), and detects global termination with a Mattern-style
four-counter quiescence check driven through the transport itself.

Termination detection is *wakeup-driven*: schedulers poke an activity epoch
whenever a rank transitions to idle (and on timer/failure state changes),
and the detector blocks on that epoch instead of sleep-polling.  The
four-counter logic itself (two consecutive idle polls with globally
``sent == received`` and empty mailboxes) is unchanged.

A ``Runtime`` may host *all* ranks (threads-as-ranks over
:class:`InProcTransport`) or a subset of them (one OS process hosting one
*or several* ranks over :class:`repro.net.SocketTransport`, declared via
the transport's ``local_ranks``; co-located ranks exchange messages
through the transport's in-process loopback).  In the distributed case
every cross-rank interaction —
status polling for the Mattern detector, the termination broadcast, task
failure propagation, detector wakeups — travels through the transport as
CONTROL messages; rank 0 owns the detector, the other processes block until
its ``terminate`` broadcast arrives.  Counter balancing uses the
transport's per-peer sent/received vectors restricted to the alive ranks,
so events exchanged with a failed process stay balanced without reading its
(unreachable) memory.

Beyond-paper (but anticipated in the paper's §VII "further work"): machine
generated events — timer events (``fire_after``) and rank-failure events
(``RANK_FAILED``) — and node-failure injection used by the fault-tolerant
trainer built on top.
"""
from __future__ import annotations

import functools
import heapq
import itertools
import pickle
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .deprecation import warn_deprecated
from .event import (ALL, ANY, SELF, RANK_FAILED, SYS_PREFIX, TIMER_CANCELLED,
                    Dep, Event, copy_payload)
from .metrics import _FIXED8, _IMMUTABLE, payload_nbytes
from ..durable.log import FIRED
from .scheduler import Scheduler
from .transport import CONTROL, EVENT, InProcTransport, Message, Transport

DepLike = Union[Dep, Tuple[Any, str]]
FireLike = Union[Tuple[Any, str], Tuple[Any, str, Any]]


class EdatDeadlockError(RuntimeError):
    """Raised when the system is quiescent but the paper's termination
    conditions (§II.E) cannot be met: a transitory task has unmet
    dependencies, a task is parked forever, or transitory events remain
    unconsumed.  (The paper's library would hang; we diagnose.)"""


class EdatTaskError(RuntimeError):
    """A task raised; re-raised from :meth:`Runtime.run`."""


class RankDiedError(EdatTaskError):
    """A rank's process died (SIGKILL, crash, lost heartbeat) and the run
    cannot complete from this observer's point of view — notably when the
    dead rank is the termination coordinator (rank 0), whose terminate
    broadcast will never arrive.  Driver-side ``Future``s surface it; the
    process launcher treats it as an orderly child outcome (exit 0)."""


class TimerHandle:
    def __init__(self, runtime: "Runtime", tid: int):
        self._rt = runtime
        self.tid = tid

    def cancel(self) -> bool:
        """Cancel the timer.  True only if it had not yet fired."""
        return self._rt._cancel_timer(self.tid)


class TaskHandle:
    """Handle for a submitted task (v2 API): returned by ``ctx.submit`` /
    ``ctx.submit_persistent``.  ``remove()`` deregisters a *named* task
    (the paper's ``edatRemoveTask``); unnamed handles return False."""

    __slots__ = ("_sched", "rank", "name", "persistent")

    def __init__(self, sched: "Scheduler", name: Optional[str],
                 persistent: bool):
        self._sched = sched
        self.rank = sched.rank
        self.name = name
        self.persistent = persistent

    def remove(self) -> bool:
        """Remove the task from its rank's registry.  True iff it was
        still registered (requires the task to have been named)."""
        if self.name is None:
            return False
        return self._sched.remove_task(self.name)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        kind = "persistent" if self.persistent else "task"
        return f"TaskHandle({kind} {self.name!r} on rank {self.rank})"


class Context:
    """Per-rank public API — mirrors the paper's C API Pythonically.

    ===========================  =======================================
    paper                        here
    ===========================  =======================================
    ``edatGetRank``              ``ctx.rank``
    ``edatSubmitTask``           ``ctx.submit(fn, deps)``
    ``edatSubmitPersistentTask`` ``ctx.submit_persistent(fn, deps)``
    ``edatFireEvent``            ``ctx.fire(target, eid, data)``
    ``edatFirePersistentEvent``  ``ctx.fire(..., persistent=True)``
    ``edatWait``                 ``ctx.wait(deps)``
    ``edatRetrieveAny``          ``ctx.retrieve_any(deps)``
    ``edatLock/Unlock/TestLock`` ``ctx.lock / ctx.unlock / ctx.test_lock``
    ``EDAT_SELF/ANY/ALL``        ``edat.SELF / edat.ANY / edat.ALL``
    ``EDAT_ADDRESS``             ``ctx.fire(..., ref=True)``
    (batched fire)               ``ctx.fire_batch([(t, eid, data), ...])``
    ===========================  =======================================
    """

    def __init__(self, runtime: "Runtime", rank: int):
        self._rt = runtime
        self.rank = rank
        self.n_ranks = runtime.n_ranks
        #: declared channel table ({eid: Channel-or-None}), or None (no
        #: enforcement).  Set by :meth:`declare_channels` when a v2
        #: ``Program`` declares its typed channels.
        self._declared: Optional[Dict[str, Any]] = None

    # -- channels ------------------------------------------------------------
    def declare_channels(self, channels: Sequence[Any]) -> None:
        """Declare this rank's event vocabulary (v2 typed channels).

        Once declared, firing or depending on an *undeclared* event id
        raises ``KeyError`` immediately at the call site — the fast
        replacement for the silent never-matching typo of stringly-typed
        eids — and fires on a declared *typed* channel are payload-type
        checked even when addressed by the raw id string.  Ids starting
        with ``"__"`` (runtime-internal and machine-generated events,
        collective-pattern eids) are exempt."""
        self._declared = {str(c): (c if hasattr(c, "validate") else None)
                          for c in channels}
        dur_eids = [str(c) for c in channels if getattr(c, "durable", False)]
        if dur_eids:
            self._rt._durable_add(dur_eids)

    def _check_eid(self, eid: str) -> None:
        d = self._declared
        if d is not None and eid not in d and not eid.startswith("__"):
            raise KeyError(
                f"event id {eid!r} is not a declared channel of this "
                f"program (declared: {sorted(d)})")

    def _check_fire(self, eid: str, data: Any) -> None:
        """Declared-vocabulary enforcement for one fire: unknown id ->
        KeyError (via :meth:`_check_eid`, the one source of truth for the
        exemption rule); declared typed channel -> payload validation
        (also for raw-string addressing)."""
        self._check_eid(eid)
        ch = self._declared.get(eid)
        if ch is not None:
            ch.validate(data)

    def _pre_fire(self, eid: str, data: Any) -> None:
        """The one guard every fire path (fire / fire_batch / fire_after)
        runs: declared vocabulary enforcement when the program declared
        channels, else duck-typed payload validation for a typed Channel
        eid (a ``validate`` attribute — the core never imports
        :mod:`repro.api`).  Plain-string fires without a declaration stay
        check-free."""
        if self._declared is not None:
            self._check_fire(eid, data)
        elif type(eid) is not str:
            validate = getattr(eid, "validate", None)
            if validate is not None:
                validate(data)

    def _check_deps(self, deps: List[Dep]) -> List[Dep]:
        """Declared-vocabulary check for dependency eids (submit / wait /
        retrieve_any paths); returns ``deps`` for call-site chaining."""
        if self._declared is not None:
            for dp in deps:
                self._check_eid(dp.eid)
        return deps

    # -- tasks ---------------------------------------------------------------
    def submit(self, fn: Callable, deps: Sequence[DepLike] = (),
               name: Optional[str] = None) -> TaskHandle:
        d = self._check_deps(_deps(deps))
        sched = self._rt._sched[self.rank]
        sched.submit(fn, d, name, False)
        return TaskHandle(sched, name, False)

    def submit_persistent(self, fn: Callable, deps: Sequence[DepLike],
                          name: Optional[str] = None) -> TaskHandle:
        d = _deps(deps)
        if not d:
            raise ValueError("a persistent task needs >= 1 dependency")
        self._check_deps(d)
        sched = self._rt._sched[self.rank]
        sched.submit(fn, d, name, True)
        return TaskHandle(sched, name, True)

    def remove_task(self, name: str) -> bool:
        return self._rt._sched[self.rank].remove_task(name)

    # -- events --------------------------------------------------------------
    def fire(self, target: Any, eid: str, data: Any = None, *,
             persistent: bool = False, ref: bool = False) -> None:
        if eid.startswith(SYS_PREFIX):
            raise ValueError(f"EIDs starting with {SYS_PREFIX!r} are reserved")
        self._pre_fire(eid, data)
        self._rt._fire(self.rank, target, eid, data,
                       persistent=persistent, ref=ref)

    def fire_batch(self, fires: Sequence[FireLike], *,
                   persistent: bool = False, ref: bool = False) -> None:
        """Fire many events with one transport round-trip per destination.

        ``fires`` is a sequence of ``(target, eid)`` or ``(target, eid,
        data)`` tuples; each element has exactly the semantics of a single
        :meth:`fire` (payload copied at fire time, per-(src,dst) FIFO order
        preserved across the batch).
        """
        for f in fires:
            eid = f[1]
            if eid.startswith(SYS_PREFIX):
                raise ValueError(
                    f"EIDs starting with {SYS_PREFIX!r} are reserved")
            self._pre_fire(eid, f[2] if len(f) > 2 else None)
        self._rt._fire_batch(self.rank, fires, persistent=persistent, ref=ref)

    def fire_after(self, delay: float, target: Any, eid: str,
                   data: Any = None) -> TimerHandle:
        """Machine-generated timer event (paper §VII further work)."""
        self._pre_fire(eid, data)
        return self._rt._fire_after(self.rank, delay, target, eid, data)

    # -- pause / poll ----------------------------------------------------------
    def wait(self, deps: Sequence[DepLike]) -> List[Event]:
        return self._rt._sched[self.rank].wait(
            self._check_deps(_deps(deps)))

    def retrieve_any(self, deps: Sequence[DepLike]) -> List[Event]:
        return self._rt._sched[self.rank].retrieve_any(
            self._check_deps(_deps(deps)))

    # -- locks -----------------------------------------------------------------
    def lock(self, name: str) -> None:
        self._rt._sched[self.rank].lock(name)

    def unlock(self, name: str) -> None:
        self._rt._sched[self.rank].unlock(name)

    def test_lock(self, name: str) -> bool:
        return self._rt._sched[self.rank].test_lock(name)

    # -- info -------------------------------------------------------------------
    def alive_ranks(self) -> List[int]:
        return [r for r in range(self.n_ranks) if not self._rt.is_dead(r)]


def _deps(deps: Sequence[DepLike]) -> List[Dep]:
    out = []
    for d in deps:
        out.append(d if isinstance(d, Dep) else Dep(d[0], d[1]))
    return out


class Runtime:
    """An EDAT 'machine': ``n_ranks`` SPMD ranks over a pluggable transport.

    ``progress='thread'`` gives each rank a dedicated progress thread;
    ``progress='worker'`` maps progress polling onto idle workers — the two
    modes of paper §II.F.  In worker mode the transport's notify hook wakes
    an idle worker on message arrival instead of the worker sleep-polling.
    """

    def __init__(self, n_ranks: int, workers_per_rank: int = 1, *,
                 progress: str = "thread",
                 unconsumed: str = "error",
                 transport: Optional[Transport] = None,
                 poll_interval: float = 0.002,
                 metrics: bool = True,
                 trace: bool = False,
                 durable: Optional[Union[bool, dict]] = None):
        assert progress in ("thread", "worker")
        assert unconsumed in ("error", "warn", "ignore")
        self.n_ranks = n_ranks
        self.transport: Transport = transport or InProcTransport(n_ranks)
        self._distributed = bool(self.transport.distributed)
        # loopback-only transports can never put a fire on the wire, so the
        # fire-path metrics skip the per-target membership test entirely
        self._wire_possible = bool(self.transport.serializes)
        local = self.transport.local_ranks
        self._local_ranks: List[int] = (sorted(local) if local is not None
                                        else list(range(n_ranks)))
        #: the rank that runs the Mattern detector and broadcasts terminate
        self._det_rank = 0
        self._metrics_on = bool(metrics)
        self._trace_on = bool(trace)
        self._sched = {r: Scheduler(r, n_ranks, self, workers_per_rank,
                                    progress, metrics=self._metrics_on,
                                    trace=self._trace_on)
                       for r in self._local_ranks}
        self._ctxs = {r: Context(self, r) for r in self._local_ranks}
        self._progress_mode = progress
        self._unconsumed = unconsumed
        # retained as the detector's backstop wait cap (the detector is
        # normally woken by idle-transition pokes, not by this interval)
        self._poll_interval = max(poll_interval, 0.25)
        self._prog_threads: List[threading.Thread] = []
        self._main_threads: List[threading.Thread] = []
        self._shutdown = False
        self._error: Optional[BaseException] = None
        self._err_mu = threading.Lock()
        # activity epoch: bumped on every idle transition / timer change;
        # the termination detector blocks on it instead of sleep-polling
        self._quiet_cv = threading.Condition()
        self._epoch = 0
        # timers
        self._timers: List[Tuple[float, int, int, int, str, Any]] = []
        self._timer_ids = itertools.count()
        self._live_tids: set = set()   # scheduled and not yet fired/cancelled
        self._cancelled: set = set()
        self._timer_cv = threading.Condition()
        self._timer_thread: Optional[threading.Thread] = None
        self._pending_timers = 0
        self.stats: Dict[str, Any] = {}
        # distributed-termination plumbing (CONTROL-message protocol)
        self._status_replies: List[dict] = []
        self._status_cv = threading.Condition()
        self._probe = 0                       # status-poll generation id
        self._term_event = threading.Event()  # set by rank 0's broadcast
        self._remote_stats: Dict[str, Any] = {}
        self._remote_error: Optional[str] = None
        self._remote_poke_mu = threading.Lock()
        self._last_remote_poke = 0.0
        # durable mode (repro.durable): None until activated — either here
        # (durable=True / an eager spec) or lazily by per-channel opt-in
        # (Context.declare_channels -> _durable_add)
        self._durable = None
        self._durable_spec: Optional[dict] = None
        self._dur_mu = threading.Lock()
        if durable:
            spec = dict(durable) if isinstance(durable, dict) else {}
            if spec.get("all", True) or spec.get("channels"):
                self._durable_ensure(spec)
            else:
                self._durable_spec = spec
        if self._distributed:
            # heartbeat/EOF peer-failure detection feeds RANK_FAILED
            self.transport.on_peer_dead = self._on_peer_dead
            if hasattr(self.transport, "on_peer_join"):
                # elastic join: a replacement process re-hosted a dead rank
                self.transport.on_peer_join = self._on_peer_joined
            set_deliver = getattr(self.transport, "set_deliver", None)
            if set_deliver is not None:
                # push mode: the transport's reader threads hand batches
                # straight to delivery, skipping the progress-thread hop;
                # batches may mix co-located destination ranks
                set_deliver(self._push_deliver)
        if (progress == "worker"
                and type(self.transport).set_notify
                is not Transport.set_notify):
            # the transport can wake idle workers on arrival; without a real
            # notify override the workers fall back to timed polling
            for r in self._local_ranks:
                self.transport.set_notify(r, self._sched[r]._notify_mail)
                self._sched[r]._mail_hooked = True

    # --------------------------------------------------------------- wakeups
    def _poke(self, force: bool = False) -> None:
        """Bump the activity epoch and wake the termination detector.

        Unless forced, the wake is suppressed while the cheap quiescence
        gate fails — a busy system pokes on every idle transition (e.g.
        twice per ping-pong hop) and waking the detector each time would put
        context switches on the message critical path.  A suppressed wake
        that raced the real final transition is recovered by the detector's
        backstop timeout."""
        if not force and not self._maybe_quiescent():
            return
        if self._distributed and self._det_rank not in self._sched:
            # the detector lives in another process: nudge it with a CONTROL
            # poke (rate-limited — the backstop wait recovers a skipped one)
            now = time.monotonic()
            send = force
            if not send:
                with self._remote_poke_mu:
                    if now - self._last_remote_poke >= 0.05:
                        self._last_remote_poke = now
                        send = True
            if send:
                self.transport.send(Message(CONTROL, self._local_ranks[0],
                                            self._det_rank, ("poke", None)))
        with self._quiet_cv:
            self._epoch += 1
            self._quiet_cv.notify_all()

    # ------------------------------------------------------------ durable
    def _durable_ensure(self, spec: Optional[dict] = None):
        """Activate durable mode once (idempotent): build the
        :class:`repro.durable.DurableState` and hook every local
        scheduler's consume path so *completed* records follow fires."""
        with self._dur_mu:
            if self._durable is None:
                if spec is None:
                    spec = self._durable_spec or {"all": False}
                from repro.durable import DurableState
                dur = DurableState(self, spec)
                for r, sch in self._sched.items():
                    sch.on_consumed = dur.consumed_hook(r)
                self._durable = dur
        return self._durable

    def _durable_add(self, eids: Sequence[str]) -> None:
        """Per-channel opt-in (``Channel(..., durable=True)``), called from
        ``Context.declare_channels`` on every rank — idempotent."""
        self._durable_ensure().add_eids(eids)

    def _durable_error(self, exc: BaseException) -> None:
        with self._err_mu:
            if self._error is None:
                self._error = EdatTaskError(f"durable replay failed: {exc}")
                self._error.__cause__ = exc
        self._poke(force=True)

    def _durable_plan(self, records, prefer: Optional[int] = None,
                      targets: Optional[Dict[str, set]] = None
                      ) -> List[Tuple[object, str, int, object]]:
        """Destination selection for replay — the pure half of the old
        ``_durable_refire``, split out so the coordinator can journal the
        REPLAYED records *before* any event is sent (the in-memory log
        prunes on completion, so a fast survivor's *completed* append must
        never reach the queue ahead of the replay record it should prune).

        Dead targets are redirected to ``prefer`` (a freshly joined
        replacement) when alive, else round-robin over survivors the log
        has seen consume that channel (``targets``: eid -> historical dst
        set — a rank that never received the channel likely has no
        consumer for it).  Returns ``[(key, eid, new_dst, blob), ...]``.
        """
        alive = [r for r in range(self.n_ranks) if not self.is_dead(r)]
        if not alive:
            return []
        rr: Dict[str, int] = {}
        plan: List[Tuple[object, str, int, object]] = []
        for key, _kind, eid, _osrc, odst, blob in records:
            if not self.is_dead(odst):
                dst = odst
            elif prefer is not None and not self.is_dead(prefer):
                dst = prefer
            else:
                cand = alive
                if targets:
                    known = [r for r in alive if r in targets.get(eid, ())]
                    if known:
                        cand = known
                i = rr.get(eid, 0)
                rr[eid] = i + 1
                dst = cand[i % len(cand)]
            plan.append((key, eid, dst, blob))
        return plan

    def _durable_send(self, plan) -> None:
        """Re-fire a replay plan (at-least-once — each event keeps its
        original idempotency key).  Dead *sources* are replaced by this
        process's lead rank so the Mattern counters stay inside the alive
        columns."""
        src = min(self._sched)
        sch = self._sched[src]
        for key, eid, dst, blob in plan:
            # the in-memory backend stores immutable payloads raw (no
            # pickle roundtrip on the hot path); bytes means pickled
            data = pickle.loads(blob) if type(blob) is bytes else blob
            ev = Event(data=data, source=src, eid=eid)
            ev._dkey = key
            with sch._mu:
                sch.sent += 1
                if sch.metrics_on:
                    sch.count_fire_locked(
                        eid, 1, payload_nbytes(data),
                        0 if dst in self._sched else 1)
            self.transport.send(Message(EVENT, src, dst, ev))

    def _durable_refire(self, records, prefer: Optional[int] = None,
                        targets: Optional[Dict[str, set]] = None
                        ) -> List[Tuple[object, str, int]]:
        """Plan + send in one step (kept for direct callers/tests; the
        replay coordinator calls the halves separately so it can journal
        between them).  Returns ``[(key, eid, new_dst), ...]``."""
        plan = self._durable_plan(records, prefer=prefer, targets=targets)
        self._durable_send(plan)
        return [(key, eid, dst) for key, eid, dst, _blob in plan]

    def _on_peer_joined(self, rank: int) -> None:
        """Transport elastic-join callback: a replacement process now hosts
        ``rank``.  Re-arm durable failure handling for it and wake the
        detector (the alive set just changed under it)."""
        if self._durable is not None:
            self._durable.note_joined(rank)
        self._poke(force=True)

    # ------------------------------------------------------------ event path
    def _targets(self, src: int, target: Any) -> List[int]:
        """Expand a fire target; reject out-of-range ranks *before* any
        counter is touched (a post-count failure would permanently
        unbalance the Mattern sent/received counters and hang run())."""
        if target is ALL:
            return list(range(self.n_ranks))
        if target is SELF:
            return [src]
        t = int(target)
        if not 0 <= t < self.n_ranks:
            raise ValueError(
                f"fire target rank {t} out of range [0, {self.n_ranks})")
        return [t]

    def _fire(self, src: int, target: Any, eid: str, data: Any, *,
              persistent: bool, ref: bool) -> None:
        dur = self._durable
        if dur is not None:
            durable = dur._wcache.get(eid)  # inlined wants() fast path
            if durable is None:
                durable = dur.wants(eid)
        else:
            durable = False
        # validated before the sent counter is touched: a non-transportable
        # payload raises here, in the firing task, with balanced counters
        self.transport.validate_payload(data)
        targets = self._targets(src, target)
        if durable:
            # Durable-channel fire: plain semantics plus an idempotency key
            # stamped on each Event (``_dkey`` lives in the instance
            # __dict__, so it rides pickle and the in-process loopback
            # alike) and an off-hot-path *fired* log append.  Keys are
            # cheap tuples (the sqlite backend stringifies at write time);
            # immutable payloads skip both the defensive copy and the
            # fire-time pickle — the log's writer thread snapshots them
            # instead, which is safe exactly because nothing can mutate
            # them.  Mutable payloads pay one eager ``pickle.dumps`` that
            # doubles as the per-target defensive copy, so durable
            # payloads must pickle even on the in-proc transport.
            imm = type(data) in _IMMUTABLE
            if imm and type(data) is not bytes:
                # deferred snapshot; raw bytes payloads are excluded so a
                # backend blob is unambiguously always pickle output
                blob = data
            else:
                blob = pickle.dumps(data, pickle.HIGHEST_PROTOCOL)
            copy_free = (ref or imm
                         or (self.transport.serializes
                             and all(t not in self._sched for t in targets)))
            # a zombie task on a simulated-dead rank (kill_rank; the thread
            # finishes its current task) must not log fires the transport
            # will drop — they would leak as forever-pending records
            nx, tag, ap, dead, idk = dur._hot
            log_ok = not dead(src)
            msgs = []
            if idk:
                # reference-delivery transport + in-process log: the Event
                # object itself is the journal entry and its identity the
                # idempotency key — no counter, no key tuple, no setattr
                for t in targets:
                    payload = data if copy_free else pickle.loads(blob)
                    ev = Event(data=payload, source=src, eid=eid,
                               persistent=persistent)
                    if log_ok:
                        ap((ev, t, blob))
                    msgs.append(Message(EVENT, src, t, ev, owned=ref))
            else:
                for t in targets:
                    payload = data if copy_free else pickle.loads(blob)
                    ev = Event(data=payload, source=src, eid=eid,
                               persistent=persistent)
                    key = (src, t, eid, nx(), tag)
                    ev._dkey = key
                    if log_ok:
                        # compact fired form; the log's writer expands it
                        ap((key, blob))
                    msgs.append(Message(EVENT, src, t, ev, owned=ref))
        else:
            # a serialising transport pickles every remote message
            # synchronously inside send — that IS the fire-time snapshot,
            # so the defensive deep-copy is only needed when some target is
            # hosted by THIS process (self-sends and co-located ranks take
            # the transport's loopback, which delivers the object by
            # reference)
            copy_free = ref or (self.transport.serializes
                                and all(t not in self._sched
                                        for t in targets))
            payload = data if copy_free else copy_payload(data)
            # ref=True hands payload ownership over (EDAT_ADDRESS): a
            # deferred-write transport may then serialise it lazily and
            # zero-copy
            msgs = [Message(EVENT, src, t,
                            Event(data=payload
                                  if (copy_free or len(targets) == 1)
                                  else copy_payload(payload),
                                  source=src, eid=eid,
                                  persistent=persistent),
                            owned=ref)
                    for t in targets]
        sch = self._sched[src]
        # sent is counted before the send so the termination detector can
        # never observe balanced counters with the message still in flight;
        # a send to a dead destination is counted by the transport as
        # dropped: termination balances sent == received + dropped
        if sch.metrics_on:
            # count_fire_locked, inlined with the arithmetic hoisted off the
            # lock: this is the fire hot path
            n = len(msgs)
            nbytes = (8 if type(data) in _FIXED8
                      else payload_nbytes(data)) * n
            if not self._wire_possible:
                wire = 0
            elif n == 1:                       # overwhelmingly common
                wire = 0 if targets[0] in self._sched else 1
            else:
                wire = 0
                for t in targets:
                    if t not in self._sched:
                        wire += 1
            with sch._mu:
                sch.sent += n
                rec = sch._m_fires.get(eid)
                if rec is None:
                    rec = sch._m_fires[eid] = [0, 0, 0]
                rec[0] += n
                rec[1] += nbytes
                rec[2] += wire
        else:
            with sch._mu:
                sch.sent += len(msgs)
        if len(msgs) == 1:
            self.transport.send(msgs[0])
        else:
            self.transport.send_many(msgs)

    def _fire_batch(self, src: int, fires: Sequence[FireLike], *,
                    persistent: bool, ref: bool) -> None:
        dur = self._durable
        if dur is not None and any(dur.wants(f[1]) for f in fires):
            # durable fires need a key per (event, target): take the
            # per-fire path (batching is a wire optimisation, not semantics)
            for f in fires:
                self._fire(src, f[0], f[1], f[2] if len(f) > 2 else None,
                           persistent=persistent, ref=ref)
            return
        sch = self._sched[src]
        msgs: List[Message] = []
        agg: Optional[Dict[str, List[int]]] = {} if sch.metrics_on else None
        for f in fires:
            target, eid = f[0], f[1]
            data = f[2] if len(f) > 2 else None
            self.transport.validate_payload(data)
            targets = self._targets(src, target)
            copy_free = ref or (self.transport.serializes
                                and all(t not in self._sched
                                        for t in targets))
            payload = data if copy_free else copy_payload(data)
            for t in targets:
                msgs.append(Message(EVENT, src, t,
                                    Event(data=payload
                                          if (copy_free or len(targets) == 1)
                                          else copy_payload(payload),
                                          source=src, eid=eid,
                                          persistent=persistent),
                                    owned=ref))
            if agg is not None:
                rec = agg.get(eid)
                if rec is None:
                    rec = agg[eid] = [0, 0, 0]
                rec[0] += len(targets)
                rec[1] += payload_nbytes(data) * len(targets)
                rec[2] += sum(1 for t in targets if t not in self._sched)
        if not msgs:
            return
        with sch._mu:
            sch.sent += len(msgs)
            if agg:
                for eid, v in agg.items():
                    sch.count_fire_locked(eid, v[0], v[1], v[2])
        self.transport.send_many(msgs)

    def _send_refire(self, rank: int, ev: Event) -> None:
        """Persistent event consumed -> re-fired locally (paper §IV.A).
        The scheduler already counted it as sent under its own lock."""
        self.transport.send(Message(EVENT, rank, rank, ev.clone()))

    # system events bypass Context validation
    def _fire_sys(self, src: int, target: int, eid: str, data: Any) -> None:
        sch = self._sched[src]
        ev = Event(data=copy_payload(data), source=src, eid=eid)
        with sch._mu:
            sch.sent += 1
            if sch.metrics_on:
                sch.count_fire_locked(
                    eid, 1, payload_nbytes(data),
                    0 if target in self._sched else 1)
        self.transport.send(Message(EVENT, src, target, ev))

    # ------------------------------------------------------------- progress
    def _progress_loop(self, rank: int) -> None:
        while not self._shutdown and not self.transport.is_dead(rank):
            msgs = self.transport.recv_many(rank, timeout=0.5)
            if msgs:
                self._handle_many(rank, msgs)

    def _progress_poll(self, rank: int) -> bool:
        """One poll step for idle-worker progress mode.  True if progressed."""
        msgs = self.transport.drain(rank, max_n=64)
        if not msgs:
            return False
        self._handle_many(rank, msgs)
        return True

    def _push_deliver(self, msgs: List[Message]) -> None:
        """Push-mode entry from a distributed transport's reader threads:
        route each message to its destination rank's scheduler (one call
        may carry messages for several co-located ranks)."""
        by_dst: Dict[int, List[Message]] = {}
        for m in msgs:
            by_dst.setdefault(m.dst, []).append(m)
        for r, ms in by_dst.items():
            if r in self._sched:
                self._handle_many(r, ms)

    def _handle_many(self, rank: int, msgs: List[Message]) -> None:
        events = [m.payload for m in msgs if m.kind == EVENT]
        if events:
            self._sched[rank].deliver_many(events)
        for m in msgs:
            if m.kind == CONTROL:
                self._handle_control(rank, m)

    def _handle_control(self, rank: int, msg: Message) -> None:
        tag, data = msg.payload
        if tag == "status?":
            st = self._local_status(rank)
            st["probe"] = data
            if self._distributed and msg.src not in self._sched:
                # detector lives in another process: reply over the wire
                self.transport.send(
                    Message(CONTROL, rank, msg.src, ("status!", st)))
            else:
                with self._status_cv:
                    self._status_replies.append(st)
                    self._status_cv.notify_all()
        elif tag == "status!":
            with self._status_cv:
                self._status_replies.append(data)
                self._status_cv.notify_all()
        elif tag == "poke":
            with self._quiet_cv:
                self._epoch += 1
                self._quiet_cv.notify_all()
        elif tag == "abort":
            # a task failed in another process; the detector returns as soon
            # as it observes the error
            with self._err_mu:
                if self._error is None:
                    self._error = EdatTaskError(data)
            self._poke(force=True)
        elif tag == "terminate":
            self._remote_stats = data.get("stats") or {}
            self._remote_error = data.get("error")
            self._term_event.set()

    def _local_status(self, rank: int) -> dict:
        """One rank's status reply, extended with the per-process state the
        distributed detector cannot read directly (timers, transport drop
        counter, mailbox depth, per-peer sent/received vectors).  Process-
        wide quantities are reported by the lowest local rank only, so
        summing replies never multi-counts."""
        st = self._sched[rank].status()
        st["rank"] = rank
        st["mailbox"] = self.transport.pending(rank)
        reporter = next((r for r in self._local_ranks
                         if not self.transport.is_dead(r)),
                        self._local_ranks[0])
        if rank == reporter:
            with self._timer_cv:
                st["timers"] = self._pending_timers
            st["dropped"] = self.transport.dropped
            if self._distributed:
                st["sent_to"] = self.transport.sent_vector()
                st["recv_from"] = self.transport.recv_vector()
        else:
            st["timers"] = 0
            st["dropped"] = 0
        return st

    # --------------------------------------------------------------- timers
    def _fire_after(self, src: int, delay: float, target: Any, eid: str,
                    data: Any) -> TimerHandle:
        if target is ALL:
            dst = self.n_ranks          # ALL sentinel in the timer tuple
        elif target is SELF:
            dst = src
        else:
            dst = int(target)
            if not 0 <= dst < self.n_ranks:
                raise ValueError(f"fire target rank {dst} out of range "
                                 f"[0, {self.n_ranks})")
        tid = next(self._timer_ids)
        self.transport.validate_payload(data)
        payload = copy_payload(data)
        with self._timer_cv:
            heapq.heappush(self._timers,
                           (time.monotonic() + delay, tid, src, dst,
                            eid, payload))
            self._live_tids.add(tid)
            self._pending_timers += 1
            self._timer_cv.notify_all()
        return TimerHandle(self, tid)

    def _cancel_timer(self, tid: int) -> bool:
        with self._timer_cv:
            if tid not in self._live_tids:
                return False  # already fired (or already cancelled)
            self._live_tids.discard(tid)
            self._cancelled.add(tid)
            self._pending_timers -= 1
            self._timer_cv.notify_all()
        self._poke()
        return True

    def _timer_loop(self) -> None:
        while not self._shutdown:
            with self._timer_cv:
                if self._shutdown:  # re-check under the cv: shutdown is
                    return          # flagged before its notify is sent
                if not self._timers:
                    self._timer_cv.wait()  # woken on push/cancel/shutdown
                    continue
                when, tid, src, dst, eid, data = self._timers[0]
                if tid in self._cancelled:
                    # cancellation already un-counted it; just drop the entry
                    heapq.heappop(self._timers)
                    self._cancelled.discard(tid)
                    continue
                now = time.monotonic()
                if when > now:
                    self._timer_cv.wait(when - now)
                    continue
                heapq.heappop(self._timers)
                self._live_tids.discard(tid)
            if dst == self.n_ranks:  # ALL
                for t in range(self.n_ranks):
                    self._fire_sys(src, t, eid, data)
            else:
                self._fire_sys(src, dst, eid, data)
            with self._timer_cv:
                # un-count the pending timer only after _fire_sys counted
                # the send: the detector must never observe timers == 0 with
                # the event not yet in the sent counter, or it could declare
                # termination in the gap and drop the timer event
                self._pending_timers -= 1

    # ---------------------------------------------------- failure injection
    def kill_rank(self, rank: int) -> None:
        """Simulate node failure: drop the rank and notify survivors with a
        machine-generated RANK_FAILED event (paper §VII further work)."""
        self.transport.mark_dead(rank)
        if rank in self._sched:
            self._sched[rank].stop()
        # the failure notification is machine-generated at each *survivor*
        # (the dead rank cannot send), sourced from the survivor itself
        for r in self._local_ranks:
            if r != rank and not self.transport.is_dead(r):
                self._fire_sys(r, r, RANK_FAILED, rank)
        if self._durable is not None:
            # marks replay in-flight *before* the poke below, so the
            # detector can't declare termination in the gap
            self._durable.note_rank_failed(rank)
        self._poke(force=True)  # alive-set changed under the detector

    def _on_peer_dead(self, rank: int) -> None:
        """Transport failure-detector callback (distributed): a peer process
        stopped heartbeating or its connection broke.  Mirrors
        :meth:`kill_rank` for the local ranks; every surviving process runs
        the same notification, so each alive rank sees one RANK_FAILED."""
        for r in self._local_ranks:
            if r != rank and not self.transport.is_dead(r):
                self._fire_sys(r, r, RANK_FAILED, rank)
        if self._durable is not None:
            self._durable.note_rank_failed(rank)
        if (self._distributed and rank == self._det_rank
                and self._det_rank not in self._sched):
            # the termination coordinator died: nobody will ever broadcast
            # terminate — fail this process instead of hanging to timeout
            with self._err_mu:
                if self._error is None:
                    self._error = RankDiedError(
                        f"rank {rank} (termination coordinator) failed")
            self._term_event.set()
        self._poke(force=True)

    def is_dead(self, rank: int) -> bool:
        return self.transport.is_dead(rank)

    # -------------------------------------------------------------- failure
    def _task_failed(self, rank: int, inst, exc: BaseException) -> None:
        first = False
        with self._err_mu:
            if self._error is None:
                self._error = EdatTaskError(
                    f"task {inst.name or inst.fn.__name__!r} on rank {rank} "
                    f"raised {type(exc).__name__}: {exc}")
                self._error.__cause__ = exc
                first = True
        if first and self._distributed and self._det_rank not in self._sched:
            # tell the detector process; it broadcasts terminate with the
            # error so every process exits instead of hanging to timeout
            self.transport.send(Message(CONTROL, rank, self._det_rank,
                                        ("abort", str(self._error))))
        self._poke(force=True)  # the detector returns as soon as it sees it

    def _ctx(self, rank: int) -> Context:
        return self._ctxs[rank]

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Optional[Dict[str, Any]]:
        """This process's metric snapshot: per-channel counters merged over
        the local ranks, per-rank execution totals, and the transport's
        wire-level view.  ``None`` when the runtime was built with
        ``metrics=False``.  Shape matches what
        :func:`repro.core.metrics.merge_metrics` consumes; the quorum-wait
        seconds a local consumer attributes to a *remote* rank appear under
        that remote rank's entry (merge sums them)."""
        if not self._metrics_on:
            return None
        channels: Dict[str, Dict[str, int]] = {}
        ranks: Dict[int, Dict[str, Any]] = {}
        for r, sch in self._sched.items():
            snap = sch.metrics_snapshot()
            rk = ranks.setdefault(r, {"tasks_executed": 0, "busy_s": 0.0,
                                      "quorum_wait_s": 0.0})
            rk["tasks_executed"] += snap["tasks_executed"]
            rk["busy_s"] += snap["busy_s"]
            for eid, (n, b, w) in snap["fires"].items():
                ch = channels.setdefault(
                    eid, {"fires": 0, "bytes": 0, "wire_fires": 0,
                          "deliveries": 0, "consumed": 0, "queued_max": 0})
                ch["fires"] += n
                ch["bytes"] += b
                ch["wire_fires"] += w
            for eid, (d, c, _p, qm) in snap["deliveries"].items():
                ch = channels.setdefault(
                    eid, {"fires": 0, "bytes": 0, "wire_fires": 0,
                          "deliveries": 0, "consumed": 0, "queued_max": 0})
                ch["deliveries"] += d
                ch["consumed"] += c
                ch["queued_max"] = max(ch["queued_max"], qm)
            for src, secs in snap["quorum_wait_s"].items():
                srk = ranks.setdefault(
                    src, {"tasks_executed": 0, "busy_s": 0.0,
                          "quorum_wait_s": 0.0})
                srk["quorum_wait_s"] += secs
            if self._trace_on:
                rk.setdefault("trace", []).extend(snap.get("trace", ()))
                rk["trace_dropped"] = (rk.get("trace_dropped", 0)
                                       + snap.get("trace_dropped", 0))
        tmetrics = getattr(self.transport, "metrics", None)
        transport = tmetrics() if callable(tmetrics) else {"kind": "inproc"}
        out = {"channels": channels, "ranks": ranks, "transport": transport}
        if self._durable is not None:
            out["durable"] = self._durable.snapshot()
        return out

    # ------------------------------------------------------------------ run
    def run(self, main: Callable[[Context], None],
            timeout: float = 120.0) -> Dict[str, Any]:
        """Deprecated v1 entry point — use ``edat.run(main, ranks=...)``
        or ``edat.Session`` (the v2 API), which owns runtime construction
        and teardown.  Behaviour is unchanged; a DeprecationWarning is
        emitted once per call site."""
        warn_deprecated(
            "Runtime.run is deprecated: start programs through "
            "edat.run(program, ranks=...) or edat.Session (the v2 API)")
        return self._run_internal(main, timeout=timeout)

    def _run_internal(self, main: Callable[[Context], None],
                      timeout: float = 120.0) -> Dict[str, Any]:
        """Run ``main(ctx)`` SPMD on every local rank; return when the
        paper's four termination conditions (§II.E) hold globally.
        Equivalent to ``edatInit(); main(); edatFinalise()``.  With a
        distributed transport each participating process calls ``run`` with
        the same ``main``; rank 0's process detects global termination and
        broadcasts it to the others."""
        with self._status_cv:
            self._status_replies = []

        for s in self._sched.values():
            s.start()
        if self._progress_mode == "thread":
            for r in self._local_ranks:
                t = threading.Thread(target=self._progress_loop, args=(r,),
                                     daemon=True, name=f"edat-p{r}")
                self._prog_threads.append(t)
                t.start()
        self._timer_thread = threading.Thread(target=self._timer_loop,
                                              daemon=True, name="edat-timer")
        self._timer_thread.start()

        def _main(rank: int):
            try:
                main(self._ctxs[rank])
            except Exception as e:  # noqa: BLE001
                self._task_failed(rank, type("M", (), {
                    "name": f"main[{rank}]", "fn": main})(), e)
            finally:
                self._sched[rank].set_main_done()

        for r in self._local_ranks:
            t = threading.Thread(target=_main, args=(r,), daemon=True,
                                 name=f"edat-main{r}")
            self._main_threads.append(t)
            t.start()

        try:
            if self._det_rank in self._sched or not self._distributed:
                try:
                    self._await_termination(timeout)
                except BaseException as e:
                    self._broadcast_terminate(f"{type(e).__name__}: {e}")
                    raise
                else:
                    err = self._error
                    self._broadcast_terminate(
                        None if err is None
                        else f"{type(err).__name__}: {err}")
            else:
                self._await_remote_termination(timeout)
        finally:
            self._shutdown = True
            for s in self._sched.values():
                s.stop()
            for r in self._local_ranks:
                self.transport.wake(r)
            with self._timer_cv:
                self._timer_cv.notify_all()
            for t in self._main_threads:
                t.join(5.0)
            for s in self._sched.values():
                s.join()
            self.transport.close()
            if self._durable is not None:
                # land every queued log record (sqlite readers outlive us)
                self._durable.close()
        if self._error is not None:
            raise self._error
        return self.stats

    def _broadcast_terminate(self, error: Optional[str]) -> None:
        """Rank 0 (detector) -> everyone else: the run is over (CONTROL)."""
        if not self._distributed:
            return
        payload = {"stats": dict(self.stats), "error": error}
        for r in range(self.n_ranks):
            if r not in self._sched and not self.is_dead(r):
                self.transport.send(Message(CONTROL, self._det_rank, r,
                                            ("terminate", payload)))

    def _await_remote_termination(self, timeout: float) -> None:
        """Non-detector process: block until rank 0 broadcasts terminate
        (or a local/peer failure makes waiting pointless)."""
        deadline = time.monotonic() + timeout
        while not self._term_event.wait(
                min(0.25, max(0.0, deadline - time.monotonic()))):
            if time.monotonic() >= deadline:
                if self._error is not None:
                    return  # raised by run() after cleanup
                raise TimeoutError(
                    f"rank(s) {self._local_ranks} did not receive the "
                    f"termination broadcast within {timeout}s")
        if self._remote_stats:
            self.stats.update(self._remote_stats)
        err = self._remote_error
        if err is not None and self._error is None:
            if err.startswith("EdatDeadlockError"):
                self._error = EdatDeadlockError(err)
            else:
                self._error = EdatTaskError(err)

    # ------------------------------------------------- termination detector
    def _poll_status(self) -> List[dict]:
        alive = [r for r in range(self.n_ranks) if not self.is_dead(r)]
        if self._progress_mode == "thread" or self._distributed:
            # formal poll through the transport: remote ranks answer with a
            # CONTROL status! reply; local ranks append directly.  Replies
            # carry the probe id so a late reply from a previous poll can
            # never satisfy (or pollute) this one.
            self._probe += 1
            probe = self._probe
            src = self._det_rank if self._distributed else -1
            with self._status_cv:
                self._status_replies = []
            for r in alive:
                self.transport.send(Message(CONTROL, src, r,
                                            ("status?", probe)))
            deadline = time.monotonic() + 1.0
            with self._status_cv:
                while True:
                    got = [st for st in self._status_replies
                           if st.get("probe") == probe]
                    remaining = deadline - time.monotonic()
                    if len(got) >= len(alive) or remaining <= 0:
                        return got
                    self._status_cv.wait(remaining)
        # in-proc worker-poll mode: workers may all be busy; read directly
        # (safe here because status() takes the scheduler lock)
        return [self._local_status(r) for r in alive]

    def _maybe_quiescent(self) -> bool:
        """Lock-free pre-check gating the formal status poll.  Dirty reads
        are safe here: a false positive only costs one formal poll, a false
        negative is recovered by the next poke or the backstop wait.  This
        keeps the detector off the progress threads' critical path while
        the system is busy (e.g. it never sends CONTROL traffic in the
        middle of a ping-pong exchange)."""
        s = rcv = 0
        for r in self._local_ranks:
            sch = self._sched[r]
            if not self.is_dead(r):
                if (sch._ready or sch._running or sch._resuming
                        or not sch._main_done):
                    return False
            s += sch.sent
            rcv += sch.received
        if self._pending_timers:
            return False
        dur = self._durable
        if dur is not None and dur.busy():
            # a durable replay is in flight: re-fires are imminent, so the
            # counters' balance (or imbalance) right now is meaningless
            return False
        if self._distributed:
            # only local state is readable: locally quiet is the best this
            # gate can certify — the formal CONTROL poll decides globally
            return True
        # no mailbox probe here: an undelivered user event already shows as
        # s > rcv (sent counts at fire, received at delivery), and the formal
        # poll re-checks mailboxes authoritatively — probing them here would
        # contend with the transport's hot path on every idle transition
        return s == rcv + self.transport.dropped

    def _await_termination(self, timeout: float) -> None:
        """Mattern four-counter quiescence: two consecutive stable polls with
        every rank idle and globally sent == received.  Between polls the
        detector blocks on the activity epoch (woken by idle transitions)
        instead of sleep-polling."""
        t0 = time.monotonic()
        prev: Optional[Tuple[int, int, int]] = None
        while True:
            if self._error is not None:
                return
            remaining = timeout - (time.monotonic() - t0)
            if remaining <= 0:
                raise TimeoutError(
                    f"EDAT did not terminate within {timeout}s; "
                    f"status={self._poll_status()}")
            with self._quiet_cv:
                epoch = self._epoch
            if not self._maybe_quiescent():
                prev = None
                with self._quiet_cv:
                    if self._epoch == epoch and self._error is None:
                        self._quiet_cv.wait(min(self._poll_interval,
                                                remaining))
                continue
            sts = self._poll_status()
            alive = [r for r in range(self.n_ranks) if not self.is_dead(r)]
            if len(sts) < len(alive):
                prev = None
                continue
            if self._distributed:
                # cross-process balance: per-peer transport vectors from the
                # replies, restricted to alive columns — events exchanged
                # with a failed process cancel on both sides without ever
                # reading its (unreachable) counters
                alive_set = set(alive)
                s = sum(v for x in sts
                        for j, v in enumerate(x.get("sent_to", ()))
                        if j in alive_set)
                rcv = sum(v for x in sts
                          for j, v in enumerate(x.get("recv_from", ()))
                          if j in alive_set)
                timers = sum(x["timers"] for x in sts)
                mailbox = sum(x["mailbox"] for x in sts)
            else:
                with self._timer_cv:
                    timers = self._pending_timers
                mailbox = sum(self.transport.pending(r) for r in alive)
                s = sum(x["sent"] for x in sts)
                rcv = sum(x["received"] for x in sts)
                # dead ranks: include their final counter snapshots so
                # events they exchanged before failing stay balanced
                for r in range(self.n_ranks):
                    if self.is_dead(r):
                        s += self._sched[r].sent
                        rcv += self._sched[r].received
                rcv += self.transport.dropped
            all_idle = (all(x["idle"] for x in sts)
                        and mailbox == 0 and timers == 0
                        and not (self._durable is not None
                                 and self._durable.busy()))
            if not all_idle or s != rcv:
                prev = None
                if self._distributed:
                    # the local-only quiescence gate cannot veto remote
                    # traffic, so a busy exchange would otherwise trigger a
                    # formal CONTROL poll per idle transition; damp to at
                    # most ~50 polls/s (adds <=20 ms to real termination)
                    time.sleep(0.02)
                with self._quiet_cv:
                    if self._epoch == epoch and self._error is None:
                        self._quiet_cv.wait(min(self._poll_interval,
                                                remaining))
                continue
            if prev == (s, rcv, len(alive)):
                # two consecutive stable, idle, balanced polls -> quiescent
                parked = sum(x["parked"] for x in sts)
                unmet = sum(x["unmet"] for x in sts)
                stored = sum(x["stored"] for x in sts)
                if self._distributed:
                    # scheduler counters (user-event view) of alive ranks;
                    # a dead process's counters are unreachable
                    ev_s = sum(x["sent"] for x in sts)
                    ev_r = sum(x["received"] for x in sts)
                    dropped = sum(x["dropped"] for x in sts)
                else:
                    ev_s, ev_r = s, rcv
                    dropped = self.transport.dropped
                self.stats.update(
                    events_sent=ev_s, events_received=ev_r,
                    tasks_executed=sum(x["executed"] for x in sts),
                    events_dropped=dropped,
                    unconsumed_events=stored)
                if parked or unmet:
                    raise EdatDeadlockError(
                        f"quiescent with {parked} parked task(s) and {unmet} "
                        f"transitory task(s) with unmet dependencies — the "
                        f"paper's termination conditions 1/2 can never hold")
                if stored and self._unconsumed != "ignore":
                    msg = (f"quiescent with {stored} unconsumed transitory "
                           f"event(s) (paper termination condition 4)")
                    if self._unconsumed == "error":
                        raise EdatDeadlockError(msg)
                    import warnings
                    warnings.warn(msg, stacklevel=1)
                return
            # first stable poll: confirm immediately — the counters must
            # hold identical across two polls for quiescence
            prev = (s, rcv, len(alive))
