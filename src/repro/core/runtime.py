"""EDAT runtime: ranks, progress, distributed termination, timers, failures.

``Runtime`` plays the role of the paper's library init/finalise pair
(§II, §II.E): it spawns one SPMD main thread per rank, runs progress (a
dedicated progress thread per rank, or idle-worker polling — both modes of
paper §II.F), and detects global termination with a Mattern-style
four-counter quiescence check driven through the transport itself.

Beyond-paper (but anticipated in the paper's §VII "further work"): machine
generated events — timer events (``fire_after``) and rank-failure events
(``RANK_FAILED``) — and node-failure injection used by the fault-tolerant
trainer built on top.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .event import (ALL, ANY, SELF, RANK_FAILED, SYS_PREFIX, TIMER_CANCELLED,
                    Dep, Event, copy_payload)
from .scheduler import Scheduler
from .transport import CONTROL, EVENT, InProcTransport, Message, Transport

DepLike = Union[Dep, Tuple[Any, str]]


class EdatDeadlockError(RuntimeError):
    """Raised when the system is quiescent but the paper's termination
    conditions (§II.E) cannot be met: a transitory task has unmet
    dependencies, a task is parked forever, or transitory events remain
    unconsumed.  (The paper's library would hang; we diagnose.)"""


class EdatTaskError(RuntimeError):
    """A task raised; re-raised from :meth:`Runtime.run`."""


class TimerHandle:
    def __init__(self, runtime: "Runtime", tid: int):
        self._rt = runtime
        self.tid = tid

    def cancel(self) -> bool:
        return self._rt._cancel_timer(self.tid)


class Context:
    """Per-rank public API — mirrors the paper's C API Pythonically.

    ===========================  =======================================
    paper                        here
    ===========================  =======================================
    ``edatGetRank``              ``ctx.rank``
    ``edatSubmitTask``           ``ctx.submit(fn, deps)``
    ``edatSubmitPersistentTask`` ``ctx.submit_persistent(fn, deps)``
    ``edatFireEvent``            ``ctx.fire(target, eid, data)``
    ``edatFirePersistentEvent``  ``ctx.fire(..., persistent=True)``
    ``edatWait``                 ``ctx.wait(deps)``
    ``edatRetrieveAny``          ``ctx.retrieve_any(deps)``
    ``edatLock/Unlock/TestLock`` ``ctx.lock / ctx.unlock / ctx.test_lock``
    ``EDAT_SELF/ANY/ALL``        ``edat.SELF / edat.ANY / edat.ALL``
    ``EDAT_ADDRESS``             ``ctx.fire(..., ref=True)``
    ===========================  =======================================
    """

    def __init__(self, runtime: "Runtime", rank: int):
        self._rt = runtime
        self.rank = rank
        self.n_ranks = runtime.n_ranks

    # -- tasks ---------------------------------------------------------------
    def submit(self, fn: Callable, deps: Sequence[DepLike] = (),
               name: Optional[str] = None) -> None:
        self._rt._sched[self.rank].submit(fn, _deps(deps), name, False)

    def submit_persistent(self, fn: Callable, deps: Sequence[DepLike],
                          name: Optional[str] = None) -> None:
        d = _deps(deps)
        if not d:
            raise ValueError("a persistent task needs >= 1 dependency")
        self._rt._sched[self.rank].submit(fn, d, name, True)

    def remove_task(self, name: str) -> bool:
        return self._rt._sched[self.rank].remove_task(name)

    # -- events --------------------------------------------------------------
    def fire(self, target: Any, eid: str, data: Any = None, *,
             persistent: bool = False, ref: bool = False) -> None:
        if eid.startswith(SYS_PREFIX):
            raise ValueError(f"EIDs starting with {SYS_PREFIX!r} are reserved")
        self._rt._fire(self.rank, target, eid, data,
                       persistent=persistent, ref=ref)

    def fire_after(self, delay: float, target: Any, eid: str,
                   data: Any = None) -> TimerHandle:
        """Machine-generated timer event (paper §VII further work)."""
        return self._rt._fire_after(self.rank, delay, target, eid, data)

    # -- pause / poll ----------------------------------------------------------
    def wait(self, deps: Sequence[DepLike]) -> List[Event]:
        return self._rt._sched[self.rank].wait(_deps(deps))

    def retrieve_any(self, deps: Sequence[DepLike]) -> List[Event]:
        return self._rt._sched[self.rank].retrieve_any(_deps(deps))

    # -- locks -----------------------------------------------------------------
    def lock(self, name: str) -> None:
        self._rt._sched[self.rank].lock(name)

    def unlock(self, name: str) -> None:
        self._rt._sched[self.rank].unlock(name)

    def test_lock(self, name: str) -> bool:
        return self._rt._sched[self.rank].test_lock(name)

    # -- info -------------------------------------------------------------------
    def alive_ranks(self) -> List[int]:
        return [r for r in range(self.n_ranks) if not self._rt.is_dead(r)]


def _deps(deps: Sequence[DepLike]) -> List[Dep]:
    out = []
    for d in deps:
        out.append(d if isinstance(d, Dep) else Dep(d[0], d[1]))
    return out


class Runtime:
    """An EDAT 'machine': ``n_ranks`` SPMD ranks over a pluggable transport.

    ``progress='thread'`` gives each rank a dedicated progress thread;
    ``progress='worker'`` maps progress polling onto idle workers — the two
    modes of paper §II.F.
    """

    def __init__(self, n_ranks: int, workers_per_rank: int = 1, *,
                 progress: str = "thread",
                 unconsumed: str = "error",
                 transport: Optional[Transport] = None,
                 poll_interval: float = 0.002):
        assert progress in ("thread", "worker")
        assert unconsumed in ("error", "warn", "ignore")
        self.n_ranks = n_ranks
        self.transport: InProcTransport = transport or InProcTransport(n_ranks)
        self._sched = [Scheduler(r, n_ranks, self, workers_per_rank, progress)
                       for r in range(n_ranks)]
        self._ctxs = [Context(self, r) for r in range(n_ranks)]
        self._progress_mode = progress
        self._unconsumed = unconsumed
        self._poll_interval = poll_interval
        self._prog_threads: List[threading.Thread] = []
        self._main_threads: List[threading.Thread] = []
        self._shutdown = False
        self._error: Optional[BaseException] = None
        self._err_mu = threading.Lock()
        # timers
        self._timers: List[Tuple[float, int, int, int, str, Any]] = []
        self._timer_ids = itertools.count()
        self._cancelled: set = set()
        self._timer_cv = threading.Condition()
        self._timer_thread: Optional[threading.Thread] = None
        self._pending_timers = 0
        self.stats: Dict[str, Any] = {}

    # ------------------------------------------------------------ event path
    def _fire(self, src: int, target: Any, eid: str, data: Any, *,
              persistent: bool, ref: bool) -> None:
        payload = data if ref else copy_payload(data)
        if target is ALL:
            targets = list(range(self.n_ranks))
        elif target is SELF:
            targets = [src]
        else:
            targets = [int(target)]
        sch = self._sched[src]
        for t in targets:
            ev = Event(data=payload if (ref or len(targets) == 1)
                       else copy_payload(payload),
                       source=src, eid=eid, persistent=persistent)
            with sch._mu:
                sch.sent += 1
            # a send to a dead destination is counted by the transport as
            # dropped; termination balances sent == received + dropped
            self.transport.send(Message(EVENT, src, t, ev))

    def _refire_local(self, rank: int, ev: Event) -> None:
        """Persistent event consumed -> re-fired locally (paper §IV.A)."""
        sch = self._sched[rank]
        sch.sent += 1  # caller holds sch._mu
        self.transport.send(Message(EVENT, rank, rank, ev.clone()))

    # system events bypass Context validation
    def _fire_sys(self, src: int, target: int, eid: str, data: Any) -> None:
        sch = self._sched[src]
        ev = Event(data=copy_payload(data), source=src, eid=eid)
        with sch._mu:
            sch.sent += 1
        self.transport.send(Message(EVENT, src, target, ev))

    # ------------------------------------------------------------- progress
    def _progress_loop(self, rank: int) -> None:
        while not self._shutdown and not self.transport.is_dead(rank):
            msg = self.transport.recv(rank, timeout=0.1)
            if msg is not None:
                self._handle(rank, msg)

    def _progress_poll(self, rank: int) -> bool:
        """One poll step for idle-worker progress mode.  True if progressed."""
        msg = self.transport.try_recv(rank)
        if msg is None:
            return False
        self._handle(rank, msg)
        return True

    def _handle(self, rank: int, msg: Message) -> None:
        if msg.kind == EVENT:
            self._sched[rank].deliver(msg.payload)
        elif msg.kind == CONTROL:
            tag, data = msg.payload
            if tag == "status?":
                st = self._sched[rank].status()
                st["rank"] = rank
                self._status_replies.append(st)
                with self._status_cv:
                    self._status_cv.notify_all()

    # --------------------------------------------------------------- timers
    def _fire_after(self, src: int, delay: float, target: Any, eid: str,
                    data: Any) -> TimerHandle:
        tid = next(self._timer_ids)
        payload = copy_payload(data)
        with self._timer_cv:
            heapq.heappush(self._timers,
                           (time.monotonic() + delay, tid, src,
                            self.n_ranks if target is ALL else (
                                src if target is SELF else int(target)),
                            eid, payload))
            self._pending_timers += 1
            self._timer_cv.notify_all()
        return TimerHandle(self, tid)

    def _cancel_timer(self, tid: int) -> bool:
        with self._timer_cv:
            self._cancelled.add(tid)
            self._timer_cv.notify_all()
        return True

    def _timer_loop(self) -> None:
        while not self._shutdown:
            with self._timer_cv:
                if not self._timers:
                    self._timer_cv.wait(0.05)
                    continue
                when, tid, src, dst, eid, data = self._timers[0]
                now = time.monotonic()
                if tid in self._cancelled:
                    heapq.heappop(self._timers)
                    self._cancelled.discard(tid)
                    self._pending_timers -= 1
                    continue
                if when > now:
                    self._timer_cv.wait(min(when - now, 0.05))
                    continue
                heapq.heappop(self._timers)
                self._pending_timers -= 1
            if dst == self.n_ranks:  # ALL
                for t in range(self.n_ranks):
                    self._fire_sys(src, t, eid, data)
            else:
                self._fire_sys(src, dst, eid, data)

    # ---------------------------------------------------- failure injection
    def kill_rank(self, rank: int) -> None:
        """Simulate node failure: drop the rank and notify survivors with a
        machine-generated RANK_FAILED event (paper §VII further work)."""
        self.transport.mark_dead(rank)
        self._sched[rank].stop()
        # the failure notification is machine-generated at each *survivor*
        # (the dead rank cannot send), sourced from the survivor itself
        for r in range(self.n_ranks):
            if r != rank and not self.transport.is_dead(r):
                self._fire_sys(r, r, RANK_FAILED, rank)

    def is_dead(self, rank: int) -> bool:
        return self.transport.is_dead(rank)

    # -------------------------------------------------------------- failure
    def _task_failed(self, rank: int, inst, exc: BaseException) -> None:
        with self._err_mu:
            if self._error is None:
                self._error = EdatTaskError(
                    f"task {inst.name or inst.fn.__name__!r} on rank {rank} "
                    f"raised {type(exc).__name__}: {exc}")
                self._error.__cause__ = exc

    def _ctx(self, rank: int) -> Context:
        return self._ctxs[rank]

    # ------------------------------------------------------------------ run
    def run(self, main: Callable[[Context], None],
            timeout: float = 120.0) -> Dict[str, Any]:
        """Run ``main(ctx)`` SPMD on every rank; return when the paper's four
        termination conditions (§II.E) hold globally.  Equivalent to
        ``edatInit(); main(); edatFinalise()``."""
        self._status_replies: List[dict] = []
        self._status_cv = threading.Condition()

        for s in self._sched:
            s.start()
        if self._progress_mode == "thread":
            for r in range(self.n_ranks):
                t = threading.Thread(target=self._progress_loop, args=(r,),
                                     daemon=True, name=f"edat-p{r}")
                self._prog_threads.append(t)
                t.start()
        self._timer_thread = threading.Thread(target=self._timer_loop,
                                              daemon=True, name="edat-timer")
        self._timer_thread.start()

        def _main(rank: int):
            try:
                main(self._ctxs[rank])
            except Exception as e:  # noqa: BLE001
                self._task_failed(rank, type("M", (), {
                    "name": f"main[{rank}]", "fn": main})(), e)
            finally:
                self._sched[rank].set_main_done()

        for r in range(self.n_ranks):
            t = threading.Thread(target=_main, args=(r,), daemon=True,
                                 name=f"edat-main{r}")
            self._main_threads.append(t)
            t.start()

        try:
            self._await_termination(timeout)
        finally:
            self._shutdown = True
            for s in self._sched:
                s.stop()
            for r in range(self.n_ranks):
                self.transport.wake(r)
            for t in self._main_threads:
                t.join(5.0)
            for s in self._sched:
                s.join()
        if self._error is not None:
            raise self._error
        return self.stats

    # ------------------------------------------------- termination detector
    def _poll_status(self) -> List[dict]:
        alive = [r for r in range(self.n_ranks) if not self.is_dead(r)]
        self._status_replies = []
        if self._progress_mode == "thread":
            for r in alive:
                self.transport.send(Message(CONTROL, -1, r, ("status?", None)))
            deadline = time.monotonic() + 1.0
            with self._status_cv:
                while (len(self._status_replies) < len(alive)
                       and time.monotonic() < deadline):
                    self._status_cv.wait(0.05)
            return list(self._status_replies)
        # worker-poll mode: workers may all be busy; read directly (in-proc
        # shortcut is safe here because status() takes the scheduler lock)
        return [dict(self._sched[r].status(), rank=r) for r in alive]

    def _await_termination(self, timeout: float) -> None:
        """Mattern four-counter quiescence: two consecutive stable polls with
        every rank idle and globally sent == received."""
        t0 = time.monotonic()
        prev: Optional[Tuple[int, int]] = None
        while True:
            if self._error is not None:
                return
            if time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    f"EDAT did not terminate within {timeout}s; "
                    f"status={self._poll_status()}")
            sts = self._poll_status()
            alive = [r for r in range(self.n_ranks) if not self.is_dead(r)]
            if len(sts) < len(alive):
                prev = None
                continue
            with self._timer_cv:
                timers = self._pending_timers
            mailbox = sum(self.transport.pending(r) for r in alive)
            s = sum(x["sent"] for x in sts)
            rcv = sum(x["received"] for x in sts)
            # dead ranks: include their final counter snapshots so events
            # they exchanged before failing stay balanced
            for r in range(self.n_ranks):
                if self.is_dead(r):
                    s += self._sched[r].sent
                    rcv += self._sched[r].received
            rcv += self.transport.dropped
            all_idle = all(x["idle"] for x in sts) and mailbox == 0 and timers == 0
            if not all_idle or s != rcv:
                prev = None
                time.sleep(self._poll_interval)
                continue
            if prev == (s, rcv):
                # two consecutive stable, idle, balanced polls -> quiescent
                parked = sum(x["parked"] for x in sts)
                unmet = sum(x["unmet"] for x in sts)
                stored = sum(x["stored"] for x in sts)
                self.stats.update(
                    events_sent=s, events_received=rcv,
                    tasks_executed=sum(x["executed"] for x in sts),
                    events_dropped=self.transport.dropped,
                    unconsumed_events=stored)
                if parked or unmet:
                    raise EdatDeadlockError(
                        f"quiescent with {parked} parked task(s) and {unmet} "
                        f"transitory task(s) with unmet dependencies — the "
                        f"paper's termination conditions 1/2 can never hold")
                if stored and self._unconsumed != "ignore":
                    msg = (f"quiescent with {stored} unconsumed transitory "
                           f"event(s) (paper termination condition 4)")
                    if self._unconsumed == "error":
                        raise EdatDeadlockError(msg)
                    import warnings
                    warnings.warn(msg, stacklevel=1)
                return
            prev = (s, rcv)
            time.sleep(self._poll_interval)
