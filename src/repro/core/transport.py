"""Pluggable transport layer (paper §II.F).

The paper's EDAT library ships an MPI transport behind a pluggable interface;
"other mechanisms can be easily added".  Here the reference implementation is
an in-process transport (ranks are threads with private object spaces), which
preserves the *semantics* that matter for correctness arguments:

* per-(src,dst) FIFO delivery (paper §II.B ordering guarantee),
* payloads copied at fire time (no silent shared-memory aliasing),
* message counting hooks for distributed termination (Mattern four-counter),
* sends to failed ranks are dropped (node-failure simulation).

A real multi-host deployment would implement :class:`Transport` over
``jax.distributed`` / gRPC; nothing above this layer would change.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from collections import deque
from typing import Any, Optional

# message kinds
EVENT = "event"            # user event (counted for termination)
CONTROL = "control"        # runtime control (poll / poll-reply / terminate / abort)


@dataclasses.dataclass
class Message:
    kind: str
    src: int
    dst: int
    payload: Any  # Event for kind=EVENT; (tag, data) tuple for CONTROL


class Transport(abc.ABC):
    """Abstract transport: point-to-point ordered messaging between ranks."""

    @abc.abstractmethod
    def send(self, msg: Message) -> bool:
        """Enqueue ``msg`` for delivery.  Returns False if dst is dead."""

    @abc.abstractmethod
    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        """Blocking receive for ``rank``; None on timeout/shutdown."""

    @abc.abstractmethod
    def wake(self, rank: int) -> None:
        """Wake a blocked :meth:`recv` (used at shutdown)."""


class InProcTransport(Transport):
    """Threads-as-ranks transport with per-destination FIFO mailboxes.

    Each source appends atomically in fire order, so per-(src,dst) order is
    preserved — the same guarantee the paper's MPI transport provides.
    """

    def __init__(self, n_ranks: int):
        self.n_ranks = n_ranks
        self._boxes = [deque() for _ in range(n_ranks)]
        self._cvs = [threading.Condition() for _ in range(n_ranks)]
        self._dead = [False] * n_ranks
        self._dropped = 0  # messages dropped due to dead destinations
        self._mu = threading.Lock()

    # -- failure simulation -------------------------------------------------
    def mark_dead(self, rank: int) -> None:
        with self._mu:
            self._dead[rank] = True
        with self._cvs[rank]:
            # undelivered user events die with the rank: account as dropped
            n_events = sum(1 for m in self._boxes[rank] if m.kind == EVENT)
            with self._mu:
                self._dropped += n_events
            self._boxes[rank].clear()
            self._cvs[rank].notify_all()

    def is_dead(self, rank: int) -> bool:
        return self._dead[rank]

    @property
    def dropped(self) -> int:
        return self._dropped

    # -- Transport API -------------------------------------------------------
    def send(self, msg: Message) -> bool:
        if self._dead[msg.dst]:
            with self._mu:
                self._dropped += 1
            return False
        cv = self._cvs[msg.dst]
        with cv:
            if self._dead[msg.dst]:  # re-check under the box lock
                self._dropped += 1
                return False
            self._boxes[msg.dst].append(msg)
            cv.notify()
        return True

    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        cv = self._cvs[rank]
        with cv:
            if not self._boxes[rank]:
                cv.wait(timeout)
            if self._boxes[rank]:
                return self._boxes[rank].popleft()
            return None

    def try_recv(self, rank: int) -> Optional[Message]:
        """Non-blocking receive (used by idle-worker polling mode)."""
        cv = self._cvs[rank]
        with cv:
            if self._boxes[rank]:
                return self._boxes[rank].popleft()
            return None

    def wake(self, rank: int) -> None:
        with self._cvs[rank]:
            self._cvs[rank].notify_all()

    def pending(self, rank: int) -> int:
        """Number of undelivered messages queued for ``rank``."""
        with self._cvs[rank]:
            return len(self._boxes[rank])
