"""Pluggable transport layer (paper §II.F).

The paper's EDAT library ships an MPI transport behind a pluggable interface;
"other mechanisms can be easily added".  Two transports ship here:

* :class:`InProcTransport` — ranks are threads with private object spaces in
  one process.  The reference implementation: zero-copy mailboxes, payloads
  deep-copied at fire time, ``kill_rank`` failure simulation.
* :class:`repro.net.SocketTransport` — ranks are separate OS processes
  exchanging length-prefixed pickled frames over TCP, with a heartbeat-based
  peer failure detector.  Built by :mod:`repro.net.bootstrap` and launched
  by ``python -m repro.net.launch`` / :func:`repro.net.launch_processes`.

Both preserve the semantics that the correctness arguments rely on:

* per-(src,dst) FIFO delivery (paper §II.B ordering guarantee),
* fire-and-forget payloads (copied or serialised at fire time),
* message counting hooks for distributed termination (Mattern four-counter),
* sends to failed ranks are dropped (node-failure handling).

Batching: :meth:`Transport.send_many` enqueues a whole fire-batch with one
lock (or syscall) round-trip per destination, and :meth:`Transport.drain` /
:meth:`Transport.recv_many` pop every pending message in one round-trip —
the runtime's progress path uses these so a burst of N events costs
O(destinations) round-trips, not O(N).  A minimal transport only has to
implement ``send`` / ``recv`` / ``wake``; the base class supplies working
(looping) batch defaults and inert failure/notification hooks, and the
runtime falls back to timed polling in worker-progress mode.

Coalescing: a transport may additionally *defer* the wire write — enqueue
on ``send`` and drain the queue from a writer thread that packs many
messages into one syscall (``SocketTransport``'s default, knobs
``coalesce`` / ``flush_interval`` / ``max_batch_bytes``).  Such a
transport must still snapshot each non-``owned`` payload synchronously
inside ``send`` (fire-and-forget semantics); ``Message.owned`` marks
payloads whose ownership was handed over at fire time, which may be
encoded lazily and zero-copy.  :meth:`Transport.flush` blocks until
deferred writes have reached the kernel — a no-op for synchronous
transports.

Notification: :meth:`Transport.set_notify` registers a per-rank callback
invoked after messages are enqueued (outside the mailbox lock).  In
idle-worker progress mode the runtime points it at the scheduler's condition
variable so an idle worker wakes on arrival instead of sleep-polling.

Distributed transports (``distributed = True``) additionally declare which
ranks live in this process (``local_ranks``) and keep per-peer sent/received
vectors so the termination detector can balance counters across processes
through CONTROL messages instead of shared memory.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from collections import deque
from typing import Any, Callable, List, Optional

# message kinds
EVENT = "event"            # user event (counted for termination)
CONTROL = "control"        # runtime control (poll / poll-reply / terminate / abort)


@dataclasses.dataclass
class Message:
    kind: str
    src: int
    dst: int
    payload: Any  # Event for kind=EVENT; (tag, data) tuple for CONTROL
    #: True when the firing task handed payload ownership over (``ref=True``
    #: fires, the paper's EDAT_ADDRESS): nobody mutates the payload after
    #: fire, so a serialising transport may encode it lazily and zero-copy
    #: (pickle protocol-5 out-of-band buffers) instead of snapshotting it
    #: inside ``send``.
    owned: bool = False


class Transport(abc.ABC):
    """Abstract transport: point-to-point ordered messaging between ranks."""

    #: True when ranks live in separate processes; the runtime then speaks
    #: to remote ranks exclusively through CONTROL messages.
    distributed: bool = False
    #: Ranks hosted by this process (None: all ranks are local, in-proc).
    local_ranks = None
    #: True when ``send`` serialises the message synchronously (the wire
    #: encoding *is* the fire-time snapshot): the runtime then skips the
    #: defensive deep-copy for remote-only fires.
    serializes: bool = False

    @abc.abstractmethod
    def send(self, msg: Message) -> bool:
        """Enqueue ``msg`` for delivery.  Returns False if dst is dead."""

    @abc.abstractmethod
    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        """Blocking receive for ``rank``; None on timeout/shutdown."""

    @abc.abstractmethod
    def wake(self, rank: int) -> None:
        """Wake a blocked :meth:`recv` (used at shutdown)."""

    def send_many(self, msgs: List[Message]) -> int:
        """Enqueue a batch; returns the number actually delivered.  The
        default loops over :meth:`send`; implementations should batch."""
        return sum(1 for m in msgs if self.send(m))

    def drain(self, rank: int, max_n: Optional[int] = None) -> List[Message]:
        """Pop up to ``max_n`` pending messages (all, if None) without
        blocking.  The default loops over zero-timeout :meth:`recv`;
        implementations should batch."""
        out: List[Message] = []
        while max_n is None or len(out) < max_n:
            m = self.recv(rank, timeout=0)
            if m is None:
                break
            out.append(m)
        return out

    def recv_many(self, rank: int,
                  timeout: Optional[float]) -> List[Message]:
        """Blocking batched receive: wait up to ``timeout`` for at least one
        message, then return everything pending.  The default composes one
        blocking :meth:`recv` with a :meth:`drain`; implementations should
        pop the whole mailbox in a single round-trip."""
        first = self.recv(rank, timeout)
        if first is None:
            return []
        return [first, *self.drain(rank)]

    def set_notify(self, rank: int, fn: Optional[Callable[[], None]]) -> None:
        """Register a callback invoked after message arrival for ``rank``
        (no-op by default; callback must not assume any lock is held).
        Transports that do not override this cannot wake idle workers, so
        the runtime falls back to timed polling in worker-progress mode."""

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until deferred (coalesced) sends have been handed to the
        OS, or ``timeout`` expires.  Transports that write synchronously
        inside :meth:`send` have nothing to wait for — returns True."""
        return True

    def validate_payload(self, data: Any) -> None:
        """Raise ``TypeError`` if ``data`` cannot travel on this transport.
        Called at fire time, *before* any termination counter is touched, so
        a bad payload fails in the firing task with a clear error instead of
        crashing a worker/progress thread mid-delivery.  No-op by default
        (in-proc payloads only need to be copyable)."""

    # -- failure handling (inert defaults for minimal transports) -----------
    def is_dead(self, rank: int) -> bool:
        """True if ``rank`` is known to have failed."""
        return False

    def mark_dead(self, rank: int) -> None:
        """Locally declare ``rank`` failed (failure injection / detection)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support failure injection")

    @property
    def dropped(self) -> int:
        """Messages dropped because their destination was dead."""
        return 0

    def pending(self, rank: int) -> int:
        """Undelivered messages queued for ``rank`` (0 if unknown; the
        sent/received counters still catch in-flight events)."""
        return 0

    def close(self) -> None:
        """Release transport resources (sockets, threads).  No-op default."""


class InProcTransport(Transport):
    """Threads-as-ranks transport with per-destination FIFO mailboxes.

    Each source appends atomically in fire order, so per-(src,dst) order is
    preserved — the same guarantee the paper's MPI transport provides.
    """

    def __init__(self, n_ranks: int):
        self.n_ranks = n_ranks
        self._boxes = [deque() for _ in range(n_ranks)]
        self._cvs = [threading.Condition() for _ in range(n_ranks)]
        self._dead = [False] * n_ranks
        self._notify: List[Optional[Callable[[], None]]] = [None] * n_ranks
        self._dropped = 0  # messages dropped due to dead destinations
        self._mu = threading.Lock()

    # -- failure simulation -------------------------------------------------
    def mark_dead(self, rank: int) -> None:
        with self._mu:
            self._dead[rank] = True
        with self._cvs[rank]:
            # undelivered user events die with the rank: account as dropped
            n_events = sum(1 for m in self._boxes[rank] if m.kind == EVENT)
            with self._mu:
                self._dropped += n_events
            self._boxes[rank].clear()
            self._cvs[rank].notify_all()

    def is_dead(self, rank: int) -> bool:
        return self._dead[rank]

    @property
    def dropped(self) -> int:
        return self._dropped

    # -- Transport API -------------------------------------------------------
    def set_notify(self, rank: int, fn: Optional[Callable[[], None]]) -> None:
        self._notify[rank] = fn

    def send(self, msg: Message) -> bool:
        if self._dead[msg.dst]:
            with self._mu:
                self._dropped += 1
            return False
        cv = self._cvs[msg.dst]
        with cv:
            if self._dead[msg.dst]:  # re-check under the box lock
                with self._mu:
                    self._dropped += 1
                return False
            self._boxes[msg.dst].append(msg)
            cv.notify()
        hook = self._notify[msg.dst]
        if hook is not None:
            hook()  # outside the mailbox lock: hook may take scheduler locks
        return True

    def send_many(self, msgs: List[Message]) -> int:
        delivered = 0
        by_dst: dict = {}
        for m in msgs:
            by_dst.setdefault(m.dst, []).append(m)
        for dst, ms in by_dst.items():
            if self._dead[dst]:
                with self._mu:
                    self._dropped += len(ms)
                continue
            cv = self._cvs[dst]
            with cv:
                if self._dead[dst]:
                    with self._mu:
                        self._dropped += len(ms)
                    continue
                self._boxes[dst].extend(ms)
                cv.notify()
            delivered += len(ms)
            hook = self._notify[dst]
            if hook is not None:
                hook()
        return delivered

    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        cv = self._cvs[rank]
        with cv:
            if not self._boxes[rank]:
                cv.wait(timeout)
            if self._boxes[rank]:
                return self._boxes[rank].popleft()
            return None

    def try_recv(self, rank: int) -> Optional[Message]:
        """Non-blocking single-message receive (utility; batch consumers
        use :meth:`drain`)."""
        cv = self._cvs[rank]
        with cv:
            if self._boxes[rank]:
                return self._boxes[rank].popleft()
            return None

    def recv_many(self, rank: int,
                  timeout: Optional[float]) -> List[Message]:
        """Blocking batched receive: wait up to ``timeout`` for the mailbox
        to be non-empty, then pop everything in one lock round-trip."""
        cv = self._cvs[rank]
        with cv:
            if not self._boxes[rank]:
                cv.wait(timeout)
            box = self._boxes[rank]
            if not box:
                return []
            out = list(box)
            box.clear()
            return out

    def drain(self, rank: int, max_n: Optional[int] = None) -> List[Message]:
        """Pop up to ``max_n`` pending messages (all, if None) in FIFO order
        with a single lock round-trip.  Never blocks."""
        with self._cvs[rank]:
            box = self._boxes[rank]
            if not box:
                return []
            if max_n is None or max_n >= len(box):
                out = list(box)
                box.clear()
            else:
                out = [box.popleft() for _ in range(max_n)]
            return out

    def wake(self, rank: int) -> None:
        with self._cvs[rank]:
            self._cvs[rank].notify_all()

    def pending(self, rank: int) -> int:
        """Number of undelivered messages queued for ``rank``."""
        with self._cvs[rank]:
            return len(self._boxes[rank])
