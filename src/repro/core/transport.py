"""Pluggable transport layer (paper §II.F).

The paper's EDAT library ships an MPI transport behind a pluggable interface;
"other mechanisms can be easily added".  Here the reference implementation is
an in-process transport (ranks are threads with private object spaces), which
preserves the *semantics* that matter for correctness arguments:

* per-(src,dst) FIFO delivery (paper §II.B ordering guarantee),
* payloads copied at fire time (no silent shared-memory aliasing),
* message counting hooks for distributed termination (Mattern four-counter),
* sends to failed ranks are dropped (node-failure simulation).

Batching: :meth:`Transport.send_many` enqueues a whole fire-batch with one
lock round-trip per destination, and :meth:`InProcTransport.drain` pops every
pending message in one round-trip — the runtime's progress path uses both so
a burst of N events costs O(destinations) lock acquisitions, not O(N).

Notification: :meth:`Transport.set_notify` registers a per-rank callback
invoked after messages are enqueued (outside the mailbox lock).  In
idle-worker progress mode the runtime points it at the scheduler's condition
variable so an idle worker wakes on arrival instead of sleep-polling.

A real multi-host deployment would implement :class:`Transport` over
``jax.distributed`` / gRPC; nothing above this layer would change.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from collections import deque
from typing import Any, Callable, List, Optional

# message kinds
EVENT = "event"            # user event (counted for termination)
CONTROL = "control"        # runtime control (poll / poll-reply / terminate / abort)


@dataclasses.dataclass
class Message:
    kind: str
    src: int
    dst: int
    payload: Any  # Event for kind=EVENT; (tag, data) tuple for CONTROL


class Transport(abc.ABC):
    """Abstract transport: point-to-point ordered messaging between ranks."""

    @abc.abstractmethod
    def send(self, msg: Message) -> bool:
        """Enqueue ``msg`` for delivery.  Returns False if dst is dead."""

    @abc.abstractmethod
    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        """Blocking receive for ``rank``; None on timeout/shutdown."""

    @abc.abstractmethod
    def wake(self, rank: int) -> None:
        """Wake a blocked :meth:`recv` (used at shutdown)."""

    def send_many(self, msgs: List[Message]) -> int:
        """Enqueue a batch; returns the number actually delivered.  The
        default loops over :meth:`send`; implementations should batch."""
        return sum(1 for m in msgs if self.send(m))

    def drain(self, rank: int, max_n: Optional[int] = None) -> List[Message]:
        """Pop up to ``max_n`` pending messages (all, if None) without
        blocking.  The default loops over zero-timeout :meth:`recv`;
        implementations should batch."""
        out: List[Message] = []
        while max_n is None or len(out) < max_n:
            m = self.recv(rank, timeout=0)
            if m is None:
                break
            out.append(m)
        return out

    def set_notify(self, rank: int, fn: Optional[Callable[[], None]]) -> None:
        """Register a callback invoked after message arrival for ``rank``
        (no-op by default; callback must not assume any lock is held).
        Transports that do not override this cannot wake idle workers, so
        the runtime falls back to timed polling in worker-progress mode."""


class InProcTransport(Transport):
    """Threads-as-ranks transport with per-destination FIFO mailboxes.

    Each source appends atomically in fire order, so per-(src,dst) order is
    preserved — the same guarantee the paper's MPI transport provides.
    """

    def __init__(self, n_ranks: int):
        self.n_ranks = n_ranks
        self._boxes = [deque() for _ in range(n_ranks)]
        self._cvs = [threading.Condition() for _ in range(n_ranks)]
        self._dead = [False] * n_ranks
        self._notify: List[Optional[Callable[[], None]]] = [None] * n_ranks
        self._dropped = 0  # messages dropped due to dead destinations
        self._mu = threading.Lock()

    # -- failure simulation -------------------------------------------------
    def mark_dead(self, rank: int) -> None:
        with self._mu:
            self._dead[rank] = True
        with self._cvs[rank]:
            # undelivered user events die with the rank: account as dropped
            n_events = sum(1 for m in self._boxes[rank] if m.kind == EVENT)
            with self._mu:
                self._dropped += n_events
            self._boxes[rank].clear()
            self._cvs[rank].notify_all()

    def is_dead(self, rank: int) -> bool:
        return self._dead[rank]

    @property
    def dropped(self) -> int:
        return self._dropped

    # -- Transport API -------------------------------------------------------
    def set_notify(self, rank: int, fn: Optional[Callable[[], None]]) -> None:
        self._notify[rank] = fn

    def send(self, msg: Message) -> bool:
        if self._dead[msg.dst]:
            with self._mu:
                self._dropped += 1
            return False
        cv = self._cvs[msg.dst]
        with cv:
            if self._dead[msg.dst]:  # re-check under the box lock
                with self._mu:
                    self._dropped += 1
                return False
            self._boxes[msg.dst].append(msg)
            cv.notify()
        hook = self._notify[msg.dst]
        if hook is not None:
            hook()  # outside the mailbox lock: hook may take scheduler locks
        return True

    def send_many(self, msgs: List[Message]) -> int:
        delivered = 0
        by_dst: dict = {}
        for m in msgs:
            by_dst.setdefault(m.dst, []).append(m)
        for dst, ms in by_dst.items():
            if self._dead[dst]:
                with self._mu:
                    self._dropped += len(ms)
                continue
            cv = self._cvs[dst]
            with cv:
                if self._dead[dst]:
                    with self._mu:
                        self._dropped += len(ms)
                    continue
                self._boxes[dst].extend(ms)
                cv.notify()
            delivered += len(ms)
            hook = self._notify[dst]
            if hook is not None:
                hook()
        return delivered

    def recv(self, rank: int, timeout: Optional[float]) -> Optional[Message]:
        cv = self._cvs[rank]
        with cv:
            if not self._boxes[rank]:
                cv.wait(timeout)
            if self._boxes[rank]:
                return self._boxes[rank].popleft()
            return None

    def try_recv(self, rank: int) -> Optional[Message]:
        """Non-blocking single-message receive (utility; batch consumers
        use :meth:`drain`)."""
        cv = self._cvs[rank]
        with cv:
            if self._boxes[rank]:
                return self._boxes[rank].popleft()
            return None

    def recv_many(self, rank: int,
                  timeout: Optional[float]) -> List[Message]:
        """Blocking batched receive: wait up to ``timeout`` for the mailbox
        to be non-empty, then pop everything in one lock round-trip."""
        cv = self._cvs[rank]
        with cv:
            if not self._boxes[rank]:
                cv.wait(timeout)
            box = self._boxes[rank]
            if not box:
                return []
            out = list(box)
            box.clear()
            return out

    def drain(self, rank: int, max_n: Optional[int] = None) -> List[Message]:
        """Pop up to ``max_n`` pending messages (all, if None) in FIFO order
        with a single lock round-trip.  Never blocks."""
        with self._cvs[rank]:
            box = self._boxes[rank]
            if not box:
                return []
            if max_n is None or max_n >= len(box):
                out = list(box)
                box.clear()
            else:
                out = [box.popleft() for _ in range(max_n)]
            return out

    def wake(self, rank: int) -> None:
        with self._cvs[rank]:
            self._cvs[rank].notify_all()

    def pending(self, rank: int) -> int:
        """Number of undelivered messages queued for ``rank``."""
        with self._cvs[rank]:
            return len(self._boxes[rank])
