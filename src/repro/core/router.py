"""Indexed event routing: ``(source, eid)`` -> ordered consumer lists.

The seed scheduler offered every arriving event to *all* registered
consumers in registration order — O(consumers) per delivery, quadratic for
the common many-persistent-tasks pattern (paper §IV.A).  The router keeps
two indices instead:

* an *exact* table keyed by ``(source, eid)`` for resolved deps (SELF and
  ALL are expanded before registration, paper §II.D), and
* a *wildcard* side-table keyed by ``eid`` for ANY-source deps.

Each index bucket holds consumers in registration order, so offering an
event to the merge of the two buckets (by ``reg_order``) preserves the
paper's §II.B precedence rule exactly: "a task submitted before another
task ... has a higher precedence in the consumption of events".  Within a
consumer, dependency-order delivery (§II.A) and persistent-frame refill
(§IV.A) are unchanged — the router only decides *which* consumer is offered
the event, via the same ``try_fill`` protocol the linear scan used.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

from .event import Event


class EventRouter:
    """Routes events to consumers in registration-precedence order.

    Consumers are any objects with ``deps`` (a list of expanded
    :class:`~repro.core.event.Dep`), an int ``reg_order`` assigned at
    registration, and a ``try_fill(ev) -> bool`` method.  All methods must
    be called under the owning scheduler's lock.
    """

    __slots__ = ("_exact", "_any")

    def __init__(self):
        self._exact: Dict[Tuple[int, str], List] = {}
        self._any: Dict[str, List] = {}

    def register(self, consumer) -> None:
        """Index ``consumer`` under each distinct dep key.

        Consumers must be registered in increasing ``reg_order`` so each
        bucket stays sorted by precedence (appends preserve this).
        """
        exact_keys = set()
        any_eids = set()
        for d in consumer.deps:
            if d.is_any:
                any_eids.add(d.eid)
            else:
                exact_keys.add(d.key)
        for k in exact_keys:
            self._exact.setdefault(k, []).append(consumer)
        for eid in any_eids:
            self._any.setdefault(eid, []).append(consumer)

    def unregister(self, consumer) -> None:
        """Drop ``consumer`` from every bucket it was indexed under."""
        for table, key in self._keys_of(consumer):
            bucket = table.get(key)
            if bucket is None:
                continue
            try:
                bucket.remove(consumer)
            except ValueError:
                pass
            if not bucket:
                del table[key]

    def _keys_of(self, consumer) -> Iterator[tuple]:
        seen = set()
        for d in consumer.deps:
            k = (1, d.eid) if d.is_any else (0, d.key)
            if k in seen:
                continue
            seen.add(k)
            yield (self._any, d.eid) if d.is_any else (self._exact, d.key)

    def candidates(self, source: int, eid: str) -> Iterator:
        """Consumers that could accept a ``(source, eid)`` event, merged
        from the exact and wildcard buckets by registration precedence."""
        ex = self._exact.get((source, eid))
        an = self._any.get(eid)
        if not an:
            yield from (ex or ())
            return
        if not ex:
            yield from an
            return
        i = j = 0
        while i < len(ex) and j < len(an):
            if ex[i].reg_order <= an[j].reg_order:
                yield ex[i]
                i += 1
            else:
                yield an[j]
                j += 1
        yield from ex[i:]
        yield from an[j:]

    def offer(self, ev: Event) -> Optional[object]:
        """Offer ``ev`` to candidates in precedence order; return the
        consumer that accepted it, or None (caller stores the event)."""
        for c in self.candidates(ev.source, ev.eid):
            if c.try_fill(ev):
                return c
        return None

    def stats(self) -> dict:
        return {
            "exact_keys": len(self._exact),
            "wildcard_eids": len(self._any),
        }
