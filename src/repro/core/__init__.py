"""EDAT core: event-driven asynchronous tasks (Brown, Brown & Bull, 2020).

Public API::

    from repro import edat          # or: from repro.core import *

    rt = edat.Runtime(n_ranks=2, workers_per_rank=2)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(task1)                       # no dependencies
        else:
            ctx.submit(task2, deps=[(0, "event1")])

    rt.run(main)
"""
from .event import ALL, ANY, SELF, RANK_FAILED, Dep, Event, dep
from .router import EventRouter
from .runtime import (Context, EdatDeadlockError, EdatTaskError, Runtime,
                      TimerHandle)
from .scheduler import Scheduler
from .transport import InProcTransport, Message, Transport

__all__ = [
    "ALL", "ANY", "SELF", "RANK_FAILED", "Dep", "Event", "dep",
    "Context", "Runtime", "EdatDeadlockError", "EdatTaskError", "TimerHandle",
    "Scheduler", "EventRouter", "InProcTransport", "Message", "Transport",
]
