"""EDAT core: event-driven asynchronous tasks (Brown, Brown & Bull, 2020).

Public API (v2) — one ``Session`` entry point with typed channels::

    from repro import edat

    GRAD = edat.Channel("grad", payload=dict)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(task1)                       # no dependencies
        else:
            ctx.submit(task2, deps=[(0, GRAD)])

    edat.run(main, ranks=2)                         # threads-as-ranks
    edat.run(main, ranks=4, procs=2,
             transport="socket")                    # OS processes over TCP

Structured workloads implement the ``edat.Program`` protocol
(``start(ctx)`` plus declared ``channels``) and return results through
``Session.gather()``.  The v1 idiom (``edat.Runtime(n).run(main)``)
still works but emits a DeprecationWarning — construction, bootstrap,
spawn and teardown now belong to :class:`repro.api.Session`.

This package holds the runtime itself: events/deps (:mod:`.event`),
per-rank scheduling (:mod:`.scheduler`), indexed routing
(:mod:`.router`), ranks/progress/termination/timers (:mod:`.runtime`),
the pluggable transport interface (:mod:`.transport`) and collective
patterns (:mod:`.patterns`).
"""
from .event import ALL, ANY, SELF, RANK_FAILED, Dep, Event, dep
from .router import EventRouter
from .runtime import (Context, EdatDeadlockError, EdatTaskError,
                      RankDiedError, Runtime, TaskHandle, TimerHandle)
from .scheduler import Scheduler
from .transport import InProcTransport, Message, Transport

__all__ = [
    "ALL", "ANY", "SELF", "RANK_FAILED", "Dep", "Event", "dep",
    "Context", "Runtime", "EdatDeadlockError", "EdatTaskError",
    "RankDiedError", "TaskHandle", "TimerHandle",
    "Scheduler", "EventRouter", "InProcTransport", "Message", "Transport",
]
