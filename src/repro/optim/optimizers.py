"""Optimizers: AdamW, int8-moment AdamW, Adafactor, SGD-momentum.

All states are pytrees mirroring the parameter tree, so the logical-axis
sharding rules apply unchanged (ZeRO-1/3: under fsdp rules, moments shard
over 'data' exactly like the parameters).  ``abstract_state`` builds the
ShapeDtypeStruct tree for the dry-run without allocating anything.

int8 moments (``adamw8``) store per-tensor absmax-scaled int8 m/v — a 7x
optimizer-memory cut vs fp32 Adam, which is what lets the 671B config fit
the assigned pod (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptCfg:
    name: str = "adamw"          # adamw | adamw8 | adafactor | sgdm
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    master_fp32: bool = False    # keep fp32 master copy of bf16 params


@dataclasses.dataclass
class Optimizer:
    cfg: OptCfg
    init: Callable[[Any], Any]
    abstract_state: Callable[[Any], Any]
    state_axes: Callable[[Any], Any]     # logical axes for the state tree
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]


def _lr(cfg: OptCfg, step):
    from .schedules import cosine_schedule
    return cosine_schedule(step, peak=cfg.peak_lr, warmup=cfg.warmup,
                           total=cfg.total_steps)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _clipped(cfg: OptCfg, grads):
    if cfg.clip_norm is None:
        return grads, jnp.asarray(0.0)
    g = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (g + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(
        x.dtype), grads), g


# ---------------------------------------------------------------- quantised
def _q8(x32):
    amax = jnp.max(jnp.abs(x32)) + 1e-12
    q = jnp.round(x32 / amax * 127.0).astype(jnp.int8)
    return q, amax.astype(jnp.float32)


def _dq8(q, amax):
    return q.astype(jnp.float32) * (amax / 127.0)


# ------------------------------------------------------------------- adamw
def make_optimizer(cfg: OptCfg) -> Optimizer:
    if cfg.name in ("adamw", "adamw8"):
        return _adamw(cfg, quantised=cfg.name == "adamw8")
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    if cfg.name == "sgdm":
        return _sgdm(cfg)
    raise ValueError(cfg.name)


def _adamw(cfg: OptCfg, quantised: bool) -> Optimizer:
    def init(params):
        def leaf(p):
            if quantised:
                z8 = jnp.zeros(p.shape, jnp.int8)
                sc = jnp.zeros((), jnp.float32)
                st = {"m": z8, "m_s": sc, "v": z8, "v_s": sc}
            else:
                st = {"m": jnp.zeros(p.shape, jnp.float32),
                      "v": jnp.zeros(p.shape, jnp.float32)}
            if cfg.master_fp32:
                st["master"] = p.astype(jnp.float32)
            return st
        return {"mu": jax.tree.map(leaf, params),
                "count": jnp.zeros((), jnp.int32)}

    def abstract_state(aparams):
        def leaf(p):
            if quantised:
                st = {"m": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                      "m_s": jax.ShapeDtypeStruct((), jnp.float32),
                      "v": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                      "v_s": jax.ShapeDtypeStruct((), jnp.float32)}
            else:
                st = {"m": jax.ShapeDtypeStruct(p.shape, jnp.float32),
                      "v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
            if cfg.master_fp32:
                st["master"] = jax.ShapeDtypeStruct(p.shape, jnp.float32)
            return st
        return {"mu": jax.tree.map(leaf, aparams),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    def state_axes(param_axes):
        def leaf(ax):
            if quantised:
                st = {"m": ax, "m_s": (), "v": ax, "v_s": ()}
            else:
                st = {"m": ax, "v": ax}
            if cfg.master_fp32:
                st["master"] = ax
            return st
        return {"mu": jax.tree.map(leaf, param_axes,
                                   is_leaf=lambda x: isinstance(x, tuple)),
                "count": ()}

    def update(grads, state, params, step):
        cnt = state["count"] + 1
        lr = _lr(cfg, step)
        grads, gnorm = _clipped(cfg, grads)
        b1c = 1 - cfg.b1 ** cnt.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** cnt.astype(jnp.float32)

        def leaf(g, st, p):
            g32 = g.astype(jnp.float32)
            if quantised:
                m = _dq8(st["m"], st["m_s"])
                v = _dq8(st["v"], st["v_s"])
            else:
                m, v = st["m"], st["v"]
            m = cfg.b1 * m + (1 - cfg.b1) * g32
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
            upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
            base = st["master"] if cfg.master_fp32 else p.astype(jnp.float32)
            decay = cfg.weight_decay if p.ndim >= 2 else 0.0
            new = base - lr * (upd + decay * base)
            out = {}
            if quantised:
                out["m"], out["m_s"] = _q8(m)
                out["v"], out["v_s"] = _q8(v)
            else:
                out["m"], out["v"] = m, v
            if cfg.master_fp32:
                out["master"] = new
            return new.astype(p.dtype), out

        flat = jax.tree.map(leaf, grads, state["mu"], params,
                            is_leaf=lambda x: isinstance(x, dict) and "m" in x)
        # tree.map over 3 trees with dict leaves: leaf() returned tuples
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, {"mu": new_mu, "count": cnt}, metrics

    return Optimizer(cfg, init, abstract_state, state_axes, update)


# ---------------------------------------------------------------- adafactor
def _adafactor(cfg: OptCfg) -> Optimizer:
    def _shapes(p):
        if p.ndim >= 2:
            row = p.shape[:-1]
            col = p.shape[:-2] + p.shape[-1:]
            return row, col
        return None, None

    def init(params):
        def leaf(p):
            row, col = _shapes(p)
            if row is None:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {"vr": jnp.zeros(row, jnp.float32),
                    "vc": jnp.zeros(col, jnp.float32)}
        return {"mu": jax.tree.map(leaf, params),
                "count": jnp.zeros((), jnp.int32)}

    def abstract_state(aparams):
        def leaf(p):
            row, col = _shapes(p)
            if row is None:
                return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
            return {"vr": jax.ShapeDtypeStruct(row, jnp.float32),
                    "vc": jax.ShapeDtypeStruct(col, jnp.float32)}
        return {"mu": jax.tree.map(leaf, aparams),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    def state_axes(param_axes):
        def leaf(ax):
            if len(ax) < 2:
                return {"v": ax}
            return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        return {"mu": jax.tree.map(leaf, param_axes,
                                   is_leaf=lambda x: isinstance(x, tuple)),
                "count": ()}

    def update(grads, state, params, step):
        cnt = state["count"] + 1
        lr = _lr(cfg, step)
        grads, gnorm = _clipped(cfg, grads)
        decay = 1.0 - (cnt.astype(jnp.float32)) ** -0.8

        def leaf(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + 1e-30
            if "v" in st:
                v = decay * st["v"] + (1 - decay) * g2
                upd = g32 * jax.lax.rsqrt(v + cfg.eps)
                new_st = {"v": v}
            else:
                vr = decay * st["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * st["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (vr / jnp.mean(vr, axis=-1, keepdims=True) + 1e-30)
                pre = jax.lax.rsqrt(denom)[..., None] * \
                    jax.lax.rsqrt(vc + 1e-30)[..., None, :]
                upd = g32 * pre
                new_st = {"vr": vr, "vc": vc}
            # update clipping (Adafactor RMS rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            base = p.astype(jnp.float32)
            wd = cfg.weight_decay if p.ndim >= 2 else 0.0
            new = base - lr * (upd + wd * base)
            return new.astype(p.dtype), new_st

        flat = jax.tree.map(leaf, grads, state["mu"], params,
                            is_leaf=lambda x: isinstance(x, dict) and (
                                "v" in x or "vr" in x))
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "count": cnt}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(cfg, init, abstract_state, state_axes, update)


# -------------------------------------------------------------------- sgdm
def _sgdm(cfg: OptCfg) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(
            lambda p: {"m": jnp.zeros(p.shape, jnp.float32)}, params),
            "count": jnp.zeros((), jnp.int32)}

    def abstract_state(aparams):
        return {"mu": jax.tree.map(
            lambda p: {"m": jax.ShapeDtypeStruct(p.shape, jnp.float32)},
            aparams), "count": jax.ShapeDtypeStruct((), jnp.int32)}

    def state_axes(param_axes):
        return {"mu": jax.tree.map(lambda ax: {"m": ax}, param_axes,
                                   is_leaf=lambda x: isinstance(x, tuple)),
                "count": ()}

    def update(grads, state, params, step):
        cnt = state["count"] + 1
        lr = _lr(cfg, step)
        grads, gnorm = _clipped(cfg, grads)

        def leaf(g, st, p):
            m = cfg.b1 * st["m"] + g.astype(jnp.float32)
            new = p.astype(jnp.float32) - lr * m
            return new.astype(p.dtype), {"m": m}

        flat = jax.tree.map(leaf, grads, state["mu"], params,
                            is_leaf=lambda x: isinstance(x, dict) and "m" in x)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "count": cnt}, \
            {"grad_norm": gnorm, "lr": lr}

    return Optimizer(cfg, init, abstract_state, state_axes, update)
