from .optimizers import (OptCfg, make_optimizer, Optimizer)
from .schedules import cosine_schedule, linear_warmup

__all__ = ["OptCfg", "make_optimizer", "Optimizer", "cosine_schedule",
           "linear_warmup"]
