"""LR schedules (pure functions of an int32 step)."""
import jax.numpy as jnp


def linear_warmup(step, *, peak, warmup):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup, 1))


def cosine_schedule(step, *, peak, warmup, total, floor=0.1):
    warm = jnp.minimum(1.0, (step + 1) / max(warmup, 1))
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return peak * warm * cos
