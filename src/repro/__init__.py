"""repro: EDAT-JAX — event-driven asynchronous tasks for multi-pod JAX."""
__version__ = "1.0.0"
