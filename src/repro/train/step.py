"""Step builders: train_step (remat + microbatch accumulation), prefill_step,
serve_step.  These are the functions the launcher jits/lowers; the dry-run
lowers exactly these with abstract inputs."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.sharding.ctx import constrain


def make_train_step(model, opt: Optimizer, *, microbatches: int = 1,
                    acc_dtype=jnp.float32) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    microbatches > 1: gradient accumulation via lax.scan over batch slices
    (peak activation memory divides by the accumulation factor).  Keep the
    per-microbatch batch >= the data-parallel mesh extent or the whole
    model replicates across 'data' (see EXPERIMENTS.md §Perf, deepseek).

    acc_dtype: gradient-accumulator dtype.  bfloat16 halves both the
    accumulator HBM traffic and the per-microbatch gradient reduction
    bytes, at the cost of ~3 mantissa bits across the accumulation sum."""

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (loss, _m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(acc_dtype), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (g_sum, l_sum), _ = jax.lax.scan(acc, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: (g / microbatches), g_sum)
            loss = l_sum / microbatches
            metrics = {"ce": loss}
        new_params, new_state, opt_metrics = opt.update(
            grads, opt_state, params, step)
        out = {"loss": loss, **{k: v for k, v in metrics.items()},
               **opt_metrics}
        return new_params, new_state, out

    return train_step


def make_prefill_step(model, *, max_len: Optional[int] = None) -> Callable:
    """prefill_step(params, tokens [, frontend]) -> (last_logits, cache).

    ``max_len`` overrides the cache length (default: exactly the prompt).
    The serving engine passes its decode-cache length here so a prefilled
    single-request cache has the same per-layer shapes as one batch slot
    of the decode cache and can be spliced in directly; decoding then
    continues past the prompt without reallocating."""

    def prefill_step(params, batch):
        B, S = batch["tokens"].shape
        extra = {}
        if "frame_embeds" in batch:
            extra["frame_embeds"] = batch["frame_embeds"]
        if "patch_embeds" in batch:
            extra["patch_embeds"] = batch["patch_embeds"]
        total = S + (batch.get("patch_embeds").shape[1]
                     if "patch_embeds" in batch else 0)
        caches = model.init_cache(B, max_len or total)
        return model.prefill(params, batch["tokens"], caches, **extra)

    return prefill_step


def make_serve_step(model) -> Callable:
    """serve_step(params, caches, tokens, pos) -> (next_tokens, caches).

    One decode step for the whole batch: greedy argmax next token."""

    def serve_step(params, caches, tokens, pos):
        logits, caches = model.decode_step(params, caches, tokens, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches

    return serve_step
