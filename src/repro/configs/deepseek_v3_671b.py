"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

61L d_model=7168 128H MLA d_ff(expert)=2048 vocab=129280; 1 shared + 256
routed experts, top-8, first 3 layers dense (d_ff 18432); MTP depth 1.
"""
from repro.models.config import MLACfg, ModelCfg, MoECfg
from .base import ArchSpec

CFG = ModelCfg(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, head_dim=192,   # qk head dim (nope+rope)
    pattern=("mla",), rope_theta=10000.0,
    norm="rmsnorm", mlp="gated_silu", tie_embeddings=False,
    mla=MLACfg(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128,
               v_dim=128),
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               first_dense=3, d_ff_dense=18432, router_scale=True),
    mtp_depth=1,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset({"long_500k"}),   # MLA is full attention
    microbatches={"train_4k": 32},
    published_params=671e9,
)
