"""StarCoder2-15B [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152; sliding window
4096 (sub-quadratic: long_500k runs), RoPE, biases.
"""
from repro.models.config import ModelCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab=49152,
    pattern=("local",), window=4096, rope_theta=100000.0,
    norm="layernorm", mlp="gelu", bias=True, tie_embeddings=False,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset(),                # windowed attention
    microbatches={"train_4k": 8},
    published_params=15e9,
)
