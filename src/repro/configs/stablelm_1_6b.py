"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352; partial rotary 25%.
"""
from repro.models.config import ModelCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab=100352,
    pattern=("attn",), rope_theta=10000.0, rope_fraction=0.25,
    norm="layernorm", mlp="gated_silu", tie_embeddings=False,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset({"long_500k"}),   # pure full attention
    microbatches={"train_4k": 4},
    published_params=1.64e9,
)
