"""Assigned input shapes (identical across all 10 architectures).

train_*   lowers ``train_step``; prefill_* lowers ``prefill_step``;
decode_* / long_* lower ``serve_step`` (one token, KV cache of seq_len).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq: int
    global_batch: int
    kind: str                   # train | prefill | decode
    microbatches: int = 1       # train: gradient-accumulation factor


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
