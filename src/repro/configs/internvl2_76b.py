"""InternVL2-Llama3-76B LM backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The InternViT-6B
vision frontend is a STUB: input_specs provides 256 precomputed patch
embeddings per sample, prepended to the text sequence.
"""
from repro.models.config import ModelCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256,
    pattern=("attn",), rope_theta=500000.0,
    norm="rmsnorm", mlp="gated_silu", tie_embeddings=False,
    frontend="vision", n_frontend_tokens=256,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset({"long_500k"}),   # pure full attention
    microbatches={"train_4k": 16},
    published_params=70.6e9,                # LM backbone (ViT stubbed)
)
