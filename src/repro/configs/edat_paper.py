"""The paper's OWN experiment configurations (§V, §VI), as used by the
benchmark harness — the analogue of an arch config for the runtime itself.

Scaled presets: the paper ran scale-29 Kronecker graphs on a Cray XC30 up
to 30720 cores and MONC with 16384 analytics cores; this container has one
core, so `paper` shapes are recorded for reference and `ci` shapes are what
`python -m benchmarks.run` executes by default (`--full` selects `big`).
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class BFSBench:
    scale: int                 # 2^scale vertices
    edgefactor: int
    ranks: Tuple[int, ...]
    roots: int


@dataclasses.dataclass(frozen=True)
class InsituBench:
    analytics: Tuple[int, ...]
    items_per_producer: int
    field_elems: int


BFS = {
    "ci": BFSBench(scale=12, edgefactor=16, ranks=(1, 2, 4), roots=2),
    "big": BFSBench(scale=16, edgefactor=16, ranks=(1, 2, 4, 8, 16),
                    roots=8),
    # paper §V: scale 29 (536M vertices, 8.5B edges), 1280 nodes x 24 cores
    "paper": BFSBench(scale=29, edgefactor=16,
                      ranks=(384, 768, 1536, 3072, 6144, 12288, 30720),
                      roots=64),
}

INSITU = {
    "ci": InsituBench(analytics=(1, 2, 4), items_per_producer=32,
                      field_elems=1024),
    "big": InsituBench(analytics=(1, 2, 4, 8, 16), items_per_producer=128,
                       field_elems=1024),
    # paper §VI: up to 16384 analytics cores, 1:1 with computational cores
    "paper": InsituBench(analytics=(1024, 2048, 4096, 8192, 16384),
                         items_per_producer=1024, field_elems=4096),
}
