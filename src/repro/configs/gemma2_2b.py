"""Gemma-2-2B [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4, head_dim 256) d_ff=9216 vocab=256000;
alternating local (4096) / global; attn softcap 50, final softcap 30.
"""
from repro.models.config import ModelCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256000,
    pattern=("local", "attn"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    norm="rmsnorm", norm_plus_one=True, mlp="gated_gelu",
    scale_embed=True, tie_embeddings=True,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset(),  # half the layers are windowed
    microbatches={"train_4k": 4},
    published_params=2.6e9,
)
