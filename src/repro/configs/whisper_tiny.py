"""Whisper-tiny [arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865; the audio conv
frontend is a STUB (input_specs provides precomputed frame embeddings).
Sinusoidal positions on both stacks (deviation: Whisper's decoder uses
learned positions; sinusoids let assigned 4k/32k lengths lower cleanly).
"""
from repro.models.config import ModelCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865,
    norm="layernorm", mlp="gelu", bias=True, rope=False,
    tie_embeddings=True, encdec=True, frontend="audio",
    max_target_length=32768,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset({"long_500k"}),   # full attention both stacks
    microbatches={"train_4k": 1},
    published_params=39e6,
    param_tolerance=0.35,  # conv frontend + learned positions stubbed out
)
