"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU + local
attention in a 2:1 pattern, window 2048.
"""
from repro.models.config import ModelCfg, RGLRUCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    pattern=("rglru", "rglru", "local"), window=2048,
    norm="rmsnorm", norm_plus_one=True, mlp="gated_gelu",
    scale_embed=True, tie_embeddings=True,
    rglru=RGLRUCfg(lru_width=4096, conv_size=4),
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset(),                # recurrent + windowed: long OK
    microbatches={"train_4k": 8},
    published_params=9e9,
    param_tolerance=0.35,  # dense (not block-diagonal) RG-LRU gates
)
