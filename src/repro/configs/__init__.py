"""Architecture registry: the 10 assigned configs (+ reduced smoke variants)."""
from typing import Dict

from .base import ArchSpec, reduce_cfg
from .shapes import SHAPES, ShapeCfg

from . import (deepseek_v3_671b, gemma2_2b, gemma3_1b, granite_moe_1b,
               internvl2_76b, mamba2_370m, recurrentgemma_9b, stablelm_1_6b,
               starcoder2_15b, whisper_tiny)

_MODULES = [internvl2_76b, deepseek_v3_671b, granite_moe_1b, whisper_tiny,
            mamba2_370m, recurrentgemma_9b, stablelm_1_6b, starcoder2_15b,
            gemma3_1b, gemma2_2b]

ARCHS: Dict[str, ArchSpec] = {m.SPEC.name: m.SPEC for m in _MODULES}

__all__ = ["ARCHS", "ArchSpec", "SHAPES", "ShapeCfg", "reduce_cfg"]
