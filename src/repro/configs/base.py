"""ArchSpec: a full-size config + shape applicability + reduced smoke config."""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Optional

from repro.models.config import MLACfg, ModelCfg, MoECfg, RGLRUCfg, SSMCfg


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    cfg: ModelCfg
    # shapes skipped for this arch (documented in DESIGN.md §Arch-applicability)
    skip_shapes: FrozenSet[str] = frozenset()
    # per-shape gradient-accumulation (memory control for train cells)
    microbatches: Optional[Dict[str, int]] = None
    published_params: Optional[float] = None   # total param count to assert
    param_tolerance: float = 0.08

    @property
    def name(self) -> str:
        return self.cfg.name


def reduce_cfg(cfg: ModelCfg) -> ModelCfg:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        n_layers=max(len(cfg.pattern), 2) if len(cfg.pattern) <= 3 else
        len(cfg.pattern),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.mlp == "none" else 256,
        vocab=512,
        window=min(cfg.window, 64),
        max_target_length=256,
        dtype="float32",
        remat="none",
    )
    if cfg.moe is not None:
        # capacity 8x: no token dropping in smoke tests, so prefill+decode
        # matches teacher forcing exactly
        kw["moe"] = MoECfg(
            n_experts=8, top_k=2, d_expert=64,
            n_shared=cfg.moe.n_shared,
            first_dense=min(cfg.moe.first_dense, 1),
            d_ff_dense=128, router_scale=cfg.moe.router_scale,
            capacity_factor=8.0)
    if cfg.mla is not None:
        kw["mla"] = MLACfg(q_lora=64, kv_lora=32, rope_dim=16, nope_dim=32,
                           v_dim=32)
    if cfg.ssm is not None:
        kw["ssm"] = SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16,
                           n_groups=1, chunk=32)
        kw["d_model"] = 64  # d_inner=128, 8 ssd heads
    if cfg.rglru is not None:
        kw["rglru"] = RGLRUCfg(lru_width=128, conv_size=4)
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
        kw["n_layers"] = 3  # 1 dense prefix + 2 moe
    return cfg.replace(**kw)
