"""Gemma-3-1B [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (MQA kv=1, head_dim 256) d_ff=6912 vocab=262144;
5 local (window 512, theta 10k) : 1 global (theta 1M); qk-norm; post-norms.
"""
from repro.models.config import ModelCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=512, rope_theta=1000000.0, local_rope_theta=10000.0,
    qk_norm=True, post_norms=True,
    norm="rmsnorm", norm_plus_one=True, mlp="gated_gelu",
    scale_embed=True, tie_embeddings=True,
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset(),  # local-dominant; global layers O(seq)/token
    microbatches={"train_4k": 4},
    published_params=1.0e9,
)
