"""Mamba2-370M [arXiv:2405.21060].

48L d_model=1024, attention-free SSD blocks, ssm_state=128, vocab=50280.
"""
from repro.models.config import ModelCfg, SSMCfg
from .base import ArchSpec

CFG = ModelCfg(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280,
    pattern=("ssd",), mlp="none",
    norm="rmsnorm", tie_embeddings=True,
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
               chunk=128),
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset(),                # constant-state: runs long_500k
    microbatches={"train_4k": 4},
    published_params=370e6,
)
