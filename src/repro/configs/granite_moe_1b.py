"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff(expert)=512 vocab=49155; 32 experts
top-8.
"""
from repro.models.config import ModelCfg, MoECfg
from .base import ArchSpec

CFG = ModelCfg(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    pattern=("attn",), rope_theta=10000.0,
    norm="rmsnorm", mlp="gated_silu", tie_embeddings=True,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512, n_shared=0,
               first_dense=0, router_scale=False),
)

SPEC = ArchSpec(
    cfg=CFG,
    skip_shapes=frozenset({"long_500k"}),   # full attention
    microbatches={"train_4k": 4},
    published_params=1.3e9,
)
