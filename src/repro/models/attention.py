"""Attention mixers: GQA (global / sliding-window) and DeepSeek MLA.

Training/prefill paths can dispatch to the Pallas flash kernel
(``cfg.attn_impl == 'pallas'``); decode and CPU dry-run use the XLA
reference path.  Caches carry an explicit per-slot ``pos`` array so global
caches and ring-buffered sliding-window caches share one masking rule.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import P, rms_norm, rotary, softcap
from .config import ModelCfg
from repro.sharding.ctx import constrain

NEG_INF = -2.0e38


def ref_attention(q, k, v, *, scale, q_pos, k_pos, window: Optional[int],
                  cap: Optional[float], causal: bool = True):
    """Grouped-query attention, fp32 softmax.

    q: (B, Sq, H, D); k/v: (B, Sk, KH, D); q_pos: (B, Sq); k_pos: (B, Sk).
    Masks: causal (k_pos <= q_pos), optional sliding window, and empty
    cache slots (k_pos < 0)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    g = H // KH
    qr = q.reshape(B, Sq, KH, g, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qr, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    mask = k_pos[:, None, :] >= 0
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v dim may differ (MLA)


# =============================================================== GQA mixer
def gqa_specs(cfg: ModelCfg) -> Dict[str, P]:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sp = {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.bias:
        sp["bq"] = P((H, hd), ("heads", "head_dim"), "zeros")
        sp["bk"] = P((KH, hd), ("kv_heads", "head_dim"), "zeros")
        sp["bv"] = P((KH, hd), ("kv_heads", "head_dim"), "zeros")
        sp["bo"] = P((d,), ("embed",), "zeros")
    if cfg.qk_norm:
        sp["q_norm"] = P((hd,), ("head_dim",), "zeros")
        sp["k_norm"] = P((hd,), ("head_dim",), "zeros")
    return sp


def gqa_apply(p, x, *, cfg: ModelCfg, kind: str, positions,
              cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """kind: 'attn' (global) or 'local' (window=cfg.window).

    positions: (B, S) int32 absolute positions of x's tokens.
    cache: {'k','v': (B, L, KH, D), 'pos': (B, L)} or None (training)."""
    B, S, _ = x.shape
    window = cfg.window if kind == "local" else None
    theta = cfg.local_rope_theta if kind == "local" else cfg.rope_theta

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], plus_one=True)
        k = rms_norm(k, p["k_norm"], plus_one=True)
    if cfg.rope:
        q = rotary(q, positions, theta=theta, fraction=cfg.rope_fraction)
        k = rotary(k, positions, theta=theta, fraction=cfg.rope_fraction)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.hd ** -0.5

    new_cache = None
    if cache is None:
        out = _train_attention(q, k, v, scale=scale, positions=positions,
                               window=window, cfg=cfg,
                               causal=kind != "enc")
    else:
        L = cache["k"].shape[1]
        # ring-buffer slot for window caches; append slot for global caches.
        # If the update covers >= L tokens only the last L may be written
        # (duplicate-index scatter order is undefined otherwise).
        if S >= L:
            k_w, v_w, pos_w = k[:, -L:], v[:, -L:], positions[:, -L:]
        else:
            k_w, v_w, pos_w = k, v, positions
        slot = pos_w % L                                       # (B, S')
        bidx = jnp.arange(B)[:, None]
        ck = cache["k"].at[bidx, slot].set(k_w)
        cv = cache["v"].at[bidx, slot].set(v_w)
        cpos = cache["pos"].at[bidx, slot].set(pos_w)
        out = ref_attention(q, ck, cv, scale=scale, q_pos=positions,
                            k_pos=cpos, window=window, cap=cfg.attn_softcap)
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.bias:
        out = out + p["bo"]
    return out, new_cache


CHUNKED_THRESHOLD = 8192  # use online-softmax chunking above this length


def chunked_attention(q, k, v, *, scale, window: Optional[int],
                      cap: Optional[float], causal: bool = True,
                      q_chunk: int = 2048, kv_chunk: int = 2048):
    """Online-softmax attention (flash-style) in pure jnp: O(S * chunk)
    memory instead of O(S^2).  Causal/window chunks that are fully masked
    are still computed (static loop) but stay tiny; the Pallas kernel skips
    them on TPU."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    g = H // KH
    Dv = v.shape[-1]
    nq, nk = S // q_chunk, S // kv_chunk
    qr = q.reshape(B, nq, q_chunk, KH, g, D)

    def q_block(qi, qb):
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            lg = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                            preferred_element_type=jnp.float32) * scale
            lg = softcap(lg, cap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            lg = jnp.where(mask[None, None, None], lg, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(lg, axis=-1))
            p = jnp.exp(lg - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KH, g, q_chunk, Dv), jnp.float32)
        m0 = jnp.full((B, KH, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KH, g, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, q_chunk, KH, g, Dv)

    outs = jax.lax.map(lambda qi: q_block(qi, qr[:, qi]), jnp.arange(nq))
    # (nq, B, q_chunk, KH, g, Dv) -> (B, S, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dv)
    return out.astype(v.dtype)


def _train_attention(q, k, v, *, scale, positions, window, cfg: ModelCfg,
                     causal: bool = True):
    if cfg.attn_impl == "pallas" and causal:
        from repro.kernels.flash_attention import ops as flash_ops
        if flash_ops.supported(q, k, window, cfg.attn_softcap):
            return flash_ops.flash_attention(
                q, k, v, scale=scale, causal=True, window=window,
                softcap=cfg.attn_softcap)
    S = q.shape[1]
    if S >= CHUNKED_THRESHOLD and S % 2048 == 0:
        return chunked_attention(q, k, v, scale=scale, window=window,
                                 cap=cfg.attn_softcap, causal=causal)
    return ref_attention(q, k, v, scale=scale, q_pos=positions,
                         k_pos=positions, window=window,
                         cap=cfg.attn_softcap, causal=causal)


def gqa_cache_spec(cfg: ModelCfg, kind: str, batch: int,
                   max_len: int) -> Dict[str, P]:
    L = min(cfg.window, max_len) if kind == "local" else max_len
    KH, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": P((batch, L, KH, hd), ("batch", "cache", "kv_heads", "head_dim"),
               "zeros"),
        "v": P((batch, L, KH, hd), ("batch", "cache", "kv_heads", "head_dim"),
               "zeros"),
        "pos": P((batch, L), ("batch", "cache"), "zeros", dtype=jnp.int32),
    }


def init_cache_pos(cache: dict) -> dict:
    """Empty slots are marked pos = -1 (masked out)."""
    out = dict(cache)
    out["pos"] = jnp.full_like(cache["pos"], -1)
    return out


# ================================================================ MLA mixer
def mla_specs(cfg: ModelCfg) -> Dict[str, P]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.nope_dim + m.rope_dim
    return {
        "wq_a": P((d, m.q_lora), ("embed", "q_lora")),
        "q_norm": P((m.q_lora,), ("q_lora",), "ones"),
        "wq_b": P((m.q_lora, H, qk), ("q_lora", "heads", "head_dim")),
        "wkv_a": P((d, m.kv_lora), ("embed", "kv_lora")),
        "kv_norm": P((m.kv_lora,), ("kv_lora",), "ones"),
        "wk_rope": P((d, m.rope_dim), ("embed", "head_dim")),
        "wk_b": P((m.kv_lora, H, m.nope_dim), ("kv_lora", "heads", "head_dim")),
        "wv_b": P((m.kv_lora, H, m.v_dim), ("kv_lora", "heads", "head_dim")),
        "wo": P((H, m.v_dim, d), ("heads", "head_dim", "embed")),
    }


def mla_apply(p, x, *, cfg: ModelCfg, positions,
              cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    """DeepSeek-V3 Multi-head Latent Attention.

    Cache stores only the compressed latent (kv_lora) + shared rope key —
    the paper's memory saving.  Decode uses the absorbed formulation (no
    materialised per-head K/V of length L)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    scale = (m.nope_dim + m.rope_dim) ** -0.5

    q = jnp.einsum("bsd,dl->bsl", x, p["wq_a"])
    q = rms_norm(q, p["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", q, p["wq_b"])       # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_rope = rotary(q_rope, positions, theta=cfg.rope_theta)

    c_kv = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wk_rope"])
    k_rope = rotary(k_rope[:, :, None, :], positions,
                    theta=cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"])
        v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, m.rope_dim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _train_attention(qf, k, v, scale=scale, positions=positions,
                               window=None, cfg=cfg)
        new_cache = None
    else:
        L = cache["c_kv"].shape[1]
        bidx = jnp.arange(B)[:, None]
        slot = positions % L
        cc = cache["c_kv"].at[bidx, slot].set(c_kv)
        cr = cache["k_rope"].at[bidx, slot].set(k_rope)
        cpos = cache["pos"].at[bidx, slot].set(positions)
        # absorbed: q_nope^T k_nope = (q_nope W_uk) . c_kv
        q_abs = jnp.einsum("bshk,lhk->bshl", q_nope, p["wk_b"])
        logits = (jnp.einsum("bshl,btl->bhst", q_abs, cc,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshr,btr->bhst", q_rope, cr,
                               preferred_element_type=jnp.float32)) * scale
        mask = (cpos[:, None, :] <= positions[:, :, None]) & \
               (cpos[:, None, :] >= 0)
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx_l = jnp.einsum("bhst,btl->bshl", probs.astype(cc.dtype), cc)
        out = jnp.einsum("bshl,lhk->bshk", ctx_l, p["wv_b"])
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos}

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_cache


def mla_cache_spec(cfg: ModelCfg, batch: int, max_len: int) -> Dict[str, P]:
    m = cfg.mla
    return {
        "c_kv": P((batch, max_len, m.kv_lora), ("batch", "cache", "kv_lora"),
                  "zeros"),
        "k_rope": P((batch, max_len, m.rope_dim),
                    ("batch", "cache", "head_dim"), "zeros"),
        "pos": P((batch, max_len), ("batch", "cache"), "zeros",
                 dtype=jnp.int32),
    }
