"""Mixture-of-Experts layer (DeepSeek-V3 / Granite-MoE style).

Sort-based capacity dispatch: token→expert assignments are sorted by expert
id and scattered into an (E, C, d) table with gather/scatter *indices* — no
(T, E, C) one-hot einsum, so the dispatch memory is O(E·C·d), not O(T·E·C).
Experts are sharded over the 'model' mesh axis (expert parallelism); GSPMD
turns the gathers into the dispatch all-to-alls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import P
from .config import ModelCfg
from repro.sharding.ctx import constrain


def moe_specs(cfg: ModelCfg) -> Dict[str, P]:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.n_experts
    sp = {
        "router": P((d, E), ("embed", "expert"), scale=d ** -0.5),
        "wg": P((E, d, f), ("expert", "embed", "moe_mlp")),
        "wu": P((E, d, f), ("expert", "embed", "moe_mlp")),
        "wd": P((E, f, d), ("expert", "moe_mlp", "embed")),
    }
    if m.router_scale:  # DeepSeek aux-loss-free bias
        sp["router_bias"] = P((E,), ("expert",), "zeros", dtype=jnp.float32)
    if m.n_shared:
        fs = m.d_expert * m.n_shared
        sp["shared_wg"] = P((d, fs), ("embed", "mlp"))
        sp["shared_wu"] = P((d, fs), ("embed", "mlp"))
        sp["shared_wd"] = P((fs, d), ("mlp", "embed"))
    return sp


def moe_apply(p, x, *, cfg: ModelCfg) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, p["router"],
                        preferred_element_type=jnp.float32)
    if m.router_scale:            # DeepSeek-V3: sigmoid affinity + bias
        affin = jax.nn.sigmoid(logits)
        gval, gidx = jax.lax.top_k(affin + p["router_bias"], k)
        gval = jnp.take_along_axis(affin, gidx, axis=1)
        weights = gval / (jnp.sum(gval, axis=1, keepdims=True) + 1e-20)
        probs = affin / (jnp.sum(affin, axis=-1, keepdims=True) + 1e-20)
    else:                         # Granite: softmax router
        probs = jax.nn.softmax(logits, axis=-1)
        weights, gidx = jax.lax.top_k(probs, k)
        weights = weights / (jnp.sum(weights, axis=1, keepdims=True) + 1e-20)

    # load-balance aux loss: E * sum_e f_e * p_e
    ones = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], gidx].set(1.0)
    f_e = jnp.mean(ones, axis=0) * E / k
    p_e = jnp.mean(probs, axis=0)
    aux = jnp.sum(f_e * p_e) * E / E  # = E * mean(f*p) with f normalised

    # ---- sort-based dispatch --------------------------------------------
    import math
    C = int(max(1, math.ceil(T * k / E * m.capacity_factor)))
    flat_e = gidx.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = weights.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, se, side="left")
    pos_in_e = jnp.arange(T * k) - first       # rank within expert run
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)  # E*C = drop bin

    table = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        st.astype(jnp.int32), mode="drop")
    table = table[:E * C]
    wtab = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sw, 0.0), mode="drop")[:E * C]

    xg = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)[table]
    xg = constrain(xg.reshape(E, C, d), ("expert", "capacity", "embed"))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", xg, p["wu"])
    h = constrain(h, ("expert", "capacity", "moe_mlp"))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    ye = constrain(ye, ("expert", "capacity", "embed"))

    # ---- combine ----------------------------------------------------------
    yflat = (ye.reshape(E * C, d) * wtab[:, None].astype(ye.dtype))
    out = jnp.zeros((T + 1, d), ye.dtype).at[table].add(yflat)[:T]

    if m.n_shared:
        sh = jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wu"])
        out = out + sh @ p["shared_wd"]
    return out.reshape(B, S, d), aux
