"""Decoder-only LM assembly: segments, scan-over-layers, loss, decode.

A model is a sequence of *segments*; each segment is a repeating unit of
layer descriptors scanned with stacked parameters (keeps HLO size O(unit),
compile time O(1) in depth).  Heterogeneous patterns (gemma3 5:1,
recurrentgemma 2:1, deepseek dense-prefix) are factored automatically.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba2 as m2
from . import rglru as rg
from .common import (P, abstract_tree, axes_tree, gelu, init_tree, layer_norm,
                     rms_norm, sinusoid_positions)
from .config import ModelCfg
from .moe import moe_apply, moe_specs
from repro.sharding.ctx import constrain

Desc = Tuple[str, str]  # (mixer kind, mlp kind)


def build_segments(descs: List[Desc]) -> List[Tuple[Tuple[Desc, ...], int]]:
    """Factor a layer list into (unit, repeats) segments, greedily maximising
    unit*repeats coverage (unit length <= 8)."""
    segments = []
    i, n = 0, len(descs)
    while i < n:
        best = (1, 1)
        for u in range(1, 9):
            if i + u > n:
                break
            unit = descs[i:i + u]
            r = 1
            while i + (r + 1) * u <= n and descs[i + r * u:i + (r + 1) * u] == unit:
                r += 1
            if u * r > best[0] * best[1]:
                best = (u, r)
        u, r = best
        segments.append((tuple(descs[i:i + u]), r))
        i += u * r
    return segments


# --------------------------------------------------------------- norms/mlp
def norm_specs(cfg: ModelCfg) -> Dict[str, P]:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": P((d,), ("embed",), "ones"),
                "b": P((d,), ("embed",), "zeros")}
    init = "zeros" if cfg.norm_plus_one else "ones"
    return {"w": P((d,), ("embed",), init)}


def norm_apply(p, x, cfg: ModelCfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"], plus_one=cfg.norm_plus_one)


def mlp_specs(cfg: ModelCfg) -> Dict[str, P]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("gated_silu", "gated_gelu"):
        return {"wg": P((d, f), ("embed", "mlp")),
                "wu": P((d, f), ("embed", "mlp")),
                "wd": P((f, d), ("mlp", "embed"))}
    sp = {"w1": P((d, f), ("embed", "mlp")),
          "w2": P((f, d), ("mlp", "embed"))}
    if cfg.bias:
        sp["b1"] = P((f,), ("mlp",), "zeros")
        sp["b2"] = P((d,), ("embed",), "zeros")
    return sp


def mlp_apply(p, x, cfg: ModelCfg):
    if cfg.mlp in ("gated_silu", "gated_gelu"):
        act = jax.nn.silu if cfg.mlp == "gated_silu" else gelu
        h = act(x @ p["wg"]) * (x @ p["wu"])
        h = constrain(h, ("batch", "seq", "mlp"))
        return h @ p["wd"]
    h = x @ p["w1"]
    if cfg.bias:
        h = h + p["b1"]
    h = constrain(gelu(h), ("batch", "seq", "mlp"))
    h = h @ p["w2"]
    if cfg.bias:
        h = h + p["b2"]
    return h


# ------------------------------------------------------------------ layers
MIXER_SPECS = {
    "attn": attn.gqa_specs,
    "local": attn.gqa_specs,
    "enc": attn.gqa_specs,
    "mla": attn.mla_specs,
    "ssd": m2.mamba2_specs,
    "rglru": rg.rglru_specs,
}


def layer_specs(cfg: ModelCfg, desc: Desc) -> Dict[str, Any]:
    mixer, mlp_kind = desc
    sp: Dict[str, Any] = {
        "ln1": norm_specs(cfg),
        "mix": MIXER_SPECS[mixer](cfg),
    }
    if mlp_kind != "none":  # mamba2: the block IS the layer, no FFN half
        sp["ln2"] = norm_specs(cfg)
        sp["mlp"] = moe_specs(cfg) if mlp_kind == "moe" else (
            _dense_ff_specs(cfg, mlp_kind))
    if cfg.post_norms:
        sp["ln1p"] = norm_specs(cfg)
        if mlp_kind != "none":
            sp["ln2p"] = norm_specs(cfg)
    return sp


def _dense_ff_specs(cfg: ModelCfg, mlp_kind: str):
    if mlp_kind == "dense_big" and cfg.moe is not None:
        big = cfg.replace(d_ff=cfg.moe.d_ff_dense)
        return mlp_specs(big)
    return mlp_specs(cfg)


def mixer_apply(kind: str, p, x, *, cfg, positions, cache):
    if kind in ("attn", "local", "enc"):
        return attn.gqa_apply(p, x, cfg=cfg, kind=kind, positions=positions,
                              cache=cache)
    if kind == "mla":
        return attn.mla_apply(p, x, cfg=cfg, positions=positions, cache=cache)
    if kind == "ssd":
        return m2.mamba2_apply(p, x, cfg=cfg, cache=cache)
    if kind == "rglru":
        return rg.rglru_apply(p, x, cfg=cfg, cache=cache)
    raise ValueError(kind)


def layer_apply(lp, x, *, cfg: ModelCfg, desc: Desc, positions, cache):
    mixer, mlp_kind = desc
    h = norm_apply(lp["ln1"], x, cfg)
    mix, new_cache = mixer_apply(mixer, lp["mix"], h, cfg=cfg,
                                 positions=positions, cache=cache)
    if cfg.post_norms:
        mix = norm_apply(lp["ln1p"], mix, cfg)
    x = x + mix
    x = constrain(x, ("batch", "residual_seq", "embed"))
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind == "none":
        return x, new_cache, aux
    h = norm_apply(lp["ln2"], x, cfg)
    if mlp_kind == "moe":
        out, aux = moe_apply(lp["mlp"], h, cfg=cfg)
    elif mlp_kind == "dense_big" and cfg.moe is not None:
        out = mlp_apply(lp["mlp"], h, cfg.replace(d_ff=cfg.moe.d_ff_dense))
    else:
        out = mlp_apply(lp["mlp"], h, cfg)
    if cfg.post_norms:
        out = norm_apply(lp["ln2p"], out, cfg)
    x = x + out
    return (constrain(x, ("batch", "residual_seq", "embed")),
            new_cache, aux)


def mixer_cache_spec(cfg: ModelCfg, kind: str, batch: int, max_len: int):
    if kind == "attn":
        return attn.gqa_cache_spec(cfg, "attn", batch, max_len)
    if kind == "local":
        return attn.gqa_cache_spec(cfg, "local", batch, max_len)
    if kind == "mla":
        return attn.mla_cache_spec(cfg, batch, max_len)
    if kind == "ssd":
        return m2.mamba2_cache_spec(cfg, batch)
    if kind == "rglru":
        return rg.rglru_cache_spec(cfg, batch)
    return None


# ---------------------------------------------------------------- the model
class TransformerLM:
    """Decoder-only LM (all families except enc-dec)."""

    def __init__(self, cfg: ModelCfg):
        self.cfg = cfg
        self.descs = self._descs()
        self.segments = build_segments(self.descs)

    def _descs(self) -> List[Desc]:
        cfg = self.cfg
        kinds = cfg.layer_kinds()
        descs = []
        for i, k in enumerate(kinds):
            if cfg.moe is not None:
                mlp_kind = "dense_big" if i < cfg.moe.first_dense else "moe"
            else:
                mlp_kind = cfg.mlp
            descs.append((k, mlp_kind))
        return descs

    # -- specs ---------------------------------------------------------------
    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        from .common import stack_spec
        specs: Dict[str, Any] = {
            # 1/sqrt(d) embedding init keeps tied logits ~unit variance
            # (scale_embed models multiply activations back up by sqrt(d)).
            # 'embed_tbl' (not 'embed'): the table's d-dim must NOT be
            # FSDP-sharded over 'data' — the logits contraction over a
            # data-sharded d produces a giant cross-data all-reduce of the
            # (tokens, vocab) logits every microbatch (§Perf iteration 2).
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed_tbl"),
                       "embed", scale=cfg.d_model ** -0.5),
            "final_norm": norm_specs(cfg),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P((cfg.d_model, cfg.vocab),
                                 ("embed_tbl", "vocab"))
        for si, (unit, reps) in enumerate(self.segments):
            seg: Dict[str, Any] = {}
            for ui, desc in enumerate(unit):
                ls = layer_specs(cfg, desc)
                seg[f"u{ui}"] = stack_spec(ls, reps) if reps > 1 else ls
            specs[f"seg{si}"] = seg
        if cfg.mtp_depth:
            specs["mtp"] = {
                "proj": P((2 * cfg.d_model, cfg.d_model), ("mlp", "embed")),
                "norm_h": norm_specs(cfg),
                "norm_e": norm_specs(cfg),
                "layer": layer_specs(cfg, self.descs[-1]),
            }
        return specs

    def init(self, key: jax.Array):
        return init_tree(self.param_specs(), key, _dt(self.cfg))

    def abstract_params(self):
        return abstract_tree(self.param_specs(), _dt(self.cfg))

    def param_axes(self):
        return axes_tree(self.param_specs())

    # -- forward ---------------------------------------------------------------
    def embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return x

    def _unit_body(self, unit, positions, cache_mode):
        cfg = self.cfg

        def body(x_aux, slices):
            x, aux = x_aux
            pslices, cslices = slices
            new_caches = []
            for ui, desc in enumerate(unit):
                x, nc, a = layer_apply(
                    pslices[f"u{ui}"], x, cfg=cfg, desc=desc,
                    positions=positions, cache=cslices[ui])
                new_caches.append(nc)
                aux = aux + a
            return (x, aux), new_caches
        return body

    def forward(self, params, x, *, positions, caches=None):
        """x: embedded inputs (B, S, d).  Returns (hidden, new_caches, aux).

        caches: list per segment of per-unit cache trees (stacked when the
        segment is scanned), or None for training."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for si, (unit, reps) in enumerate(self.segments):
            seg_p = params[f"seg{si}"]
            seg_c = caches[si] if caches is not None else [None] * len(unit)
            body = self._unit_body(unit, positions, caches is not None)
            if cfg.remat != "none":
                policy = (jax.checkpoint_policies.nothing_saveable
                          if cfg.remat == "full" else
                          jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
                body = jax.checkpoint(body, policy=policy,
                                      prevent_cse=reps == 1)
            if reps == 1:
                (x, aux), ncs = body((x, aux), (seg_p, seg_c))
                new_caches.append(ncs)
            else:
                (x, aux), ncs = jax.lax.scan(body, (x, aux), (seg_p, seg_c))
                new_caches.append(ncs)
        x = norm_apply(params["final_norm"], x, cfg)
        return x, (new_caches if caches is not None else None), aux

    def logits(self, params, hidden):
        cfg = self.cfg
        if cfg.tie_embeddings:
            lg = jnp.einsum("bsd,vd->bsv", hidden, params["embed"])
        else:
            lg = jnp.einsum("bsd,dv->bsv", hidden, params["lm_head"])
        from .common import softcap
        lg = softcap(lg, cfg.final_softcap)
        return constrain(lg, ("batch", "seq", "vocab"))

    # -- losses -----------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: {'tokens': (B,S) int32, 'labels': (B,S) int32, and for
        stub frontends 'patch_embeds': (B,P,d)}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self.embed(params, tokens)
        offset = 0
        if cfg.frontend == "vision":
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
            offset = pe.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        h, _, aux = self.forward(params, x, positions=positions)
        h = h[:, offset:]
        lg = self.logits(params, h)
        ce = _xent(lg, batch["labels"])
        loss = ce + 0.001 * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth:
            mtp = self._mtp_loss(params, h, tokens, batch["labels"])
            loss = loss + 0.3 * mtp
            metrics["mtp"] = mtp
        return loss, metrics

    def _mtp_loss(self, params, h, tokens, labels):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
        trunk state at t combined with the embedding of token t+1."""
        cfg = self.cfg
        mp = params["mtp"]
        h_in = norm_apply(mp["norm_h"], h[:, :-1], cfg)
        e_in = norm_apply(mp["norm_e"], self.embed(params, tokens[:, 1:]), cfg)
        x = jnp.concatenate([h_in, e_in], axis=-1) @ mp["proj"]
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        x2, _, _aux = _single_layer(self, mp["layer"], x, positions)
        lg = self.logits(params, norm_apply(params["final_norm"], x2, cfg))
        return _xent(lg[:, :-1], labels[:, 2:] if labels.shape[1] > 2
                     else labels[:, :0])

    # -- serving -----------------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        from .common import stack_spec
        segs = []
        for (unit, reps) in self.segments:
            us = []
            for desc in unit:
                cs = mixer_cache_spec(cfg, desc[0], batch, max_len)
                us.append(stack_spec(cs, reps) if reps > 1 else cs)
            segs.append(us)
        return segs

    def init_cache(self, batch: int, max_len: int):
        specs = self.cache_specs(batch, max_len)
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or _dt(self.cfg)), specs,
            is_leaf=lambda x: isinstance(x, P))
        # mark attention cache slots empty (pos = -1)
        def fix(seg):
            return [
                (dict(u, pos=jnp.full_like(u["pos"], -1))
                 if isinstance(u, dict) and "pos" in u else u)
                for u in seg
            ]
        return [fix(seg) for seg in cache]

    def abstract_cache(self, batch: int, max_len: int):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or _dt(self.cfg)),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, P))

    def prefill(self, params, tokens, caches, *, patch_embeds=None):
        """Forward over a prompt, writing caches; returns (last_logits, caches)."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        if cfg.frontend == "vision" and patch_embeds is not None:
            x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        h, caches, _ = self.forward(params, x, positions=positions,
                                    caches=caches)
        return self.logits(params, h[:, -1:]), caches

    def decode_step(self, params, caches, tokens, pos):
        """One decode step.  tokens: (B,1); pos: (B,1) absolute positions."""
        x = self.embed(params, tokens)
        h, caches, _ = self.forward(params, x, positions=pos, caches=caches)
        return self.logits(params, h), caches


def _single_layer(model: "TransformerLM", lp, x, positions):
    return layer_apply(lp, x, cfg=model.cfg, desc=model.descs[-1],
                       positions=positions, cache=None)


def _xent(logits, labels):
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def _dt(cfg: ModelCfg):
    return jnp.dtype(cfg.dtype)
