"""Encoder-decoder LM (Whisper-family backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d).  Sinusoidal positions
are used on both stacks so arbitrary assigned sequence lengths lower
cleanly (deviation from Whisper's learned decoder positions — noted in
DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import P, abstract_tree, axes_tree, init_tree, sinusoid_positions, stack_spec
from .config import ModelCfg
from .lm import _xent, mlp_apply, mlp_specs, norm_apply, norm_specs
from repro.sharding.ctx import constrain


def cross_attn_specs(cfg: ModelCfg) -> Dict[str, P]:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed")),
    }


def cross_attn_apply(p, x, enc_kv, *, cfg: ModelCfg):
    """enc_kv: (k, v) precomputed from encoder output."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    q_pos = jnp.full((B, Sq), Sk, jnp.int32)     # attend to everything
    k_pos = jnp.zeros((B, Sk), jnp.int32)
    out = attn.ref_attention(q, k, v, scale=cfg.hd ** -0.5, q_pos=q_pos,
                             k_pos=k_pos, window=None, cap=None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def enc_kv(p, enc_out):
    return (jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"]),
            jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"]))


class EncDecLM:
    """Whisper-shaped enc-dec transformer; n_layers per stack."""

    def __init__(self, cfg: ModelCfg):
        self.cfg = cfg

    # -- specs -------------------------------------------------------------
    def _enc_layer(self):
        cfg = self.cfg
        return {"ln1": norm_specs(cfg), "mix": attn.gqa_specs(cfg),
                "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}

    def _dec_layer(self):
        cfg = self.cfg
        return {"ln1": norm_specs(cfg), "self": attn.gqa_specs(cfg),
                "lnx": norm_specs(cfg), "cross": cross_attn_specs(cfg),
                "ln2": norm_specs(cfg), "mlp": mlp_specs(cfg)}

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        n = cfg.n_layers
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed_tbl"),
                       "embed", scale=cfg.d_model ** -0.5),
            "enc": stack_spec(self._enc_layer(), n),
            "enc_norm": norm_specs(cfg),
            "dec": stack_spec(self._dec_layer(), n),
            "dec_norm": norm_specs(cfg),
        }

    def init(self, key):
        return init_tree(self.param_specs(), key, jnp.dtype(self.cfg.dtype))

    def abstract_params(self):
        return abstract_tree(self.param_specs(), jnp.dtype(self.cfg.dtype))

    def param_axes(self):
        return axes_tree(self.param_specs())

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frame_embeds):
        cfg = self.cfg
        B, S, _ = frame_embeds.shape
        x = frame_embeds.astype(jnp.dtype(cfg.dtype))
        x = x + sinusoid_positions(S, cfg.d_model).astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))

        def body(x, lp):
            h = norm_apply(lp["ln1"], x, cfg)
            mix, _ = attn.gqa_apply(lp["mix"], h, cfg=cfg, kind="enc",
                                    positions=positions, cache=None)
            x = x + mix
            h = norm_apply(lp["ln2"], x, cfg)
            x = x + mlp_apply(lp["mlp"], h, cfg)
            return constrain(x, ("batch", "seq", "embed")), None

        body_fn = body
        if cfg.remat != "none":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body_fn, x, params["enc"])
        return norm_apply(params["enc_norm"], x, cfg)

    # -- decoder -------------------------------------------------------------
    def _dec_body(self, positions, use_cache):
        cfg = self.cfg

        def body(x, slices):
            lp, kv, cache = slices
            h = norm_apply(lp["ln1"], x, cfg)
            mix, nc = attn.gqa_apply(lp["self"], h, cfg=cfg, kind="attn",
                                     positions=positions, cache=cache)
            x = x + mix
            h = norm_apply(lp["lnx"], x, cfg)
            x = x + cross_attn_apply(lp["cross"], h, kv, cfg=cfg)
            h = norm_apply(lp["ln2"], x, cfg)
            x = x + mlp_apply(lp["mlp"], h, cfg)
            return constrain(x, ("batch", "seq", "embed")), nc
        return body

    def decode(self, params, tokens, enc_out, *, positions, caches=None,
               cross_kv=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        # sinusoidal absolute positions (same positions across batch)
        x = x + sinusoid_positions(cfg.max_target_length,
                                   cfg.d_model).astype(x.dtype)[positions[0]]
        if cross_kv is None:
            cross_kv = jax.vmap(
                lambda lp: enc_kv(lp["cross"], enc_out),
                in_axes=(0,))(params["dec"])

        body = self._dec_body(positions, caches is not None)
        if cfg.remat != "none" and caches is None:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        # caches=None is an empty pytree: scan carries it through untouched
        x, new_caches = jax.lax.scan(body, x,
                                     (params["dec"], cross_kv, caches))
        x = norm_apply(params["dec_norm"], x, cfg)
        lg = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return constrain(lg, ("batch", "seq", "vocab")), new_caches, cross_kv

    # -- public API (mirrors TransformerLM) ----------------------------------
    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frame_embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        lg, _, _ = self.decode(params, tokens, enc_out, positions=positions)
        ce = _xent(lg, batch["labels"])
        return ce, {"ce": ce}

    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        return stack_spec(attn.gqa_cache_spec(cfg, "attn", batch, max_len),
                          cfg.n_layers)

    def abstract_cache(self, batch: int, max_len: int):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape,
                                           s.dtype or jnp.dtype(self.cfg.dtype)),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, P))

    def init_cache(self, batch: int, max_len: int):
        c = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or jnp.dtype(self.cfg.dtype)),
            self.cache_specs(batch, max_len),
            is_leaf=lambda x: isinstance(x, P))
        c["pos"] = jnp.full_like(c["pos"], -1)
        return c

    def prefill(self, params, tokens, caches, *, frame_embeds=None):
        enc_out = self.encode(params, frame_embeds)
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        lg, caches, cross_kv = self.decode(params, tokens, enc_out,
                                           positions=positions, caches=caches)
        return lg[:, -1:], (caches, cross_kv)

    def decode_step(self, params, state, tokens, pos):
        """state = (self_caches, cross_kv) from prefill."""
        caches, cross_kv = state
        lg, caches, _ = self.decode(params, tokens, None, positions=pos,
                                    caches=caches, cross_kv=cross_kv)
        return lg, (caches, cross_kv)
