"""Model configuration — one dataclass covering all 10 assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0            # shared experts (DeepSeek-V3: 1)
    first_dense: int = 0         # leading dense layers (DeepSeek-V3: 3)
    d_ff_dense: int = 0          # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_scale: bool = False   # normalise top-k weights (DeepSeek sigmoid)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUCfg:
    lru_width: int = 0           # 0 -> d_model
    conv_size: int = 4
    # Griffin's gates are block-diagonal with `block_heads` blocks; 0 keeps
    # dense gates (baseline).  Block-diagonal removes the gate matmul's
    # contraction over the sharded width => no per-layer all-reduce.
    block_heads: int = 0


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # layer mixing pattern: repeating unit of
    #   'attn' (global), 'local' (sliding window), 'mla', 'ssd', 'rglru'
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 4096           # sliding-window size for 'local'
    local_rope_theta: float = 10000.0

    # attention details
    rope: bool = True            # Whisper: False (absolute sinusoid only)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # StableLM-2: 0.25
    qk_norm: bool = False        # Gemma-3
    attn_softcap: Optional[float] = None   # Gemma-2: 50
    final_softcap: Optional[float] = None  # Gemma-2: 30
    attn_scale: Optional[float] = None     # override 1/sqrt(head_dim)
    bias: bool = False           # StarCoder2: True

    # norms / mlp
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_plus_one: bool = False  # Gemma-family (1+w) RMSNorm
    post_norms: bool = False     # Gemma-2/3 post-attn/post-mlp norms
    mlp: str = "gated_silu"      # gated_silu | gelu | gated_gelu
    tie_embeddings: bool = True
    scale_embed: bool = False    # Gemma-family sqrt(d) embed scaling
    logit_bias: bool = False

    # sub-configs
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    rglru: Optional[RGLRUCfg] = None

    # encoder-decoder (whisper): n_layers applies to both stacks
    encdec: bool = False
    # multimodal frontends are STUBS: input_specs() provides precomputed
    # frame/patch embeddings of this many positions
    frontend: str = "none"       # none | audio | vision
    n_frontend_tokens: int = 0

    mtp_depth: int = 0           # DeepSeek-V3 multi-token prediction

    # compute knobs (not architecture): may be overridden per experiment
    dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none
    attn_impl: str = "ref"       # ref | pallas
    max_target_length: int = 8192

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer mixer kinds, length n_layers."""
        kinds = []
        i = 0
        while len(kinds) < self.n_layers:
            kinds.append(self.pattern[i % len(self.pattern)])
            i += 1
        return tuple(kinds)

    def replace(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)
