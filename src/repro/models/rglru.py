"""RecurrentGemma recurrent block: conv1d + RG-LRU (Real-Gated LRU).

The RG-LRU diagonal recurrence h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t*x_t)
is computed with an associative scan over time in fp32 (the blocked Pallas
kernel in ``repro.kernels.rglru`` mirrors the same (a, b) composition).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import P, gelu
from .config import ModelCfg

_C = 8.0  # RG-LRU temperature constant (Griffin paper)


def rglru_specs(cfg: ModelCfg) -> Dict[str, P]:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    k = cfg.rglru.conv_size
    bh = cfg.rglru.block_heads
    sp = {
        "wy": P((d, w), ("embed", "rec")),
        "wx": P((d, w), ("embed", "rec")),
        "conv_w": P((k, w), ("dconv", "rec"), scale=0.5),
        "conv_b": P((w,), ("rec",), "zeros"),
        "ba": P((w,), ("rec",), "zeros"),
        "bi": P((w,), ("rec",), "zeros"),
        "lam": P((w,), ("rec",), "ones", scale=0.65),  # Λ resonance param
        "wo": P((w, d), ("rec", "embed")),
    }
    if bh:
        # Griffin-faithful block-diagonal gates; blocks shard over 'model'
        sp["wa"] = P((bh, w // bh, w // bh), ("ssm_heads", None, None))
        sp["wi"] = P((bh, w // bh, w // bh), ("ssm_heads", None, None))
    else:
        sp["wa"] = P((w, w), ("rec", None))   # dense gates (baseline)
        sp["wi"] = P((w, w), ("rec", None))
    return sp


def _gates(p, xf, bh: int):
    """r, i gates: dense or block-diagonal (communication-free under TP)."""
    if bh:
        B, T, W = xf.shape
        xh = xf.reshape(B, T, bh, W // bh)
        r = jnp.einsum("bthw,hwv->bthv", xh,
                       p["wa"].astype(jnp.float32)).reshape(B, T, W)
        i = jnp.einsum("bthw,hwv->bthv", xh,
                       p["wi"].astype(jnp.float32)).reshape(B, T, W)
        return (jax.nn.sigmoid(r + p["ba"].astype(jnp.float32)),
                jax.nn.sigmoid(i + p["bi"].astype(jnp.float32)))
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32)
                       + p["bi"].astype(jnp.float32))
    return r, i


def _rglru_scan(x, r, i, lam, h0=None):
    """x, r, i: (B, T, W) fp32;  lam: (W,);  h0: (B, W) initial state.
    Returns h: (B, T, W)."""
    log_a = -_C * jax.nn.softplus(lam) * r              # (B,T,W) <= 0
    a = jnp.exp(log_a)
    gated = i * x
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        # h_t := h_t + (prod_{<=t} a) * h0
        h = h + a_s * h0[:, None, :]
    return h


def rglru_apply(p, x, *, cfg: ModelCfg,
                cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    B, T, _ = x.shape
    K = cfg.rglru.conv_size
    y_gate = gelu(x @ p["wy"])
    xr = x @ p["wx"]

    if cache is None or T > 1:
        pad = (jnp.zeros((B, K - 1, xr.shape[-1]), xr.dtype)
               if cache is None else cache["conv"].astype(xr.dtype))
        xp = jnp.concatenate([pad, xr], axis=1)
        conv = sum(xp[:, i:i + T] * p["conv_w"][i] for i in range(K)) \
            + p["conv_b"]
        xf = conv.astype(jnp.float32)
        r, i = _gates(p, xf, cfg.rglru.block_heads)
        h0 = None if cache is None else cache["h"]
        lam = p["lam"].astype(jnp.float32)
        if cfg.attn_impl == "pallas" and h0 is None:
            from repro.kernels.rglru import ops as rglru_ops
            if rglru_ops.supported(T, xf.shape[-1]):
                h = rglru_ops.rglru(xf, r, i, lam)
            else:
                h = _rglru_scan(xf, r, i, lam, h0=h0)
        else:
            h = _rglru_scan(xf, r, i, lam, h0=h0)
        new_cache = None if cache is None else \
            {"conv": xp[:, -(K - 1):], "h": h[:, -1]}
    else:
        xp = jnp.concatenate([cache["conv"], xr], axis=1)  # (B,K,W)
        conv = sum(xp[:, i] * p["conv_w"][i] for i in range(K)) + p["conv_b"]
        xf = conv.astype(jnp.float32)[:, None]
        r, i = _gates(p, xf, cfg.rglru.block_heads)
        log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
        a = jnp.exp(log_a)
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
        h = a * cache["h"][:, None] + b
        new_cache = {"conv": xp[:, 1:], "h": h[:, 0]}

    out = (h.astype(x.dtype) * y_gate) @ p["wo"]
    return out, new_cache


def rglru_cache_spec(cfg: ModelCfg, batch: int) -> Dict[str, P]:
    w = cfg.rglru.lru_width or cfg.d_model
    return {
        "conv": P((batch, cfg.rglru.conv_size - 1, w),
                  ("batch", "dconv", "rec"), "zeros"),
        "h": P((batch, w), ("batch", "rec"), "zeros", dtype=jnp.float32),
    }
