"""Model zoo: composable decoder-only / enc-dec transformers in pure JAX."""
from .config import MLACfg, ModelCfg, MoECfg, RGLRUCfg, SSMCfg
from .encdec import EncDecLM
from .lm import TransformerLM, build_segments


def build_model(cfg: ModelCfg):
    return EncDecLM(cfg) if cfg.encdec else TransformerLM(cfg)


__all__ = ["ModelCfg", "MoECfg", "MLACfg", "SSMCfg", "RGLRUCfg",
           "TransformerLM", "EncDecLM", "build_model", "build_segments"]
