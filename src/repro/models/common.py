"""Parameter-spec system, norms, rotary embeddings, shared layer pieces.

Parameters are plain pytrees (nested dicts) of ``jnp`` arrays.  Every leaf is
declared once as a :class:`P` spec carrying its *logical axes* (MaxText-style)
— ``sharding/rules.py`` maps logical axes onto mesh axes, and the dry-run
derives ``ShapeDtypeStruct`` + ``NamedSharding`` trees from the same specs
without ever materialising weights.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter spec: shape + logical axes + initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override (default: fan-in)
    dtype: Any = None              # default: model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _path_seed(path: str) -> int:
    return int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "big")


def init_param(spec: P, key: jax.Array, path: str, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    k = jax.random.fold_in(key, _path_seed(path))
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        std = spec.scale or 1.0
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    std = spec.scale if spec.scale is not None else fan_in ** -0.5
    return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)


def _tree_paths(tree, prefix=""):
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out[k] = _tree_paths(v, f"{prefix}/{k}")
        return out
    return prefix


def init_tree(specs, key: jax.Array, dtype) -> Any:
    """Materialise a spec tree into parameters (deterministic per path)."""
    paths = _tree_paths(specs)
    return jax.tree.map(
        lambda s, p: init_param(s, key, p, dtype), specs, paths,
        is_leaf=lambda x: isinstance(x, P))


def abstract_tree(specs, dtype) -> Any:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype), specs,
        is_leaf=lambda x: isinstance(x, P))


def axes_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, P))


def stack_spec(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked-layers dimension to every leaf (scan-over-layers)."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale,
                    s.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------- numerics
def rms_norm(x, w, *, eps=1e-6, plus_one=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (x * scale).astype(dt)


def layer_norm(x, w, b, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def softcap(x, cap: Optional[float]):
    """Gemma-2 style logit soft-capping."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def rotary(x, positions, *, theta: float = 10000.0, fraction: float = 1.0):
    """Apply RoPE to ``x`` (..., seq, heads, head_dim).

    ``fraction`` < 1 rotates only the leading slice of head_dim (StableLM)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    ang = ang[..., None, :]                                  # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1) \
        if rot < hd else out.astype(x.dtype)


def sinusoid_positions(length: int, dim: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (any length)."""
    half = dim // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    pos = np.arange(length)[:, None] * freq[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(pos), np.cos(pos)], axis=1), jnp.float32)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)
