"""Mamba-2 block: state-space duality (SSD), chunked exact computation.

Reference (pure jnp) implementation of the SSD algorithm of Dao & Gu 2024:
within a chunk the recurrence is computed as a masked quadratic form (maps
to the MXU); across chunks a cheap state recurrence carries the SSM state.
The Pallas kernel in ``repro.kernels.ssd`` mirrors this chunk structure.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import P, rms_norm
from .config import ModelCfg
from repro.sharding.ctx import constrain


def _dims(cfg: ModelCfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    d_xbc = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, d_xbc


def mamba2_specs(cfg: ModelCfg) -> Dict[str, P]:
    s, d_in, nh, d_xbc = _dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": P((d, 2 * d_in + 2 * s.n_groups * s.d_state + nh),
                     ("embed", "rec")),
        "conv_w": P((s.d_conv, d_xbc), ("dconv", "rec"), scale=0.5),
        "conv_b": P((d_xbc,), ("rec",), "zeros"),
        "a_log": P((nh,), ("ssm_heads",), "ones"),
        "dt_bias": P((nh,), ("ssm_heads",), "zeros"),
        "d_skip": P((nh,), ("ssm_heads",), "ones"),
        "norm": P((d_in,), ("rec",), "ones"),
        "out_proj": P((d_in, d), ("rec", "embed")),
    }


def ssd_reference(x, dt, a_log, b, c, *, chunk: int, init_state=None,
                  return_final_state: bool = False):
    """Chunked SSD scan (pure jnp oracle).

    x: (B, T, H, P)   values per head
    dt: (B, T, H)     softplus-discretised step
    a_log: (H,)       A = -exp(a_log)
    b, c: (B, T, G, N) input/output projections (groups broadcast to heads)
    Returns y: (B, T, H, P)  [and the final state (B,H,N,P) if requested].
    """
    B, T, H, Pd = x.shape
    G, N = b.shape[2], b.shape[3]
    nc = T // chunk
    A = -jnp.exp(a_log.astype(jnp.float32))              # (H,)
    dta = dt.astype(jnp.float32) * A                     # (B,T,H) log-decay
    rep = H // G

    xr = x.reshape(B, nc, chunk, H, Pd)
    dtr = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    da = dta.reshape(B, nc, chunk, H)
    br = jnp.repeat(b.reshape(B, nc, chunk, G, N), rep, axis=3)  # (...,H,N)
    cr = jnp.repeat(c.reshape(B, nc, chunk, G, N), rep, axis=3)

    cum = jnp.cumsum(da, axis=2)                         # (B,nc,Q,H)
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (c_i.b_j) x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask inside the exponent: exp of masked (positive) entries would be
    # inf and 0*inf => NaN gradients
    decay = jnp.exp(jnp.where(mask, seg, -1e30))
    cb = jnp.einsum("bnihd,bnjhd->bnijh", cr.astype(jnp.float32),
                    br.astype(jnp.float32))              # (B,nc,Qi,Qj,H)
    att = cb * decay * dtr[:, :, None, :, :]
    y = jnp.einsum("bnijh,bnjhp->bnihp", att, xr.astype(jnp.float32))

    # chunk-final states: S_n = sum_j exp(cum_last - cum_j) dt_j b_j x_j^T
    last = cum[:, :, -1:, :]                             # (B,nc,1,H)
    w = jnp.exp(last - cum) * dtr                        # (B,nc,Q,H)
    states = jnp.einsum("bnjh,bnjhd,bnjhp->bnhdp",
                        w, br.astype(jnp.float32), xr.astype(jnp.float32))

    # inter-chunk recurrence over nc:  S <- exp(sum da_n) S + states_n
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))           # (B,nc,H)

    def step(s, inp):
        dec, st = inp
        s = s * dec[:, :, None, None] + st
        return s, s
    init = init_state if init_state is not None else \
        jnp.zeros((B, H, N, Pd), jnp.float32)
    _, all_states = jax.lax.scan(
        step, init, (jnp.moveaxis(chunk_decay, 1, 0),
                     jnp.moveaxis(states, 1, 0)))
    prev = jnp.concatenate([init[None], all_states[:-1]], axis=0)
    prev = jnp.moveaxis(prev, 0, 1)                      # (B,nc,H,N,P)

    # inter-chunk contribution: y_i += exp(cum_i) c_i . S_prev
    y = y + jnp.einsum("bnih,bnihd,bnhdp->bnihp",
                       jnp.exp(cum), cr.astype(jnp.float32), prev)
    y = y.reshape(B, T, H, Pd)
    if return_final_state:
        return y, all_states[-1]                         # (B,H,N,P)
    return y


def _split_in(cfg: ModelCfg, zxbcdt):
    s, d_in, nh, d_xbc = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_xbc]
    dt = zxbcdt[..., d_in + d_xbc:]
    return z, xbc, dt


def _conv1d(xbc, w, b, state: Optional[jax.Array]):
    """Depthwise causal conv; state = trailing (d_conv-1) inputs or None."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (K - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)             # (B, T+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def mamba2_apply(p, x, *, cfg: ModelCfg,
                 cache: Optional[dict] = None) -> Tuple[jax.Array, Optional[dict]]:
    s, d_in, nh, d_xbc = _dims(cfg)
    B, T, _ = x.shape
    G, N, Pd = s.n_groups, s.d_state, s.head_dim

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = _split_in(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cache is None:
        xbc, _ = _conv1d(xbc, p["conv_w"], p["conv_b"], None)
        xs = xbc[..., :d_in].reshape(B, T, nh, Pd)
        b = xbc[..., d_in:d_in + G * N].reshape(B, T, G, N)
        c = xbc[..., d_in + G * N:].reshape(B, T, G, N)
        xs = constrain(xs, ("batch", "seq", "ssm_heads", None))
        if cfg.attn_impl == "pallas":
            from repro.kernels.ssd import ops as ssd_ops
            if ssd_ops.supported(T, s.chunk, Pd, N):
                y = ssd_ops.ssd(xs, dt, p["a_log"], b, c, chunk=s.chunk)
            else:
                y = ssd_reference(xs, dt, p["a_log"], b, c, chunk=s.chunk)
        else:
            pad = (-T) % s.chunk
            if pad:
                xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
                dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
                bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cp = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
                y = ssd_reference(xs_p, dtp, p["a_log"], bp, cp,
                                  chunk=s.chunk)[:, :T]
            else:
                y = ssd_reference(xs, dt, p["a_log"], b, c, chunk=s.chunk)
        new_cache = None
    elif T == 1:
        # single-token decode: O(1) state update (the SSM selling point)
        xp = jnp.concatenate([cache["conv"], xbc], axis=1)
        conv_out = sum(xp[:, i] * p["conv_w"][i]
                       for i in range(s.d_conv)) + p["conv_b"]
        xbc1 = jax.nn.silu(conv_out)[:, None]
        xs = xbc1[..., :d_in].reshape(B, nh, Pd)
        b = xbc1[..., d_in:d_in + G * N].reshape(B, G, N)
        c = xbc1[..., d_in + G * N:].reshape(B, G, N)
        rep = nh // G
        bh = jnp.repeat(b, rep, axis=1)                  # (B,H,N)
        ch = jnp.repeat(c, rep, axis=1)
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        dt1 = dt[:, 0]                                   # (B,H)
        da = jnp.exp(dt1 * A)[:, :, None, None]
        upd = (dt1[:, :, None, None] * bh[:, :, :, None]
               * xs.astype(jnp.float32)[:, :, None, :])
        state = cache["state"] * da + upd                # (B,H,N,P)
        y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), state)
        y = y[:, None]                                   # (B,1,H,P)
        xs = xs[:, None]
        new_cache = {"conv": xp[:, 1:], "state": state}
    else:
        # prefill: full-sequence compute, carrying conv/ssm state out
        xbc_raw = xbc
        xbc, _ = _conv1d(xbc, p["conv_w"], p["conv_b"], cache["conv"])
        xs = xbc[..., :d_in].reshape(B, T, nh, Pd)
        b = xbc[..., d_in:d_in + G * N].reshape(B, T, G, N)
        c = xbc[..., d_in + G * N:].reshape(B, T, G, N)
        pad = (-T) % s.chunk
        if pad:
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            xs_p, dt_p, b_p, c_p = xs, dt, b, c
        y, final_state = ssd_reference(
            xs_p, dt_p, p["a_log"], b_p, c_p, chunk=s.chunk,
            init_state=cache["state"], return_final_state=True)
        y = y[:, :T]
        conv_tail = jnp.concatenate([cache["conv"], xbc_raw],
                                    axis=1)[:, -(s.d_conv - 1):]
        new_cache = {"conv": conv_tail, "state": final_state}

    y = y + p["d_skip"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"])
    return y @ p["out_proj"], new_cache


def mamba2_cache_spec(cfg: ModelCfg, batch: int) -> Dict[str, P]:
    s, d_in, nh, d_xbc = _dims(cfg)
    return {
        "conv": P((batch, s.d_conv - 1, d_xbc), ("batch", "dconv", "rec"),
                  "zeros"),
        "state": P((batch, nh, s.d_state, s.head_dim),
                   ("batch", "ssm_heads", "state", None), "zeros",
                   dtype=jnp.float32),
    }
