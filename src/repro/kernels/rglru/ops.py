"""Jit'd wrapper for the RG-LRU kernel (+ custom_vjp via reference)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k
from .ref import rglru_ref

_INTERPRET = jax.default_backend() == "cpu"


def supported(T, W) -> bool:
    return T % 128 == 0 and W % 128 == 0


@jax.custom_vjp
def _rglru(x, r, i, lam):
    return _k.rglru_fwd(x, r, i, lam, interpret=_INTERPRET)


def _fwd(x, r, i, lam):
    return _rglru(x, r, i, lam), (x, r, i, lam)


def _bwd(res, g):
    x, r, i, lam = res
    _, vjp = jax.vjp(rglru_ref, x, r, i, lam)
    return vjp(g.astype(jnp.float32))


_rglru.defvjp(_fwd, _bwd)


def rglru(x, r, i, lam):
    return _rglru(x.astype(jnp.float32), r.astype(jnp.float32),
                  i.astype(jnp.float32), lam.astype(jnp.float32))
