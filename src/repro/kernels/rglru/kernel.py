"""Pallas TPU kernel for the RG-LRU diagonal recurrence.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),  a_t = exp(-c*sp(Λ)*r_t)

Grid = (batch, width_blocks, time_blocks); time is sequential and carries
h (one (block_w,) vector) in VMEM scratch.  Within a time block the
recurrence is a first-order scan over block_t steps of (block_w,)-wide
elementwise VPU ops — computed as a log-space blocked prefix product
(cumprod of a via cumsum of log a) so the inner loop is vectorised, not a
fori over scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params

_C = 8.0


def _rglru_kernel(x_ref, r_ref, i_ref, lam_ref, y_ref, h_ref, *,
                  block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)      # (bt, bw)
    r = r_ref[0].astype(jnp.float32)
    i = i_ref[0].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)  # (bw,)

    log_a = -_C * jax.nn.softplus(lam)[None, :] * r      # (bt, bw) <= 0
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x)

    # blocked scan in log space: A_t = prod_{<=t} a = exp(cumsum log_a)
    cum = jnp.cumsum(log_a, axis=0)                      # (bt, bw)
    A = jnp.exp(cum)
    # h_t = A_t * (h0 + sum_{j<=t} b_j / A_j)  -- numerically safe because
    # b_j/A_j = b_j * exp(-cum_j) and cum_j <= 0 could explode; instead use
    # the equivalent masked-matmul form on shifted prefixes:
    #   h_t = A_t*h0 + sum_{j<=t} exp(cum_t - cum_j) b_j
    seg = cum[:, None, :] - cum[None, :, :]              # (bt, bt, bw)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 1)
    mask = (iota_j <= iota_i)[:, :, None]
    w = jnp.exp(jnp.where(mask, seg, -1e30))             # (bt, bt, bw)
    h_series = jnp.einsum("tjw,jw->tw", w, b) + A * h_ref[...][None, :]

    h_ref[...] = h_series[-1]
    y_ref[0] = h_series.astype(y_ref.dtype)


def rglru_fwd(x, r, i, lam, *, block_t: int = 128, block_w: int = 256,
              interpret: bool = False):
    """x, r, i: (B, T, W) fp32; lam: (W,).  Returns h: (B, T, W)."""
    B, T, W = x.shape
    block_t = min(block_t, T)
    block_w = min(block_w, W)
    assert T % block_t == 0 and W % block_w == 0
    nt, nw = T // block_t, W // block_w

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((block_w,), lambda b, iw, it: (iw,)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda b, iw, it: (b, it, iw)),
        out_shape=jax.ShapeDtypeStruct((B, T, W), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, r, i, lam)
