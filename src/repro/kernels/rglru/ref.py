"""Oracle: associative-scan RG-LRU from the model."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.rglru import _rglru_scan


def rglru_ref(x, r, i, lam):
    """x, r, i: (B, T, W) fp32; lam: (W,) -> h (B, T, W)."""
    return _rglru_scan(x.astype(jnp.float32), r.astype(jnp.float32),
                       i.astype(jnp.float32), lam.astype(jnp.float32))
