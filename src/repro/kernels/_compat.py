"""Version compatibility helpers for Pallas TPU APIs.

The Mosaic compiler-params class was renamed across JAX releases
(``TPUCompilerParams`` -> ``CompilerParams``); resolve whichever this
JAX provides so the kernels run on both sides of the rename.
"""
from __future__ import annotations


def tpu_compiler_params(pltpu, **kwargs):
    """Build the TPU compiler-params object for this JAX version."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
