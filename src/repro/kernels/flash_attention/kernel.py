"""Pallas TPU flash attention (forward): online softmax, causal + sliding
window + logit softcap + GQA.

Tiling: grid = (batch, q_head, q_blocks, kv_blocks); the innermost kv axis
is sequential ('arbitrary') and accumulates into VMEM scratch (acc, m, l).
Block shapes are (block_q x head_dim) / (block_k x head_dim) — multiples of
128 on the sequence axes so the MXU sees aligned tiles.  GQA is handled in
the k/v index_map (q head h reads kv head h // group) — no materialised
broadcast.  Causal/window-skipped tiles are predicated out with pl.when so
the TPU pipeline never streams them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], block_q: int, block_k: int,
               num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # static-shape predication: is any (q, k) pair in this tile live?
    live = jnp.asarray(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= (q_start - (k_start + block_k - 1)) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, 0]                                # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale: float, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KH, S, D[v]).  Returns (B, H, S, Dv)."""
    B, H, S, D = q.shape
    KH = k.shape[1]
    Dv = v.shape[-1]
    group = H // KH
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
