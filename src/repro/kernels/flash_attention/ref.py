"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None):
    """q: (B, H, S, D); k/v: (B, KH, S, D[v]) -> (B, H, S, Dv)."""
    B, H, S, D = q.shape
    KH = k.shape[1]
    g = H // KH
    qr = q.reshape(B, KH, g, S, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(v.dtype), v)
    return out.reshape(B, H, S, v.shape[-1])
