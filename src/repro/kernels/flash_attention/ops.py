"""Jit'd public wrapper for flash attention.

Layout adapter: the model uses (B, S, H, D); the kernel uses (B, H, S, D).
Backward pass: custom_vjp recomputing with the chunked-jnp reference (the
flash forward is exact, so gradients from the reference are exact too) —
a dedicated backward kernel is future work, noted in DESIGN.md.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref

_INTERPRET = jax.default_backend() == "cpu"


def supported(q, k, window, softcap) -> bool:
    B, S, H, D = q.shape
    if S < 256 or S % 128 != 0:
        return False
    if D % 64 != 0:
        return False
    return True


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fa(q, k, v, scale, causal, window, softcap):
    # (B,S,H,D) -> (B,H,S,D)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _k.flash_attention_fwd(qt, kt, vt, scale=scale, causal=causal,
                                 window=window, softcap=softcap,
                                 interpret=_INTERPRET)
    return jnp.swapaxes(out, 1, 2)


def _fa_fwd(q, k, v, scale, causal, window, softcap):
    return _fa(q, k, v, scale, causal, window, softcap), (q, k, v)


def _fa_bwd(scale, causal, window, softcap, res, g):
    q, k, v = res

    def f(q, k, v):
        from repro.models.attention import chunked_attention, ref_attention
        B, S = q.shape[:2]
        if S >= 8192 and S % 2048 == 0:
            return chunked_attention(q, k, v, scale=scale, window=window,
                                     cap=softcap, causal=causal)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        return ref_attention(q, k, v, scale=scale, q_pos=pos, k_pos=pos,
                             window=window, cap=softcap, causal=causal)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None):
    """q: (B, S, H, D); k/v: (B, S, KH, D[v]) -> (B, S, H, Dv)."""
    return _fa(q, k, v, scale, causal, window, softcap)


def attention_ref(q, k, v, *, scale, causal=True, window=None, softcap=None):
    """(B,S,H,D)-layout oracle."""
    out = _ref.attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                             jnp.swapaxes(v, 1, 2), scale=scale,
                             causal=causal, window=window, softcap=softcap)
    return jnp.swapaxes(out, 1, 2)
