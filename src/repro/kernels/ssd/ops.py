"""Jit'd wrapper for the SSD kernel (+ custom_vjp via reference)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k
from .ref import ssd_reference

_INTERPRET = jax.default_backend() == "cpu"


def supported(T, chunk, Pd, N) -> bool:
    return T % chunk == 0 and Pd % 8 == 0 and N % 8 == 0


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd(x, dt, a_log, b, c, chunk):
    return _k.ssd_fwd(x, dt, a_log, b, c, chunk=chunk, interpret=_INTERPRET)


def _fwd(x, dt, a_log, b, c, chunk):
    return _ssd(x, dt, a_log, b, c, chunk), (x, dt, a_log, b, c)


def _bwd(chunk, res, g):
    x, dt, a_log, b, c = res

    def f(x, dt, a_log, b, c):
        return ssd_reference(x, dt, a_log, b, c, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, a_log, b, c)
    return vjp(g.astype(jnp.float32))


_ssd.defvjp(_fwd, _bwd)


def ssd(x, dt, a_log, b, c, *, chunk: int = 128):
    """x: (B,T,H,P); dt: (B,T,H); a_log: (H,); b,c: (B,T,G,N).

    Broadcasts groups to heads then runs the kernel."""
    H = x.shape[2]
    G = b.shape[2]
    if G != H:
        rep = H // G
        b = jnp.repeat(b, rep, axis=2)
        c = jnp.repeat(c, rep, axis=2)
    out = _ssd(x, dt.astype(jnp.float32), a_log, b, c, chunk)
    return out.astype(jnp.float32)
