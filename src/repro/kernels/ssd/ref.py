"""Oracle: re-export the model's pure-jnp chunked SSD."""
from repro.models.mamba2 import ssd_reference  # noqa: F401
