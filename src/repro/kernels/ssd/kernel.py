"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid = (batch, heads, chunks); the chunk axis is sequential ('arbitrary')
and carries the (d_state x head_dim) SSM state in VMEM scratch.  Within a
chunk everything is dense (Q x Q attention-like quadratic + two (Q x N) x
(N x P) matmuls), so the MXU does the heavy lifting; chunk=128 aligns the
tiles.  This mirrors ``ref.ssd_reference`` exactly (same masking-in-log-
space trick to avoid masked-inf gradients).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))    # scalar
    b = b_ref[0, :, 0].astype(jnp.float32)           # (Q, N)
    c = c_ref[0, :, 0].astype(jnp.float32)           # (Q, N)

    da = dt * a                                      # (Q,) log decay
    cum = jnp.cumsum(da)                             # (Q,)

    # intra-chunk quadratic: y_i += sum_{j<=i} e^{cum_i-cum_j} dt_j (c_i.b_j) x_j
    seg = cum[:, None] - cum[None, :]                # (Q, Q)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = iota_j <= iota_i
    decay = jnp.exp(jnp.where(mask, seg, NEG_INF))
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: y_i += e^{cum_i} c_i . S_prev
    s_prev = state_ref[...]                          # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, s_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S = e^{sum da} S_prev + sum_j e^{cum_last-cum_j} dt_j b_j x_j^T
    w = jnp.exp(cum[-1] - cum) * dt                  # (Q,)
    local = jax.lax.dot_general(b * w[:, None], x,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = jnp.exp(jnp.sum(da)) * s_prev + local

    y_ref[0, :, 0] = y.astype(y_ref.dtype)


def ssd_fwd(x, dt, a_log, b, c, *, chunk: int = 128,
            interpret: bool = False):
    """x: (B, T, H, P); dt: (B, T, H); a_log: (H,); b, c: (B, T, H, N)
    (groups already broadcast to heads).  T % chunk == 0."""
    B, T, H, Pd = x.shape
    N = b.shape[-1]
    assert T % chunk == 0
    nc = T // chunk

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, Pd), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, N), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda ib, ih, ic: (ib, ic, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, Pd),
                               lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, Pd), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b, c)
