"""Pallas TPU kernels (validated on CPU via interpret=True).

Each kernel package: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper + custom_vjp), ref.py (pure-jnp oracle).
"""
