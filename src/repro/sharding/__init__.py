from .rules import (DEFAULT_RULES, fsdp_rules, serve_rules, sp_rules,
                    resolve, tree_shardings, with_updates)
from .ctx import use_sharding, constrain, current
