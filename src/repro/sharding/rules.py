"""Logical-axis → mesh-axis rules (MaxText-style), with divisibility guards.

Every parameter/activation dimension carries a *logical* axis name; a rule
set maps logical names to mesh axes.  ``resolve`` drops a mapping whenever
the dimension is not divisible by the mesh-axis extent (e.g. 4 query heads
cannot shard over a 16-way 'model' axis — gemma3-1b), so every config lowers
on every mesh, and the roofline table shows the cost of the fallback.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]

# Baseline rule set: DP over (pod, data), TP/EP over model.
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    "residual_seq": None,   # Megatron-SP: 'model' shards the residual seq
    "cache": None,
    "embed": None,
    "embed_tbl": None,   # embedding-table d-dim: never FSDP-shard (§Perf)
    "mlp": "model",
    "moe_mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "q_lora": None,
    "kv_lora": None,
    "rec": "model",        # RG-LRU width / mamba d_inner
    "ssm_heads": "model",
    "state": None,
    "groups": None,
    "dconv": None,
    "capacity": None,
}


def with_updates(base: Dict[str, AxisVal], **kw) -> Dict[str, AxisVal]:
    out = dict(base)
    out.update(kw)
    return out


# FSDP: additionally shard the 'embed' dimension of parameters over 'data'
# (ZeRO-3 via GSPMD: XLA all-gathers per layer inside the step).
def fsdp_rules(base: Dict[str, AxisVal] = None) -> Dict[str, AxisVal]:
    return with_updates(base or DEFAULT_RULES, embed="data")


# Sequence-parallel rules for long-context cells: shard the KV-cache length
# (and activation seq) over 'data'; batch stays on 'pod' only.
def sp_rules(base: Dict[str, AxisVal] = None) -> Dict[str, AxisVal]:
    return with_updates(base or DEFAULT_RULES,
                        batch=("pod",), seq="data", cache="data")


# Megatron-style sequence parallelism for training: the residual stream is
# sharded over 'model' on the sequence axis between blocks, so each
# TP partial-sum all-reduce becomes reduce-scatter(+all-gather before the
# next projection) — ~2x less wire than AR of the full activation (and the
# f32-partial AR that XLA emits becomes RS(f32)+AG(bf16): ~2.7x).
def tp_sp_rules(base: Dict[str, AxisVal] = None) -> Dict[str, AxisVal]:
    return with_updates(base or fsdp_rules(), residual_seq="model")


# Serving rules: experts spread over BOTH axes (256 experts / 256 chips),
# MLA latent dim TP-sharded; weights otherwise replicated over 'data' for
# gather-free decode.
def serve_rules(base: Dict[str, AxisVal] = None) -> Dict[str, AxisVal]:
    return with_updates(base or DEFAULT_RULES,
                        expert=("data", "model"), kv_lora="model")


def _axis_size(mesh: Mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.axis_names else 0
    n = 1
    for a in axis:
        s = mesh.shape[a] if a in mesh.axis_names else 0
        if s == 0:
            return 0
        n *= s
    return n


def resolve(shape: Sequence[int], axes: Sequence[Optional[str]],
            mesh: Mesh, rules: Dict[str, AxisVal]) -> PartitionSpec:
    """PartitionSpec for one array; drops indivisible / conflicting axes."""
    used: set = set()
    parts = []
    for dim, name in zip(shape, axes):
        val: AxisVal = rules.get(name) if name else None
        if val is not None:
            # filter to axes present in this mesh
            tup = (val,) if isinstance(val, str) else tuple(val)
            tup = tuple(a for a in tup if a in mesh.axis_names)
            val = tup if tup else None
        if val is None:
            parts.append(None)
            continue
        flat = val if isinstance(val, tuple) else (val,)
        # suffix fallback: if the full product is indivisible, drop leading
        # axes one at a time (e.g. 32 experts on ('data','model')=256 chips
        # still shard over ('model',)=16)
        chosen = None
        for start in range(len(flat)):
            cand = flat[start:]
            size = _axis_size(mesh, cand)
            if (size > 1 and dim % size == 0
                    and not any(a in used for a in cand)):
                chosen = cand
                break
        if chosen is None:
            parts.append(None)  # indivisible or conflicting: replicate
            continue
        used.update(chosen)
        parts.append(chosen if len(chosen) > 1 else chosen[0])
    return PartitionSpec(*parts)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh,
                   rules: Dict[str, AxisVal]):
    """NamedSharding tree matching a (axes, shapes) spec tree pair."""
    def one(axes, shaped):
        return NamedSharding(mesh, resolve(shaped.shape, axes, mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))
