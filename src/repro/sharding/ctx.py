"""Ambient sharding context: activation constraints inside model code.

Model code calls ``constrain(x, logical_axes)`` at key points; outside a
mesh context (unit tests on one CPU device) it is the identity, inside the
dry-run / launcher it becomes ``with_sharding_constraint`` with the active
rule set.  This keeps the model pure while letting experiments flip rules.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from .rules import AxisVal, resolve

_tls = threading.local()


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Dict[str, AxisVal]):
    prev = getattr(_tls, "cur", None)
    _tls.cur = (mesh, rules)
    try:
        yield
    finally:
        _tls.cur = prev


def current() -> Optional[tuple]:
    return getattr(_tls, "cur", None)


def constrain(x: jax.Array, axes) -> jax.Array:
    cur = current()
    if cur is None:
        return x
    mesh, rules = cur
    spec = resolve(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
