"""End-to-end driver: event-driven fault-tolerant LM training.

Every assigned architecture is selectable via --arch (reduced to a
CPU-trainable size with --preset small, or near-100M with --preset 100m).
The trainer is the EDAT-coordinated one: gradient events (sync or K-of-N
quorum), async checkpoint events, in-situ metric events, failure recovery.

  PYTHONPATH=src python examples/train_lm.py --arch gemma2-2b --steps 50
  PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m \
      --preset 100m --steps 300 --ranks 2 --ckpt-dir /tmp/ck
  PYTHONPATH=src python examples/train_lm.py --arch stablelm-1.6b \
      --kill-rank 1 --ranks 3 --ckpt-dir /tmp/ck   # failure recovery demo
"""
import argparse
import threading
import time

import numpy as np

from repro.configs import ARCHS, reduce_cfg
from repro.data import DataCfg
from repro.models import build_model
from repro.optim import OptCfg
from repro.runtime_dist import EventDrivenTrainer, TrainerCfg


def preset_cfg(arch: str, preset: str):
    cfg = reduce_cfg(ARCHS[arch].cfg)
    if preset == "100m":
        # ~100M params, CPU-runnable shapes (a few hundred steps feasible)
        cfg = cfg.replace(n_layers=max(cfg.n_layers, 8), d_model=512,
                          n_heads=8, head_dim=64,
                          d_ff=0 if cfg.mlp == "none" else 2048,
                          vocab=32768)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--quorum", type=float, default=1.0)
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-rank", type=int, default=None,
                    help="simulate node failure of this rank mid-run")
    args = ap.parse_args()

    cfg = preset_cfg(args.arch, args.preset)
    if cfg.encdec or cfg.frontend != "none":
        cfg = cfg.replace(frontend="none", n_frontend_tokens=0)
        if cfg.encdec:
            print("note: enc-dec arch trained decoder-style on synthetic "
                  "frames is not supported by this driver; using the "
                  "decoder-only backbone")
            cfg = cfg.replace(encdec=False)
    model = build_model(cfg)
    import jax
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(model.abstract_params()))
    print(f"arch={args.arch} preset={args.preset}: {n_params/1e6:.1f}M "
          f"params, {args.ranks} ranks, {args.steps} steps")

    data = DataCfg(vocab=cfg.vocab, seq=args.seq,
                   global_batch=args.batch * args.ranks)
    opt = OptCfg(name="adamw", peak_lr=args.lr, warmup=10,
                 total_steps=max(args.steps, 100))
    start = 0
    if args.resume and args.ckpt_dir:
        from repro.checkpoint import latest_step
        start = latest_step(args.ckpt_dir) or 0
        print(f"resuming from step {start}")
    tc = TrainerCfg(steps=args.steps, n_ranks=args.ranks,
                    quorum=args.quorum, compress=args.compress,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    start_step=start, collect_timeout=5.0)
    trainer = EventDrivenTrainer(model, data, opt, tc)

    if args.kill_rank is not None:
        def killer():
            time.sleep(3.0)
            print(f"!! injecting failure of rank {args.kill_rank}")
            trainer.runtime.kill_rank(args.kill_rank)
        threading.Thread(target=killer, daemon=True).start()

    t0 = time.monotonic()
    out = trainer.run(timeout=3600)
    dt = time.monotonic() - t0
    hist = out["history"]
    tokens = args.batch * args.ranks * args.seq * args.steps
    print(f"trained {args.steps} steps in {dt:.1f}s "
          f"({tokens / dt:.0f} tok/s); stale grads used: "
          f"{out['stale_used']}; ckpt writes: {out['ckpt_writes']}")
    for m in hist[:: max(1, len(hist) // 12)]:
        print(f"  step {m['step']:4d} rank{m['rank']} "
              f"loss {m['loss']:.4f} grads {m['n_grads']}")
    if hist:
        early = np.mean([m["loss"] for m in hist[:4]])
        late = np.mean([m["loss"] for m in hist[-4:]])
        print(f"loss: {early:.4f} -> {late:.4f}")


if __name__ == "__main__":
    main()
