"""Host-level pipeline parallelism expressed as EDAT events.

Each rank is a pipeline stage owning a parameter slice.  Microbatches flow
forward as ``acts`` events and backward as ``grads`` events; a stage works
on whichever event arrives next, so the 1F1B interleave *emerges* from
event arrival order instead of a globally scheduled timetable — the
paper's thesis (drive interactions with events, no explicit
synchronisation) applied to pipeline training.  In-program (pjit) sharding
handles DP/TP inside each stage on a real pod; events carry inter-stage
activations across hosts.

  PYTHONPATH=src python examples/pipeline_stages.py --stages 3 --microbatches 8
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import edat


def make_stage_fns(d, layers_per_stage, last):
    """Each stage: a small MLP block; last stage adds the loss."""

    def fwd(params, x):
        for w in params:
            x = jnp.tanh(x @ w)
        return x

    def loss_fn(params, x, y):
        out = fwd(params, x)
        return jnp.mean((out - y) ** 2)

    if last:
        grad_x_and_p = jax.jit(jax.grad(loss_fn, argnums=(0, 1)))
        loss_val = jax.jit(loss_fn)
        return jax.jit(fwd), grad_x_and_p, loss_val
    vjp_fwd = jax.jit(lambda p, x, g: jax.vjp(fwd, p, x)[1](g))
    return jax.jit(fwd), vjp_fwd, None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=3)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--layers-per-stage", type=int, default=2)
    ap.add_argument("--mb-size", type=int, default=16)
    args = ap.parse_args()

    S, M, d = args.stages, args.microbatches, args.width
    rng = np.random.default_rng(0)
    losses = []
    mu = threading.Lock()

    # fixed regression task
    X = rng.standard_normal((args.steps, M, args.mb_size, d)).astype(
        np.float32)
    W_true = rng.standard_normal((d, d)).astype(np.float32) / np.sqrt(d)
    Y = np.tanh(X @ W_true)

    state = [None] * S  # per-stage params + stash

    def main_fn(ctx):
        r = ctx.rank
        last = r == S - 1
        key = jax.random.PRNGKey(r)
        params = [jax.random.normal(jax.random.fold_in(key, i), (d, d)) /
                  np.sqrt(d) for i in range(args.layers_per_stage)]
        fwd, bwd, lossf = make_stage_fns(d, args.layers_per_stage, last)
        stash = {}
        gacc = [jnp.zeros_like(w) for w in params]
        done_mb = [0]
        step = [0]
        lr = 0.05

        def maybe_finish_step(ctx2):
            if done_mb[0] == M:
                # local optimizer update, then a non-blocking barrier
                # (paper Listing 6) before the next step's microbatches
                for i, w in enumerate(params):
                    params[i] = w - lr * gacc[i] / M
                    gacc[i] = jnp.zeros_like(w)
                done_mb[0] = 0
                step[0] += 1
                ctx2.fire(edat.ALL, "step_done")

        def on_acts(ctx2, events):
            mb, x = events[0].data
            x = jnp.asarray(x)
            if last:
                y = jnp.asarray(Y[step[0], mb])
                (gp, gx) = bwd(params, x, y)
                with mu:
                    losses.append(float(lossf(params, x, y)))
                for i, g in enumerate(gp):
                    gacc[i] = gacc[i] + g
                ctx2.fire(r - 1, "grads", (mb, np.asarray(gx)))
                done_mb[0] += 1
                maybe_finish_step(ctx2)
            else:
                out = fwd(params, x)
                stash[mb] = x
                ctx2.fire(r + 1, "acts", (mb, np.asarray(out)))

        def on_grads(ctx2, events):
            mb, g = events[0].data
            x = stash.pop(mb)
            gp, gx = bwd(params, x, jnp.asarray(g))
            for i, gi in enumerate(gp):
                gacc[i] = gacc[i] + gi
            if r > 0:
                ctx2.fire(r - 1, "grads", (mb, np.asarray(gx)))
            done_mb[0] += 1
            maybe_finish_step(ctx2)

        def feeder(ctx2, events):
            # stage 0 injects the next step's microbatches after the
            # all-stage barrier
            if step[0] >= args.steps:
                return
            for mb in range(M):
                ctx2.fire(0 if r == 0 else r, "acts",
                          (mb, X[step[0], mb]))

        ctx.submit_persistent(on_acts, deps=[(edat.ANY, "acts")],
                              name="fwd")
        if not last:
            ctx.submit_persistent(on_grads, deps=[(edat.ANY, "grads")],
                                  name="bwd")
        if r == 0:
            ctx.submit_persistent(feeder, deps=[(edat.ALL, "step_done")],
                                  name="feeder")
            feeder(ctx, [])   # kick off step 0
        state[r] = params

    t0 = time.monotonic()
    edat.run(main_fn, ranks=S, workers_per_rank=1,
             unconsumed="ignore", timeout=600)
    dt = time.monotonic() - t0
    per_step = [np.mean(losses[i * M:(i + 1) * M])
                for i in range(args.steps)]
    print(f"pipeline {S} stages x {M} microbatches, {args.steps} steps "
          f"in {dt:.2f}s")
    print("  per-step loss:", " ".join(f"{l:.4f}" for l in per_step))
    assert per_step[-1] < per_step[0], "pipeline training must reduce loss"


if __name__ == "__main__":
    main()
