"""Quickstart: the paper's Listing 4 example, in EDAT-JAX.

Two ranks; task1 (rank 0) fires two events; task2 (rank 1) fires a third;
task3 (rank 1) consumes one event from each and prints the sum.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import edat

# typed channels (v2): typos fail fast, payload types are checked at fire
EVENT1 = edat.Channel("event1")
EVENT2 = edat.Channel("event2", payload=int)
EVENT3 = edat.Channel("event3", payload=int)


def task1(ctx, events):
    ctx.fire(1, EVENT1)                   # no payload (EDAT_NONE)
    ctx.fire(1, EVENT2, 33)               # one integer payload


def task2(ctx, events):
    ctx.fire(edat.SELF, EVENT3, 100)      # EDAT_SELF target


def task3(ctx, events):
    print(f"task3 on rank {ctx.rank}: "
          f"{events[0].data} + {events[1].data} = "
          f"{events[0].data + events[1].data}")


def main(ctx):
    if ctx.rank == 0:
        ctx.submit(task1)                                  # no dependencies
    elif ctx.rank == 1:
        ctx.submit(task2, deps=[(0, EVENT1)])
        ctx.submit(task3, deps=[(0, EVENT2), (1, EVENT3)])


if __name__ == "__main__":
    stats = edat.run(main, ranks=2, workers_per_rank=2)
    print(f"terminated cleanly: {stats}")
