"""Quickstart: the paper's Listing 4 example, in EDAT-JAX.

Two ranks; task1 (rank 0) fires two events; task2 (rank 1) fires a third;
task3 (rank 1) consumes one event from each and prints the sum.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro import edat


def task1(ctx, events):
    ctx.fire(1, "event1")                 # no payload (EDAT_NONE)
    ctx.fire(1, "event2", 33)             # one integer payload


def task2(ctx, events):
    ctx.fire(edat.SELF, "event3", 100)    # EDAT_SELF target


def task3(ctx, events):
    print(f"task3 on rank {ctx.rank}: "
          f"{events[0].data} + {events[1].data} = "
          f"{events[0].data + events[1].data}")


def main(ctx):
    if ctx.rank == 0:
        ctx.submit(task1)                                  # no dependencies
    elif ctx.rank == 1:
        ctx.submit(task2, deps=[(0, "event1")])
        ctx.submit(task3, deps=[(0, "event2"), (1, "event3")])


if __name__ == "__main__":
    rt = edat.Runtime(n_ranks=2, workers_per_rank=2)
    stats = rt.run(main)
    print(f"terminated cleanly: {stats}")
