"""Multi-process ring ping-pong over SocketTransport (repro.net).

``--ranks`` OS-hosted EDAT ranks (packed into ``--procs`` processes; one
each by default).  A token circulates the ring ``0 -> 1 -> ... -> 0``
for ``N_HOPS`` hops; every rank runs one persistent relay task depending
on its left neighbour's ``token`` channel.  Termination is the
unmodified Mattern detector speaking CONTROL messages across process
boundaries.  The v2 ``Session`` owns spawn, rendezvous and teardown:

  PYTHONPATH=src python examples/net_pingpong.py
  PYTHONPATH=src python examples/net_pingpong.py --ranks 4 --procs 2
  PYTHONPATH=src python examples/net_pingpong.py --transport inproc
"""
import argparse

from repro import edat

N_HOPS = 200
TOKEN = edat.Channel("token", payload=int)


def relay(ctx, events):
    hops = events[0].data
    if hops < N_HOPS:
        ctx.fire((ctx.rank + 1) % ctx.n_ranks, TOKEN, hops + 1)


def main(ctx):
    left = (ctx.rank - 1) % ctx.n_ranks
    ctx.submit_persistent(relay, deps=[(left, TOKEN)], name="relay")
    if ctx.rank == 0:
        ctx.fire(1, TOKEN, 1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--procs", type=int, default=None,
                    help="OS processes to pack the ranks into "
                         "(default: one per rank)")
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="socket")
    a = ap.parse_args()
    with edat.Session(a.ranks, procs=a.procs, transport=a.transport,
                      timeout=60) as s:
        s.run(main)
        stats = s.stats
    hops_per_s = N_HOPS / stats["run_seconds"]
    print(f"ring of {a.ranks} ranks ({a.transport}), {N_HOPS} hops in "
          f"{stats['run_seconds']:.3f}s ({hops_per_s:.0f} hops/s); "
          f"stats={stats}")
