"""Multi-process ring ping-pong over SocketTransport (repro.net).

Four OS processes, one EDAT rank each.  A token circulates the ring
``0 -> 1 -> 2 -> 3 -> 0`` for ``N_HOPS`` hops; every rank runs one
persistent relay task depending on its left neighbour's ``token`` event.
Termination is the unmodified Mattern detector, now speaking CONTROL
messages across process boundaries.

Run it either way:

  PYTHONPATH=src python examples/net_pingpong.py
  PYTHONPATH=src python -m repro.net.launch --ranks 4 examples/net_pingpong.py:main
"""
from repro import edat

N_HOPS = 200


def relay(ctx, events):
    hops = events[0].data
    if hops < N_HOPS:
        ctx.fire((ctx.rank + 1) % ctx.n_ranks, "token", hops + 1)


def main(ctx):
    left = (ctx.rank - 1) % ctx.n_ranks
    ctx.submit_persistent(relay, deps=[(left, "token")], name="relay")
    if ctx.rank == 0:
        ctx.fire(1, "token", 1)


if __name__ == "__main__":
    stats = edat.launch_processes(4, main, timeout=60)
    hops_per_s = N_HOPS / stats["run_seconds"]
    print(f"ring of 4 processes, {N_HOPS} hops in "
          f"{stats['run_seconds']:.3f}s ({hops_per_s:.0f} hops/s); "
          f"stats={stats}")
