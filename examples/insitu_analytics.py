"""MONC-style in-situ analytics (paper §VI): computational ranks saturate
analytics ranks with raw field events; persistent EDAT tasks analyse,
reduce across analytics ranks (distributed roots) and 'write'.

  PYTHONPATH=src python examples/insitu_analytics.py --analytics 4
  PYTHONPATH=src python examples/insitu_analytics.py --analytics 2 --transport socket
"""
import argparse
import dataclasses

from repro import edat
from repro.analytics import (BespokeAnalytics, EdatAnalytics, InsituCfg,
                             insitu_program)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--analytics", type=int, default=4)
    ap.add_argument("--items", type=int, default=64)
    ap.add_argument("--elems", type=int, default=1024)
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc",
                    help="threads-as-ranks, or one OS process per rank "
                         "over the coalescing SocketTransport")
    ap.add_argument("--bespoke", action="store_true",
                    help="also run the MONC-style baseline (inproc)")
    args = ap.parse_args()

    cfg = InsituCfg(n_analytics=args.analytics,
                    items_per_producer=args.items, field_elems=args.elems,
                    n_fields=2)
    if args.transport == "socket":
        with edat.Session(2 * cfg.n_analytics, transport="socket",
                          timeout=180, workers_per_rank=4) as s:
            s.run(edat.deferred(insitu_program, dataclasses.asdict(cfg)))
            summary = s.gather()
            dt = s.stats["run_seconds"]
        raw = cfg.n_analytics * cfg.items_per_producer
        print(f"EDAT (socket): {raw} items, {raw / dt:.1f} items/s, "
              f"latency {summary['mean_latency_s'] * 1e3:.2f} ms")
        return
    res = EdatAnalytics(cfg).run()
    print(f"EDAT    : {res['raw_items']} items, "
          f"{res['bandwidth_items_s']:.1f} items/s, "
          f"latency {res['mean_latency_s'] * 1e3:.2f} ms")
    if args.bespoke:
        res = BespokeAnalytics(cfg).run()
        print(f"bespoke : {res['raw_items']} items, "
              f"{res['bandwidth_items_s']:.1f} items/s, "
              f"latency {res['mean_latency_s'] * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
