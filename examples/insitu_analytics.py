"""MONC-style in-situ analytics (paper §VI): computational ranks saturate
analytics ranks with raw field events; persistent EDAT tasks analyse,
reduce across analytics ranks (distributed roots) and 'write'.

  PYTHONPATH=src python examples/insitu_analytics.py --analytics 4
"""
import argparse

from repro.analytics import BespokeAnalytics, EdatAnalytics, InsituCfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--analytics", type=int, default=4)
    ap.add_argument("--items", type=int, default=64)
    ap.add_argument("--elems", type=int, default=1024)
    ap.add_argument("--bespoke", action="store_true",
                    help="also run the MONC-style baseline")
    args = ap.parse_args()

    cfg = InsituCfg(n_analytics=args.analytics,
                    items_per_producer=args.items, field_elems=args.elems,
                    n_fields=2)
    res = EdatAnalytics(cfg).run()
    print(f"EDAT    : {res['raw_items']} items, "
          f"{res['bandwidth_items_s']:.1f} items/s, "
          f"latency {res['mean_latency_s'] * 1e3:.2f} ms")
    if args.bespoke:
        res = BespokeAnalytics(cfg).run()
        print(f"bespoke : {res['raw_items']} items, "
              f"{res['bandwidth_items_s']:.1f} items/s, "
              f"latency {res['mean_latency_s'] * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
