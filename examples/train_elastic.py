"""Elastic event-driven training — in one process or across many.

The same EventDrivenTrainer program attaches to threads-as-ranks or to
spawned OS processes (several ranks per process over the coalescing
socket transport); with ``--kill`` one process is SIGKILLed mid-run and
the co-located survivors roll back to the last durable checkpoint,
re-shard, and finish (the paper's §VII RANK_FAILED story, for real
processes).  Everything runs through the v2 ``edat.Session``:

    PYTHONPATH=src python examples/train_elastic.py                # threads
    PYTHONPATH=src python examples/train_elastic.py --transport socket \
        --ranks 4 --procs 2
    PYTHONPATH=src python examples/train_elastic.py --transport socket \
        --ranks 4 --procs 2 --kill                                 # chaos
"""
import argparse
import os
import tempfile
import time

from repro import edat
from repro.runtime_dist.trainer import _demo_cfgs, trainer_program


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc")
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--procs", type=int, default=2,
                    help="processes to pack the ranks into (socket only)")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--kill", action="store_true",
                    help="SIGKILL the last process after the first "
                         "checkpoint (socket only)")
    a = ap.parse_args()

    with tempfile.TemporaryDirectory(prefix="edat_train_example_") as td:
        ckdir = os.path.join(td, "ck")
        model_cfg, data_cfg, opt_cfg, trainer_cfg = _demo_cfgs(
            a.ranks, a.steps, ckdir, ckpt_every=3)

        if a.transport == "inproc":
            tr = trainer_program(model_cfg, data_cfg, opt_cfg, trainer_cfg)
            out = tr.run(timeout=600)
            hist = out["history"]
        else:
            from repro.checkpoint import latest_step
            with edat.Session(a.ranks, procs=a.procs, transport="socket",
                              timeout=600,
                              workers_per_rank=trainer_cfg.workers_per_rank,
                              unconsumed="ignore", hb_interval=0.2,
                              hb_timeout=1.5) as s:
                s.start(edat.deferred(trainer_program, model_cfg, data_cfg,
                                      opt_cfg, trainer_cfg))
                if a.kill:
                    deadline = time.monotonic() + 300
                    while ((latest_step(ckdir) or 0) < 3
                           and time.monotonic() < deadline):
                        if all(c is not None
                               for c in s.exitcodes().values()):
                            raise SystemExit(
                                "children exited before the first "
                                "checkpoint")
                        time.sleep(0.05)
                    if (latest_step(ckdir) or 0) < 3:
                        raise SystemExit("no checkpoint appeared within "
                                         "300s")
                    victim = a.ranks - 1
                    print(f"== SIGKILL the process hosting rank {victim} "
                          f"==")
                    s.kill(victim)
                s.wait(600, check=not a.kill)
                res = s.gather()
            hist = res["history"]
            for r in res["recoveries"]:
                print(f"rank {r['rank']}: rolled back to step {r['step']} "
                      f"(epoch {r['epoch']})")

        for m in hist:
            print(f"rank {m['rank']} step {m['step']:3d} "
                  f"loss {m['loss']:.4f} grads {m['n_grads']} "
                  f"stale {m['n_stale']}")
        print(f"reached step {max(m['step'] for m in hist)}/{a.steps} "
              f"({a.transport})")


if __name__ == "__main__":
    main()
