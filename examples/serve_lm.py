"""Event-driven LM serving: continuous batching driven by EDAT events.

Thin CLI over :mod:`repro.serve` — the promoted, tested subsystem this
example used to sketch.  Client ranks replay an open-loop Poisson
schedule of request events; the server rank admits them into decode
slots, a single self-sustaining ``decode_tick`` chain steps the whole
batch one greedy token at a time, and completions fire back as response
events — the paper's fire-and-forget interaction end to end, with
event-carried backpressure when the admission queue outgrows its bound.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --requests 12
  PYTHONPATH=src python examples/serve_lm.py --transport socket --procs 2
"""
import argparse

from repro.configs import ARCHS
from repro.serve import DEFAULT_MAX_LEN, LoadSpec, run_serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCHS))
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8,
                    help="total requests across all clients")
    ap.add_argument("--rps", type=float, default=8.0,
                    help="aggregate offered request rate")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=DEFAULT_MAX_LEN)
    ap.add_argument("--queue-bound", type=int, default=8)
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc")
    ap.add_argument("--procs", type=int, default=None,
                    help="processes for socket runs")
    args = ap.parse_args()

    load = LoadSpec(rps=args.rps, requests=args.requests,
                    max_new_lo=max(1, args.max_new // 2),
                    max_new_hi=args.max_new)
    out = run_serve(arch=args.arch, clients=args.clients, slots=args.slots,
                    max_len=args.max_len, load=load,
                    queue_bound=args.queue_bound,
                    transport=args.transport, procs=args.procs)
    res, s = out["result"], out["summary"]
    print(f"served {res['served']} requests / {s['tokens']} tokens in "
          f"{s['wall_s']:.2f}s serving window "
          f"({s['tokens_per_s']:.1f} tok/s, batch slots={res['slots']}, "
          f"{args.transport})")
    print(f"ttft p50={s['ttft_p50_ms']:.0f}ms p99={s['ttft_p99_ms']:.0f}ms "
          f"per-token p50={s['per_token_p50_ms']:.2f}ms "
          f"p99={s['per_token_p99_ms']:.2f}ms")
    if res["bp_signals"]:
        print(f"backpressure: {res['bp_signals']} on-signal(s); clients "
              f"throttled "
              f"{sum(r['throttled_s'] for r in res['records']):.2f}s total")
    assert res["served"] == args.requests, res
    assert res["slots_leaked"] == 0, res
    assert res["tick_execs"] == res["steps"], res


if __name__ == "__main__":
    main()
