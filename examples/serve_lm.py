"""Event-driven serving: continuous batching driven by EDAT events.

Client ranks fire request events at random times; the server rank's
batcher task admits them into decode slots, a persistent decode task steps
the whole batch through ``serve_step`` (one jitted token step with a KV
cache), and completions are fired back as response events — the paper's
fire-and-forget interaction end to end.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --requests 12
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import edat
from repro.configs import ARCHS, reduce_cfg
from repro.models import build_model
from repro.train import make_serve_step

MAX_LEN = 128


class Server:
    def __init__(self, cfg, slots: int, max_new: int):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.slots = slots
        self.max_new = max_new
        self.serve_step = jax.jit(make_serve_step(self.model))
        self.caches = self.model.init_cache(slots, MAX_LEN)
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.pos = jnp.zeros((slots, 1), jnp.int32)
        self.live = [None] * slots          # per-slot (req_id, client, left)
        self.queue = []
        self.served = 0

    # -- EDAT tasks -----------------------------------------------------------
    # server state is guarded by an EDAT named lock (paper §IV.C):
    # auto-released at task end, so request/tick tasks serialise cleanly
    # even with multiple workers.
    def on_request(self, ctx, events):
        ctx.lock("server")
        req = events[0].data
        self.queue.append((req, events[0].source))
        self._admit(ctx)
        if not any(self.live):
            return
        ctx.fire(edat.SELF, "tick")

    def _admit(self, ctx):
        # demo simplification: slots are conditioned on the prompt's last
        # token only (weights are random-init; the event-driven batching
        # mechanics, not output quality, are what this example shows).
        for i in range(self.slots):
            if self.live[i] is None and self.queue:
                (req, client) = self.queue.pop(0)
                prompt = req["prompt"]
                self.tokens = self.tokens.at[i, 0].set(prompt[-1])
                self.pos = self.pos.at[i, 0].set(len(prompt) - 1)
                self.live[i] = {"id": req["id"], "client": client,
                                "left": self.max_new, "out": []}

    def on_tick(self, ctx, events):
        ctx.lock("server")
        if not any(self.live):
            return
        nxt, self.caches = self.serve_step(self.params, self.caches,
                                           self.tokens, self.pos)
        self.tokens = nxt
        self.pos = self.pos + 1
        done_any = False
        for i, st in enumerate(self.live):
            if st is None:
                continue
            st["out"].append(int(nxt[i, 0]))
            st["left"] -= 1
            if st["left"] <= 0:
                ctx.fire(st["client"], "response",
                         {"id": st["id"], "tokens": st["out"]})
                self.live[i] = None
                self.served += 1
                done_any = True
        if done_any:
            self._admit(ctx)
        if any(self.live):
            ctx.fire(edat.SELF, "tick")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCHS))
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_cfg(ARCHS[args.arch].cfg).replace(
        frontend="none", n_frontend_tokens=0, encdec=False,
        max_target_length=MAX_LEN)
    server = Server(cfg, args.slots, args.max_new)
    n_ranks = 1 + args.clients
    got = []
    lat = {}
    mu = threading.Lock()

    def main_fn(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(server.on_request,
                                  deps=[(edat.ANY, "request")], name="req")
            ctx.submit_persistent(server.on_tick,
                                  deps=[(edat.SELF, "tick")], name="tick")
        else:
            def on_response(ctx2, events):
                r = events[0].data
                with mu:
                    got.append(r)
                    lat[r["id"]] = time.monotonic() - lat[r["id"]]
            ctx.submit_persistent(on_response,
                                  deps=[(0, "response")], name="resp")
            rng = np.random.default_rng(ctx.rank)
            per = args.requests // args.clients
            for i in range(per):
                rid = ctx.rank * 1000 + i
                with mu:
                    lat[rid] = time.monotonic()
                ctx.fire(0, "request",
                         {"id": rid,
                          "prompt": rng.integers(
                              0, cfg.vocab, size=4).tolist()})
                time.sleep(float(rng.random()) * 0.05)

    t0 = time.monotonic()
    edat.run(main_fn, ranks=n_ranks, workers_per_rank=2,
             unconsumed="ignore", timeout=600)
    dt = time.monotonic() - t0
    n_tokens = sum(len(r["tokens"]) for r in got)
    print(f"served {len(got)} requests / {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens / dt:.1f} tok/s, batch slots={args.slots})")
    if lat:
        vals = sorted(lat.values())
        print(f"latency p50={vals[len(vals)//2]*1e3:.0f}ms "
              f"p max={vals[-1]*1e3:.0f}ms")
    assert len(got) == (args.requests // args.clients) * args.clients


if __name__ == "__main__":
    main()
