"""Graph500 BFS driver (paper §V): event-driven BFS over a Kronecker graph.

  PYTHONPATH=src python examples/bfs_graph500.py --scale 14 --ranks 4
  PYTHONPATH=src python examples/bfs_graph500.py --ranks 4 --transport socket
"""
import argparse
import time

import numpy as np

from repro import edat
from repro.graph import (EdatBFS, ReferenceBFS, bfs_program, build_csr,
                         kronecker_edges, validate_bfs_tree)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--reference", action="store_true",
                    help="run the BSP reference instead of EDAT")
    ap.add_argument("--transport", choices=("inproc", "socket"),
                    default="inproc",
                    help="threads-as-ranks, or one OS process per rank "
                         "over the coalescing SocketTransport")
    ap.add_argument("--validate", action="store_true")
    args = ap.parse_args()

    n = 1 << args.scale
    print(f"generating Kronecker graph scale={args.scale} "
          f"({n} vertices, ~{n * args.edgefactor} edges)")
    edges = kronecker_edges(args.scale, args.edgefactor)
    deg = np.bincount(np.concatenate([edges[0], edges[1]]), minlength=n)
    root = int(np.where(deg > 0)[0][0])

    if args.transport == "socket":
        assert not args.reference, "--transport socket runs the EDAT BFS"
        # v2: the Session owns spawn/rendezvous/teardown; each process
        # rebuilds the graph deterministically via the deferred factory
        with edat.Session(args.ranks, transport="socket",
                          workers_per_rank=args.workers) as s:
            s.run(edat.deferred(bfs_program, args.ranks, args.scale,
                                edgefactor=args.edgefactor, root=root,
                                workers_per_rank=args.workers))
            res = s.gather()
            stats = s.stats
        parent = res["parent"]
        traversed = int(np.sum(res["traversed"]))
        dt = max(stats["run_seconds"], 1e-9)
        print(f"EDAT BFS over {args.ranks} processes: "
              f"{traversed} edges in {dt:.3f}s "
              f"-> {traversed / dt:.3e} TEPS "
              f"({stats.get('events_sent', 0) / dt:.0f} "
              f"events/s); reached {(parent >= 0).sum()}/{n}")
        if args.validate:
            ok = validate_bfs_tree(edges, parent, root)
            print(f"validation: {'PASS' if ok else 'FAIL'}")
            assert ok
        return

    csr = build_csr(edges, n, args.ranks)
    bfs = (ReferenceBFS(csr) if args.reference
           else EdatBFS(csr, workers_per_rank=args.workers))
    t0 = time.monotonic()
    parent = bfs.run(root)
    dt = time.monotonic() - t0
    traversed = sum(bfs.traversed)
    print(f"{'reference' if args.reference else 'EDAT'} BFS: "
          f"{traversed} edges in {dt:.3f}s -> {traversed / dt:.3e} TEPS; "
          f"reached {(parent >= 0).sum()}/{n}")
    if args.validate:
        ok = validate_bfs_tree(edges, parent, root)
        print(f"validation: {'PASS' if ok else 'FAIL'}")
        assert ok


if __name__ == "__main__":
    main()
