"""Golden tests for ``repro.insights``: four canonical runs, each built to
trip exactly one rule (or none), on both transports.  The point is
end-to-end: real counters out of ``Session.stats()`` drive the rules, so
a drift in either the metrics plumbing or the rule thresholds shows up
here — not just in synthetic-dict unit tests (which run first, below).
"""
import functools
import time

import pytest

from repro import edat
from repro.insights import Finding, analyze, render

pytestmark = pytest.mark.timeout(120)

TRANSPORTS = ("inproc", "socket")


# ------------------------------------------------------------- unit: rules
def _stats(channels=None, ranks=None, transport=None):
    return {"channels": channels or {}, "ranks": ranks or {},
            "transport": transport or {"kind": "inproc"}}


def test_analyze_empty_and_metrics_off():
    assert analyze({}) == []
    assert analyze({"run_seconds": 0.1}) == []   # metrics=False stats


def test_analyze_skips_machine_channels():
    ch = {"__sess.result": {"fires": 10_000, "bytes": 0, "queued_max": 9999}}
    assert analyze(_stats(channels=ch)) == []


def test_spam_precedence_over_backpressure():
    ch = {"tick": {"fires": 1000, "bytes": 8000, "deliveries": 1000,
                   "queued_max": 900}}
    rules = [f.rule for f in analyze(_stats(channels=ch))]
    assert rules == ["scalar-spam"]   # depth 900 not double-reported


def test_straggler_needs_three_ranks_and_dominance():
    ranks = {0: {"quorum_wait_s": 0.4}, 1: {"quorum_wait_s": 0.4}}
    assert analyze(_stats(ranks=ranks)) == []            # only 2 ranks
    ranks = {0: {"quorum_wait_s": 0.05}, 1: {"quorum_wait_s": 0.05},
             2: {"quorum_wait_s": 0.06}}
    assert analyze(_stats(ranks=ranks)) == []            # no dominant share
    ranks[2]["quorum_wait_s"] = 0.5
    (f,) = analyze(_stats(ranks=ranks))
    assert f.rule == "straggler" and f.data["rank"] == 2
    assert "rank 2" in str(f)


def test_admission_backpressure_rule():
    # any fire on a serving program's 'backpressure' channel means the
    # admission queue crossed its bound: report against 'request'
    ch = {"backpressure": {"fires": 3, "bytes": 120, "deliveries": 3},
          "request": {"fires": 40, "bytes": 9000, "queued_max": 11}}
    (f,) = analyze(_stats(channels=ch))
    assert f.rule == "admission-backpressure"
    assert f.data["eid"] == "request"
    assert f.data["bp_fires"] == 3 and f.data["request_fires"] == 40
    assert "throttled" in f.message
    # no backpressure fires -> no finding
    ch = {"request": {"fires": 40, "bytes": 9000, "queued_max": 3},
          "backpressure": {"fires": 0, "bytes": 0}}
    assert analyze(_stats(channels=ch)) == []


def test_tasks_replayed_rule():
    # durable recovery: one finding per (dead rank, channel), naming the
    # channel, the replayed-event count, and the dead rank
    stats = _stats()
    stats["durable"] = {"log": "sqlite", "appends": 120, "batches": 9,
                        "queue_max": 4,
                        "replays": [
                            {"dead_rank": 2, "channel": "wq.work",
                             "events": 5},
                            {"dead_rank": 2, "channel": "wq.done",
                             "events": 1}]}
    findings = analyze(stats)
    assert [f.rule for f in findings] == ["tasks-replayed"] * 2
    work = next(f for f in findings if f.data["eid"] == "wq.work")
    assert work.data["events"] == 5 and work.data["dead_rank"] == 2
    assert "'wq.work'" in work.message and "rank 2" in work.message
    assert "at-least-once" in work.message
    # durable mode on but no failure: no finding
    stats["durable"]["replays"] = []
    assert analyze(stats) == []


def test_render_shapes():
    assert "healthy" in render([])
    out = render([Finding("backpressure", "channel 'g' backpressured")])
    assert out.startswith("- **backpressure**")


# --------------------------------------------------- golden runs (mains are
# module level: the socket axis pickles them into spawned rank processes)

def _backpressure_main(ctx, n=700):
    if ctx.rank == 0:
        def slow_sink(c, events):
            time.sleep(0.002)
        ctx.submit_persistent(slow_sink, deps=[(1, "bulk")])
    else:
        payload = b"x" * 1024          # fat enough to dodge the spam rule
        for _ in range(n):
            ctx.fire(0, "bulk", payload)


def _spam_main(ctx, n=2000):
    if ctx.rank == 0:
        ctx.submit_persistent(lambda c, e: None, deps=[(1, "tick")])
    else:
        for i in range(n):
            ctx.fire(0, "tick", i)     # 8 B scalars


def _straggler_main(ctx, delay=0.25):
    if ctx.rank == 0:
        ctx.submit(lambda c, e: None, deps=[(1, "a"), (2, "a"), (3, "a")])
    else:
        if ctx.rank == 3:
            time.sleep(delay)          # the frame waits on rank 3's event
        ctx.fire(0, "a", b"x" * 100)


def _clean_main(ctx, hops=50):
    nxt = (ctx.rank + 1) % ctx.n_ranks

    def relay(c, events):
        d = events[0].data
        if d["i"] < hops:
            c.fire(nxt, "tok", {"i": d["i"] + 1, "pad": d["pad"]})

    ctx.submit_persistent(relay, deps=[((ctx.rank - 1) % ctx.n_ranks,
                                        "tok")])
    if ctx.rank == 0:
        ctx.fire(1, "tok", {"i": 0, "pad": b"x" * 100})


def _chatty_main(ctx, n=1200):
    if ctx.rank == 0:
        ctx.submit_persistent(lambda c, e: None, deps=[(1, "w")])
    else:
        payload = b"x" * 64            # fat enough to dodge the spam rule
        for _ in range(n):
            ctx.fire(0, "w", payload)


def _golden(main, *, ranks=2, transport="inproc", **kw):
    with edat.Session(ranks, transport=transport, timeout=120, **kw) as s:
        s.run(main)
        return s.stats


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_golden_backpressure(transport):
    stats = _golden(_backpressure_main, transport=transport)
    findings = analyze(stats)
    assert [f.rule for f in findings] == ["backpressure"]
    (f,) = findings
    assert f.data["eid"] == "bulk" and f.data["queued_max"] >= 512
    if transport == "socket":
        assert "max_batch_bytes" in f.message


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_golden_scalar_spam(transport):
    stats = _golden(_spam_main, transport=transport)
    findings = analyze(stats)
    assert [f.rule for f in findings] == ["scalar-spam"]
    assert findings[0].data["eid"] == "tick"
    assert "fire_batch" in findings[0].message


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_golden_straggler(transport):
    stats = _golden(_straggler_main, ranks=4, transport=transport)
    findings = analyze(stats)
    assert [f.rule for f in findings] == ["straggler"]
    assert findings[0].data["rank"] == 3
    assert "rank 3" in findings[0].message


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_golden_clean_run(transport):
    stats = _golden(_clean_main, ranks=4, transport=transport)
    assert analyze(stats) == []


def test_golden_chatty_no_coalesce():
    stats = _golden(_chatty_main, transport="socket", coalesce=False)
    findings = analyze(stats)
    rules = [f.rule for f in findings]
    assert "chatty-no-coalesce" in rules
    # a slow receiver may legitimately also backlog past the backpressure
    # threshold during the un-coalesced flood — but nothing else may fire
    assert set(rules) <= {"chatty-no-coalesce", "backpressure"}
    chatty = next(f for f in findings if f.rule == "chatty-no-coalesce")
    assert chatty.data["wire_events_sent"] >= 1000
