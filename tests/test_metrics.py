"""Per-channel runtime metrics: counters, merge, trace, Session.stats().

Covers the always-on observability layer end to end:

* ``payload_nbytes`` — the fire path's cheap size estimate;
* ``merge_metrics`` — folding per-process snapshots (sums, high-water
  marks, peer re-keying);
* inproc and socket ``Session.stats()`` carry the canonical
  ``channels`` / ``ranks`` / ``transport`` sections with exact counts
  for a deterministic program;
* ``metrics=False`` really turns the structured sections off;
* ``trace=True`` records bounded per-rank task/event timelines.
"""
import numpy as np
import pytest

from repro import edat
from repro.core.metrics import RunStats, merge_metrics, payload_nbytes

pytestmark = pytest.mark.timeout(120)


# ----------------------------------------------------------- payload sizing
def test_payload_nbytes_shapes():
    assert payload_nbytes(None) == 0
    assert payload_nbytes(7) == 8
    assert payload_nbytes(1.5) == 8
    assert payload_nbytes(True) == 8
    assert payload_nbytes(1 + 2j) == 16
    assert payload_nbytes("abcd") == 4
    assert payload_nbytes(b"x" * 100) == 100
    assert payload_nbytes(np.zeros(10, dtype=np.float64)) == 80
    assert payload_nbytes([1, 2.0, "abc"]) == 8 + 8 + 3
    assert payload_nbytes({"a": 5, "b": b"xy"}) == 8 + 2
    assert payload_nbytes(object()) == 64          # flat fallback


# ----------------------------------------------------------------- RunStats
def test_runstats_is_a_callable_dict():
    s = RunStats({"run_seconds": 0.5})
    assert s["run_seconds"] == 0.5
    assert s() is s                      # s.stats() and s.stats both work
    assert isinstance(s, dict)


# ------------------------------------------------------------ merge_metrics
def test_merge_metrics_sums_and_rekeys_peers():
    p0 = {"channels": {"g": {"fires": 10, "bytes": 100, "wire_fires": 10,
                             "deliveries": 0, "consumed": 0,
                             "queued_max": 3}},
          "ranks": {0: {"tasks_executed": 2, "busy_s": 0.1,
                        "quorum_wait_s": 0.0}},
          "transport": {"kind": "socket", "coalesce": True,
                        "wire_events_sent": 10, "wire_events_recv": 0,
                        "wire_bytes": 500, "writes": 2, "dropped": 0,
                        "sendq_max": 4, "peers": {1: {"sent": 10}}}}
    p1 = {"channels": {"g": {"fires": 0, "bytes": 0, "wire_fires": 0,
                             "deliveries": 10, "consumed": 10,
                             "queued_max": 7}},
          "ranks": {1: {"tasks_executed": 10, "busy_s": 0.4,
                        "quorum_wait_s": 0.2}},
          "transport": {"kind": "socket", "coalesce": True,
                        "wire_events_sent": 0, "wire_events_recv": 10,
                        "wire_bytes": 40, "writes": 1, "dropped": 0,
                        "sendq_max": 1, "peers": {0: {"sent": 0}}}}
    m = merge_metrics([(0, p0), (1, p1)])
    g = m["channels"]["g"]
    assert g["fires"] == 10 and g["deliveries"] == 10 and g["consumed"] == 10
    assert g["queued_max"] == 7                    # max, not sum
    assert m["ranks"][1]["tasks_executed"] == 10
    assert m["ranks"][1]["quorum_wait_s"] == 0.2
    t = m["transport"]
    assert t["wire_events_sent"] == 10 and t["wire_events_recv"] == 10
    assert t["wire_bytes"] == 540 and t["writes"] == 3
    assert t["sendq_max"] == 4                     # max, not sum
    assert set(t["peers"]) == {"0->1", "1->0"}     # re-keyed by lead rank


def test_merge_metrics_skips_empty_parts():
    assert merge_metrics([(0, {})]) == {"channels": {}, "ranks": {},
                                        "transport": {}}


# ------------------------------------------------- inproc session counters
def _fanout_main(ctx, n=50):
    if ctx.rank == 0:
        ctx.submit_persistent(lambda c, e: None, deps=[(1, "x")])
    else:
        for i in range(n):
            ctx.fire(0, "x", i)


def test_inproc_stats_channels_exact():
    with edat.Session(2) as s:
        s.run(_fanout_main)
        ch = s.stats()["channels"]["x"]
    assert ch["fires"] == 50
    assert ch["bytes"] == 50 * 8                   # int payloads
    assert ch["wire_fires"] == 0                   # all ranks co-located
    assert ch["deliveries"] == 50 and ch["consumed"] == 50
    assert 1 <= ch["queued_max"] <= 50
    tr = s.stats()["transport"]
    assert tr["kind"] == "inproc"


def test_inproc_rank_section_counts_tasks():
    with edat.Session(2) as s:
        s.run(_fanout_main)
        ranks = s.stats()["ranks"]
    assert set(ranks) == {0, 1}
    # rank 0 ran the 50 sink instances (plus nothing on rank 1)
    assert ranks[0]["tasks_executed"] == 50
    assert ranks[0]["busy_s"] >= 0.0


def test_metrics_off_omits_structured_sections():
    with edat.Session(2, metrics=False) as s:
        s.run(_fanout_main)
        stats = s.stats()
    assert "run_seconds" in stats
    assert "channels" not in stats and "transport" not in stats


def test_trace_records_task_and_recv_timelines():
    with edat.Session(2, trace=True) as s:
        s.run(_fanout_main)
        ranks = s.stats()["ranks"]
    trace0 = ranks[0]["trace"]
    kinds = {rec[0] for rec in trace0}
    assert kinds == {"recv", "task"}
    tasks = [rec for rec in trace0 if rec[0] == "task"]
    assert len(tasks) == 50
    # ("task", t0, dur, name, n_events) — timestamps are monotonic stamps
    assert all(rec[2] >= 0.0 and rec[4] == 1 for rec in tasks)
    assert ranks[0].get("trace_dropped", 0) == 0


def test_trace_off_by_default():
    with edat.Session(2) as s:
        s.run(_fanout_main)
        assert "trace" not in s.stats()["ranks"][0]


# ---------------------------------------------------- socket session merge
def test_socket_stats_merge_wire_counters():
    with edat.Session(2, transport="socket", timeout=120) as s:
        s.run(_fanout_main)
        stats = s.stats()
    ch = stats["channels"]["x"]
    assert ch["fires"] == 50 and ch["wire_fires"] == 50
    assert ch["deliveries"] == 50 and ch["consumed"] == 50
    t = stats["transport"]
    assert t["kind"] == "socket" and t["coalesce"] is True
    assert t["wire_events_sent"] == 50 and t["wire_events_recv"] == 50
    assert t["loopback_events"] == 0 and t["dropped"] == 0
    assert t["wire_bytes"] > 0 and t["writes"] >= 1
    assert set(t["peers"]) == {"0->1", "1->0"}
    assert stats["ranks"][0]["tasks_executed"] == 50


def _coloc_main(ctx):
    partner = ctx.rank ^ 1            # co-located under procs=2 packing
    far = (ctx.rank + 2) % 4
    ctx.submit_persistent(lambda c, e: None, deps=[(partner, "co")])
    ctx.submit_persistent(lambda c, e: None, deps=[(far, "fa")])
    for _ in range(10):
        ctx.fire(partner, "co", 1)
        ctx.fire(far, "fa", 1)


def test_socket_colocated_ranks_count_loopback():
    """4 ranks packed 2-per-process: fires between co-located ranks are
    loopback (no wire), fires across processes are wire."""
    with edat.Session(4, transport="socket", procs=2, timeout=120) as s:
        s.run(_coloc_main)
        stats = s.stats()
    assert stats["channels"]["co"]["wire_fires"] == 0
    assert stats["channels"]["fa"]["wire_fires"] == 40
    t = stats["transport"]
    assert t["wire_events_sent"] == 40
    assert t["loopback_events"] == 40
