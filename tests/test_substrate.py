"""Substrate layers: checkpoint store, synthetic data, optimizers,
sharding rules, step builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_optional import given, settings, st

from repro import checkpoint as ck
from repro.data import DataCfg, SyntheticLM
from repro.optim import OptCfg, make_optimizer
from repro.sharding import DEFAULT_RULES, fsdp_rules, resolve


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.float32(3.5), "d": [np.ones(2), np.zeros(3)]},
            "e": None}
    path = ck.save(str(tmp_path), 7, tree, extra={"cursor": 123})
    assert ck.latest_step(str(tmp_path)) == 7
    step, out, extra = ck.restore(str(tmp_path), tree)
    assert step == 7 and extra["cursor"] == 123
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["d"][0], tree["b"]["d"][0])
    assert out["e"] is None


def test_checkpoint_latest_pointer_advances(tmp_path):
    t = {"x": np.zeros(2)}
    ck.save(str(tmp_path), 1, t)
    ck.save(str(tmp_path), 2, t)
    assert ck.latest_step(str(tmp_path)) == 2
    step, _, _ = ck.restore(str(tmp_path), t, step=1)
    assert step == 1


# ------------------------------------------------------------------ data
def test_data_deterministic_and_topology_invariant():
    d = SyntheticLM(DataCfg(vocab=64, seq=16, global_batch=8, seed=3))
    b1 = d.batch(5, 0, 1)
    b2 = d.batch(5, 0, 1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # sharded batches tile the global batch
    s0 = d.batch(5, 0, 2)
    s1 = d.batch(5, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_has_learnable_structure():
    d = SyntheticLM(DataCfg(vocab=64, seq=64, global_batch=16, seed=3))
    b = d.batch(0)
    # bigram entropy must be far below uniform (log 64 = 4.16 nats)
    pairs = {}
    for row in np.stack([b["tokens"][:, :-1].ravel(),
                         b["tokens"][:, 1:].ravel()], 1):
        pairs.setdefault(row[0], []).append(row[1])
    ent = []
    for k, v in pairs.items():
        if len(v) < 8:
            continue
        _, counts = np.unique(v, return_counts=True)
        p = counts / counts.sum()
        ent.append(-(p * np.log(p)).sum())
    assert np.mean(ent) < 2.0


# ------------------------------------------------------------- optimizers
@pytest.mark.parametrize("name", ["adamw", "adamw8", "adafactor", "sgdm"])
def test_optimizer_reduces_quadratic(name):
    opt = make_optimizer(OptCfg(name=name, peak_lr=0.1, warmup=1,
                                total_steps=100, weight_decay=0.0))
    params = {"w": jnp.ones((8, 8)) * 3.0, "b": jnp.ones((8,))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for i in range(30):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params, jnp.asarray(i))
    assert float(loss(params)) < 0.5 * l0


def test_adamw8_state_is_int8():
    opt = make_optimizer(OptCfg(name="adamw8"))
    params = {"w": jnp.ones((16, 16))}
    st = opt.init(params)
    assert st["mu"]["w"]["m"].dtype == jnp.int8
    # abstract state matches concrete
    ab = opt.abstract_state({"w": jax.ShapeDtypeStruct((16, 16),
                                                       jnp.float32)})
    assert ab["mu"]["w"]["m"].shape == (16, 16)


# --------------------------------------------------------------- sharding
def test_resolve_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))  # single device: size-1 axes
    spec = resolve((8, 64), ("heads", "embed"), mesh, DEFAULT_RULES)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_resolve_conflict_drops_second():
    # both dims map to 'model': second must be dropped
    rules = dict(DEFAULT_RULES, embed="model", mlp="model")
    import jax.sharding as js
    devs = np.array(jax.devices() * 4)[:4] if len(jax.devices()) >= 4 \
        else None
    # build an abstract 4-way mesh via make_mesh if devices permit;
    # otherwise just exercise the code path with the host mesh
    mesh = jax.make_mesh((1,), ("model",))
    spec = resolve((16, 16), ("embed", "mlp"), mesh, rules)
    assert len(spec) == 2


@given(st.integers(1, 4), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_resolve_never_overshards(a, b):
    mesh = jax.make_mesh((1,), ("model",))
    spec = resolve((a * 3, b * 5), ("heads", "mlp"), mesh, DEFAULT_RULES)
    assert len(spec) == 2


def test_resolve_suffix_fallback():
    """32 experts on ('data','model')=mesh product that doesn't divide must
    fall back to a shardable suffix, not to full replication."""
    import numpy as np
    from jax.sharding import PartitionSpec
    # simulate with a 1x1 mesh: suffix fallback cannot find >1 divisor
    mesh = jax.make_mesh((1,), ("model",))
    rules = dict(DEFAULT_RULES, expert=("data", "model"))
    spec = resolve((32, 8, 8), ("expert", "embed", "moe_mlp"), mesh, rules)
    assert spec == PartitionSpec(None, None, None)


@pytest.mark.slow
def test_microbatch_clamp_respects_dp_extent():
    """The default microbatch count must keep per-mb batch >= pod*data."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
        import jax
        from repro.launch.cells import build_cell
        mesh = jax.make_mesh((2, 16, 2), ("pod", "data", "model"))
        # granite default mb=4: global 256 / 4 = 64 >= 32 dp -> kept
        c1 = build_cell("granite-moe-1b-a400m", "train_4k", mesh)
        assert c1.meta["microbatches"] == 4, c1.meta
        # deepseek default mb=32: 256/32 = 8 < 32 dp -> clamped to 8
        c2 = build_cell("deepseek-v3-671b", "train_4k", mesh)
        assert c2.meta["microbatches"] == 8, c2.meta
        # explicit override is never clamped (baseline reproduction)
        c3 = build_cell("deepseek-v3-671b", "train_4k", mesh,
                        microbatches=32)
        assert c3.meta["microbatches"] == 32, c3.meta
        print("CLAMP-OK")
    """)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "CLAMP-OK" in proc.stdout
