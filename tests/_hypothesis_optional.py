"""Import hypothesis if available, else skip-decorating stand-ins.

Lets test modules that mix property-based and plain tests keep their plain
tests runnable when hypothesis is not installed: only the ``@given`` tests
are skipped.  Usage::

    from _hypothesis_optional import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    def _skip_no_hypothesis(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    given = settings = _skip_no_hypothesis

    class _PlaceholderStrategies:
        """Placeholder strategies; never executed without hypothesis —
        any attribute resolves to an inert callable."""

        @staticmethod
        def _placeholder(*args, **kwargs):
            return None

        def __getattr__(self, name):
            return self._placeholder

    st = _PlaceholderStrategies()
