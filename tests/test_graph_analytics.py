"""Graph500 BFS + in-situ analytics correctness (incl. hypothesis property
test of EDAT BFS against networkx on random graphs)."""
import numpy as np
import pytest
from _hypothesis_optional import given, settings, st

from repro.analytics import BespokeAnalytics, EdatAnalytics, InsituCfg
from repro.graph import (EdatBFS, ReferenceBFS, build_csr, kronecker_edges,
                         validate_bfs_tree)


def test_kronecker_shapes():
    e = kronecker_edges(8, 16, seed=3)
    assert e.shape == (2, (1 << 8) * 16)
    assert e.max() < (1 << 8)


@pytest.mark.parametrize("ranks", [1, 2, 4])
def test_edat_bfs_matches_reference_reach(ranks):
    edges = kronecker_edges(9, 8, seed=5)
    n = 1 << 9
    csr = build_csr(edges, n, ranks)
    deg = np.bincount(np.concatenate([edges[0], edges[1]]), minlength=n)
    root = int(np.where(deg > 0)[0][0])
    pe = EdatBFS(csr).run(root)
    pr = ReferenceBFS(csr).run(root)
    assert ((pe >= 0) == (pr >= 0)).all()       # identical reachable set
    assert validate_bfs_tree(edges, pe, root)
    assert validate_bfs_tree(edges, pr, root)


@given(st.integers(10, 400), st.integers(0, 10_000), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_edat_bfs_vs_networkx(n_edges, seed, ranks):
    import networkx as nx
    rng = np.random.default_rng(seed)
    n = 64
    edges = rng.integers(0, n, size=(2, n_edges)).astype(np.int64)
    csr = build_csr(edges, n, ranks)
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(edges.T.tolist())
    g.remove_edges_from(nx.selfloop_edges(g))
    root = int(edges[0][0]) if edges[0][0] != edges[1][0] else int(
        edges[0][0])
    parent = EdatBFS(csr).run(root)
    reach_nx = set(nx.node_connected_component(g, root)) \
        if g.degree(root) > 0 or True else {root}
    reach = set(np.where(parent >= 0)[0].tolist())
    assert reach == reach_nx
    assert validate_bfs_tree(edges, parent, root)
    # BFS levels must match networkx shortest path lengths
    dist = nx.single_source_shortest_path_length(g, root)
    level = {root: 0}
    # derive levels from parent pointers
    def lvl(v, seen=()):
        if v in level:
            return level[v]
        level[v] = lvl(int(parent[v])) + 1
        return level[v]
    for v in reach:
        assert lvl(v) == dist[v], (v, lvl(v), dist[v])


def test_insitu_edat_results_correct():
    cfg = InsituCfg(n_analytics=2, items_per_producer=20, field_elems=64,
                    n_fields=2)
    res = EdatAnalytics(cfg).run()
    # every (field, timestep) must be reduced exactly once
    assert res["results"] == cfg.items_per_producer
    assert res["mean_latency_s"] > 0


def test_insitu_bespoke_results_correct():
    cfg = InsituCfg(n_analytics=2, items_per_producer=20, field_elems=64,
                    n_fields=2)
    res = BespokeAnalytics(cfg).run()
    assert res["results"] == cfg.items_per_producer
