"""Shared fault-injection test harness.

The kill/stall plumbing that used to be copy-pasted across the
distributed test files lives here:

* :func:`wait_for` / :func:`wait_for_file` — deadline-bounded condition
  polling (the ready-file handshake every SIGKILL test uses to prove the
  victim was genuinely mid-work before the kill);
* :func:`wait_for_history` — block until an in-proc trainer has really
  started stepping.  Killing "after 2 in ``alive``" at t=0 is vacuous:
  ``alive`` is empty until ``_init_state`` runs, and the first JIT can
  take seconds (see test_duplicate_recover_suppressed's history);
* :class:`Saboteur` — a background fault injector: runs ``fn`` after an
  optional predicate and delay, records any exception, and re-raises it
  at :meth:`join` so a broken saboteur fails the test instead of
  silently doing nothing;
* :func:`sigkill_when_ready` — the SIGKILL-at-phase pattern for spawned
  :class:`~repro.net.launch.ProcessGroup` runs: wait for the victim's
  ready file, let it settle into its stall, then kill its process;
* :func:`crash_socket` — simulate a process crash on a raw socket:
  ``shutdown(SHUT_RDWR)`` *then* close.  A plain ``close()`` does not
  send FIN while another duplicated fd still holds the connection, so
  the peer's failure detector would never fire;
* :func:`stall_spec` — the trainer's ``{rank: (step, seconds)}`` stall
  injection, named so tests read as intent;
* :func:`launch_replacement` / :func:`wait_for_join` — the elastic-join
  pattern: respawn the SIGKILLed process's ranks into the *running*
  world and block until the mesh splice is complete (the replacement's
  transport constructed, every survivor dialed back).
"""
from __future__ import annotations

import os
import socket
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple


def wait_for(pred: Callable[[], Any], timeout: float = 60.0,
             interval: float = 0.05, desc: str = "condition") -> Any:
    """Poll ``pred`` until it returns a truthy value; return that value.
    Raises ``TimeoutError`` (test fails fast, never wedges CI)."""
    deadline = time.monotonic() + timeout
    while True:
        val = pred()
        if val:
            return val
        if time.monotonic() >= deadline:
            raise TimeoutError(f"{desc} not met within {timeout}s")
        time.sleep(interval)


def wait_for_file(path: str, timeout: float = 60.0) -> None:
    """Wait until ``path`` exists — the victim-is-ready handshake."""
    wait_for(lambda: os.path.exists(path), timeout,
             desc=f"ready file {path!r}")


def wait_for_history(trainer, n: int = 1, timeout: float = 120.0) -> None:
    """Wait until an (in-proc) EventDrivenTrainer has recorded at least
    ``n`` metric events — i.e. training is genuinely under way (survives
    the multi-second first-JIT window where ``alive`` is still [])."""
    def some():
        with trainer._hist_mu:
            return len(trainer.history) >= n
    wait_for(some, timeout, desc=f"trainer history >= {n}")


class Saboteur:
    """Background fault injector.

    Runs ``fn()`` on a daemon thread once ``pred()`` (if given) holds and
    ``delay`` has elapsed.  Any exception (including a failed ``pred``
    wait) is captured and re-raised from :meth:`join`, so a saboteur that
    never managed to inject its fault fails the test loudly instead of
    letting it pass vacuously.
    """

    def __init__(self, fn: Callable[[], Any], *,
                 pred: Optional[Callable[[], Any]] = None,
                 delay: float = 0.0, timeout: float = 120.0,
                 name: str = "saboteur"):
        self.fn = fn
        self.pred = pred
        self.delay = delay
        self.timeout = timeout
        self.fired = threading.Event()
        self.error: Optional[BaseException] = None
        self._t = threading.Thread(target=self._run, daemon=True, name=name)

    def _run(self) -> None:
        try:
            if self.pred is not None:
                wait_for(self.pred, self.timeout, desc="saboteur trigger")
            if self.delay:
                time.sleep(self.delay)
            self.fn()
            self.fired.set()
        except BaseException as e:  # noqa: BLE001 - reported at join()
            self.error = e

    def start(self) -> "Saboteur":
        self._t.start()
        return self

    def join(self, timeout: float = 150.0) -> None:
        """Wait for the injection to have happened; re-raise its error."""
        self._t.join(timeout)
        if self.error is not None:
            raise self.error
        assert self.fired.is_set(), "saboteur never fired"


def sigkill_when_ready(pg, rank: int, ready_path: str, *,
                       timeout: float = 60.0,
                       settle: float = 0.2) -> float:
    """SIGKILL-at-phase for spawned process groups: wait until the victim
    touches ``ready_path`` (proving it reached the instrumented phase),
    give in-flight frames ``settle`` seconds, then kill the process
    hosting ``rank``.  Returns the kill timestamp (monotonic)."""
    wait_for_file(ready_path, timeout)
    time.sleep(settle)
    t0 = time.monotonic()
    pg.kill(rank)
    return t0


def crash_socket(sock: socket.socket) -> None:
    """Simulated crash: sever the connection without a clean BYE."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    sock.close()


def stall_spec(rank: int, at_step: int,
               seconds: float) -> Dict[int, Tuple[int, float]]:
    """Trainer stall injection: ``rank`` hangs ``seconds`` at
    ``at_step`` (its heartbeat pump goes silent too, like a real hang)."""
    return {rank: (at_step, seconds)}


def launch_replacement(pg, rank: int, workdir: str) -> str:
    """Elastic-join step 2 (after :func:`sigkill_when_ready` or
    ``pg.kill``): launch a replacement process for the dead one that
    hosted ``rank``.  Requires the group to have been started with
    ``elastic=True``.  Returns the ready-file path the replacement will
    touch once its mesh splice is complete — hand it to
    :func:`wait_for_join` before asserting anything about the rejoined
    world."""
    ready = os.path.join(workdir, f"rejoined_{rank}")
    pg.respawn(rank, ready_file=ready)
    return ready


def wait_for_join(ready_path: str, timeout: float = 60.0) -> None:
    """Block until an elastic replacement finished splicing into the
    running world: its transport is constructed, the coordinator re-armed
    the rank's failure handling, and every survivor accepted its dial.
    (The replayed backlog may still be draining — that is the durable
    layer's job, asserted via the log, not the splice's.)"""
    wait_for_file(ready_path, timeout)
