"""repro.net: socket transport, rendezvous, launcher, failure detection.

Three layers of coverage, cheapest first:

* transport-level unit tests over ``socket.socketpair()`` ends (no
  processes, no rendezvous);
* two full Runtimes over a socket pair *in one process* (threads), which
  exercises the whole distributed CONTROL protocol — status polls,
  terminate broadcast, abort propagation — without spawn overhead;
* real ``multiprocessing`` spawn runs through :mod:`repro.net.launch`,
  including a SIGKILL detected by the heartbeat/EOF failure detector.

Every cross-process test is guarded by the launcher's own join deadline
(and by pytest-timeout where installed): a hang fails, it never wedges CI.
"""
import functools
import os
import socket
import threading
import time

import pytest

import _chaos as chaos
from repro import edat
from repro.core.transport import CONTROL, EVENT, Message, Transport
from repro.net import SocketTransport, bootstrap
from repro.net.launch import ProcessGroup, launch_processes

pytestmark = pytest.mark.timeout(120)


def _pair(n_ranks=2, **kw):
    """Two SocketTransports joined by an AF_UNIX stream pair."""
    a, b = socket.socketpair()
    ta = SocketTransport(0, n_ranks, {1: a}, **kw)
    tb = SocketTransport(1, n_ranks, {0: b}, **kw)
    return ta, tb


def _ev(src, dst, eid, data=None):
    return Message(EVENT, src, dst, edat.Event(data=data, source=src,
                                               eid=eid))


# ------------------------------------------------------------ unit: framing
def test_socket_transport_fifo_and_batching():
    ta, tb = _pair()
    try:
        for i in range(20):
            assert ta.send(_ev(0, 1, "seq", i))
        ta.send_many([_ev(0, 1, "seq", i) for i in range(20, 40)])
        got = []
        deadline = time.monotonic() + 10
        while len(got) < 40 and time.monotonic() < deadline:
            got += [m.payload.data for m in tb.recv_many(1, timeout=1.0)]
        assert got == list(range(40))            # per-(src,dst) FIFO
        assert ta.sent_vector() == [0, 40]
        assert tb.recv_vector() == [40, 0]
        assert tb.pending(1) == 0
    finally:
        ta.close()
        tb.close()


def test_socket_transport_loopback_and_drain():
    ta, tb = _pair()
    try:
        ta.send_many([_ev(0, 0, "self", i) for i in range(5)])
        assert ta.pending(0) == 5
        msgs = ta.drain(0, max_n=3)
        assert [m.payload.data for m in msgs] == [0, 1, 2]
        assert [m.payload.data for m in ta.drain(0)] == [3, 4]
        assert ta.sent_vector()[0] == 5 and ta.recv_vector()[0] == 5
    finally:
        ta.close()
        tb.close()


def test_socket_transport_notify_hook():
    ta, tb = _pair()
    hits = threading.Event()
    try:
        tb.set_notify(1, hits.set)
        ta.send(_ev(0, 1, "x"))
        assert hits.wait(5.0)
    finally:
        ta.close()
        tb.close()


def test_socket_transport_control_not_counted():
    ta, tb = _pair()
    try:
        ta.send(Message(CONTROL, 0, 1, ("poke", None)))
        deadline = time.monotonic() + 5
        while tb.pending(1) == 0 and time.monotonic() < deadline:
            time.sleep(0.01)
        tb.drain(1)
        assert ta.sent_vector() == [0, 0]        # user events only
        assert tb.recv_vector() == [0, 0]
    finally:
        ta.close()
        tb.close()


def test_validate_payload_typeerror():
    ta, tb = _pair()
    try:
        with pytest.raises(TypeError, match="not.*picklable"):
            ta.validate_payload(lambda: None)
        ta.validate_payload({"fine": [1, 2.5, "x"]})
    finally:
        ta.close()
        tb.close()


def test_clean_close_is_not_a_failure():
    ta, tb = _pair()
    deaths = []
    tb.on_peer_dead = deaths.append
    ta.close()
    time.sleep(0.3)
    tb.close()
    assert deaths == []                          # BYE suppressed the verdict


def test_abrupt_close_declares_peer_dead():
    a, b = socket.socketpair()
    ta = SocketTransport(0, 2, {1: a})
    tb = SocketTransport(1, 2, {0: b})
    deaths = []
    tb.on_peer_dead = deaths.append
    chaos.crash_socket(a)                        # simulated crash: no BYE
    deadline = time.monotonic() + 5
    while not deaths and time.monotonic() < deadline:
        time.sleep(0.01)
    assert deaths == [0] and tb.is_dead(0)
    assert not tb.send(_ev(1, 0, "x"))           # drops, counted
    assert tb.dropped == 1
    tb.close()
    ta.close()


def test_heartbeat_detects_silent_peer():
    """Pure heartbeat-timeout path: the connection stays open but rank 0
    never beats (hb_interval=0 disables its sender)."""
    a, b = socket.socketpair()
    ta = SocketTransport(0, 2, {1: a}, hb_interval=0)
    tb = SocketTransport(1, 2, {0: b}, hb_interval=0.1, hb_timeout=0.6)
    deaths = []
    tb.on_peer_dead = deaths.append
    deadline = time.monotonic() + 10
    while not deaths and time.monotonic() < deadline:
        time.sleep(0.02)
    assert deaths == [0] and tb.is_dead(0)
    tb.close()
    ta.close()


# --------------------------------------------------- rendezvous (threads)
def test_bootstrap_all_pairs_mesh():
    n = 3
    coord = ("127.0.0.1", 0)
    # pre-pick a coordinator port the threads can share
    srv = socket.socket()
    srv.bind(coord)
    port = srv.getsockname()[1]
    srv.close()
    out = {}

    def boot(rank):
        t = bootstrap(rank, n, ("127.0.0.1", port), timeout=20)
        out[rank] = t

    ths = [threading.Thread(target=boot, args=(r,)) for r in range(n)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(30)
    assert sorted(out) == [0, 1, 2]
    try:
        # every ordered pair can talk
        for src in range(n):
            for dst in range(n):
                if src != dst:
                    assert out[src].send(_ev(src, dst, f"e{src}{dst}", src))
        for dst in range(n):
            seen = set()
            deadline = time.monotonic() + 10
            while len(seen) < n - 1 and time.monotonic() < deadline:
                for m in out[dst].recv_many(dst, timeout=1.0):
                    seen.add(m.payload.data)
            assert seen == set(range(n)) - {dst}
    finally:
        for t in out.values():
            t.close()


# ------------------------------- full distributed protocol, in one process
def _dual_runtime_run(main, *, n=2, progress="thread", timeout=30.0, **kw):
    """Two Runtimes over a socket pair, one thread each — the complete
    cross-process CONTROL protocol without spawn overhead."""
    ta, tb = _pair(n)
    rts = [edat.Runtime(n, transport=ta, progress=progress, **kw),
           edat.Runtime(n, transport=tb, progress=progress, **kw)]
    results = [None, None]

    def go(i):
        try:
            # transport injection is below the Session surface: drive the
            # runtime's internal entry point directly, not the v1 shim
            results[i] = ("ok", rts[i]._run_internal(main, timeout=timeout))
        except BaseException as e:  # noqa: BLE001
            results[i] = ("err", e)

    ths = [threading.Thread(target=go, args=(i,)) for i in range(2)]
    for t in ths:
        t.start()
    for t in ths:
        t.join(timeout + 15)
        assert not t.is_alive(), "distributed run wedged"
    return results


def test_distributed_pingpong_and_stats_broadcast():
    N = 50
    got = []

    def ping(ctx, events):
        if events[0].data < N:
            ctx.fire(1, "ping", events[0].data + 1)

    def pong(ctx, events):
        got.append(events[0].data)
        ctx.fire(0, "pong", events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(ping, deps=[(1, "pong")])
            ctx.fire(1, "ping", 1)
        else:
            ctx.submit_persistent(pong, deps=[(0, "ping")])

    res = _dual_runtime_run(main, unconsumed="ignore")
    assert [r[0] for r in res] == ["ok", "ok"]
    assert got == list(range(1, N + 1))          # FIFO across the wire
    # rank 1 received rank 0's stats via the terminate broadcast
    assert res[1][1]["events_sent"] == res[0][1]["events_sent"] > 0


def test_distributed_worker_poll_progress():
    got = []

    def sink(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "e")])
        else:
            for i in range(20):
                ctx.fire(0, "e", i)

    res = _dual_runtime_run(main, progress="worker")
    assert [r[0] for r in res] == ["ok", "ok"]
    assert got == list(range(20))


def test_fire_unpicklable_raises_at_fire_over_socket():
    """Satellite: a non-picklable payload fails *inside the firing task*
    with TypeError, and the run still terminates cleanly (the counters
    were never touched)."""
    outcome = {}

    def bad_then_good(ctx, events):
        try:
            ctx.fire(1, "bad", lambda: None)
        except TypeError as e:
            outcome["err"] = str(e)
            ctx.fire(1, "ok", 7)

    def sink(ctx, events):
        outcome["got"] = events[0].data

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(bad_then_good)
        else:
            ctx.submit(sink, deps=[(0, "ok")])

    res = _dual_runtime_run(main)
    assert [r[0] for r in res] == ["ok", "ok"]
    assert "picklable" in outcome["err"]
    assert outcome["got"] == 7


def test_fire_unpicklable_inproc_keeps_copy_semantics():
    """The in-proc transport still accepts anything copyable (no pickle
    requirement): same payload, no error."""
    got = []

    def sink(ctx, events):
        got.append(events[0].data())

    def main(ctx):
        if ctx.rank == 0:
            ctx.fire(1, "fn", lambda: 42, ref=True)
        else:
            ctx.submit(sink, deps=[(0, "fn")])

    with edat.Session(2, workers_per_rank=2) as s:
        s.run(main, timeout=30)
    assert got == [42]


def test_task_error_propagates_to_peer_process():
    def boom(ctx, events):
        raise ValueError("kaboom")

    def main(ctx):
        if ctx.rank == 1:
            ctx.submit(boom)

    res = _dual_runtime_run(main)
    assert [r[0] for r in res] == ["err", "err"]
    # rank 1 raised locally; rank 0 got the abort CONTROL message
    assert "kaboom" in str(res[0][1])
    assert isinstance(res[0][1], edat.EdatTaskError)


def test_timer_pending_on_remote_rank_delays_termination():
    """fire_after on rank 1 targeting rank 0: the detector (rank 0) must
    see rank 1's pending timer through the status replies and hold
    termination until the event lands."""
    got = []

    def tick(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(tick, deps=[(1, "tick")])
        else:
            ctx.fire_after(0.4, 0, "tick", 9)

    res = _dual_runtime_run(main)
    assert [r[0] for r in res] == ["ok", "ok"]
    assert got == [9]


def test_deadlock_detected_across_ranks():
    def never(ctx, events):  # pragma: no cover
        pass

    def main(ctx):
        if ctx.rank == 1:
            ctx.submit(never, deps=[(0, "never")])

    res = _dual_runtime_run(main, timeout=20)
    assert [r[0] for r in res] == ["err", "err"]
    assert isinstance(res[0][1], edat.EdatDeadlockError)
    assert isinstance(res[1][1], edat.EdatDeadlockError)


def test_socket_fire_and_forget_snapshot():
    """Remote fires skip the deep-copy (the wire pickle is the snapshot):
    mutating the buffer right after ctx.fire must not be observable."""
    import numpy as np
    got = {}

    def sink(ctx, events):
        got["v"] = list(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            buf = np.array([1, 2, 3])
            ctx.fire(1, "e", buf)
            buf[:] = 99
        else:
            ctx.submit(sink, deps=[(0, "e")])

    res = _dual_runtime_run(main)
    assert [r[0] for r in res] == ["ok", "ok"]
    assert got["v"] == [1, 2, 3]


def test_mark_dead_stops_inbound_delivery():
    """mark_dead must actually sever the connection (shutdown, not a
    refcounted close): nothing sent by the dead-marked peer may be
    delivered afterwards."""
    ta, tb = _pair()
    try:
        tb.mark_dead(0)
        assert tb.is_dead(0)
        ta.send(_ev(0, 1, "late", 1))
        time.sleep(0.3)
        assert tb.pending(1) == 0
        assert tb.drain(1) == []
    finally:
        ta.close()
        tb.close()


# ----------------------------------------------- minimal-Transport fallback
class MinimalTransport(Transport):
    """The least a transport can be: send/recv/wake only.  Everything else
    — send_many, drain, recv_many, notify, failure hooks — comes from the
    Transport base class defaults."""

    def __init__(self, n_ranks):
        self._boxes = [[] for _ in range(n_ranks)]
        self._cv = threading.Condition()

    def send(self, msg):
        with self._cv:
            self._boxes[msg.dst].append(msg)
            self._cv.notify_all()
        return True

    def recv(self, rank, timeout):
        with self._cv:
            if not self._boxes[rank]:
                self._cv.wait(timeout)
            if self._boxes[rank]:
                return self._boxes[rank].pop(0)
            return None

    def wake(self, rank):
        with self._cv:
            self._cv.notify_all()


@pytest.mark.parametrize("progress", ["thread", "worker"])
def test_minimal_transport_end_to_end(progress):
    """Satellite: an end-to-end run through the base-class batching
    defaults; in worker mode there is no notify hook, so this also covers
    the timed-poll progress fallback."""
    N = 30
    got = []

    def pong(ctx, events):
        got.append(events[0].data)
        ctx.fire(0, "pong", events[0].data)

    def ping(ctx, events):
        if events[0].data < N:
            ctx.fire(1, "ping", events[0].data + 1)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(ping, deps=[(1, "pong")])
            ctx.fire(1, "ping", 1)
        else:
            ctx.submit_persistent(pong, deps=[(0, "ping")])

    rt = edat.Runtime(2, transport=MinimalTransport(2), progress=progress,
                      unconsumed="ignore")
    rt._run_internal(main, timeout=60)
    assert got == list(range(1, N + 1))


# ------------------------------------------------- real spawned processes
def _ring_main(ctx, n_hops=100):
    left = (ctx.rank - 1) % ctx.n_ranks

    def relay(c, events):
        if events[0].data < n_hops:
            c.fire((c.rank + 1) % c.n_ranks, "token", events[0].data + 1)

    ctx.submit_persistent(relay, deps=[(left, "token")])
    if ctx.rank == 0:
        ctx.fire(1, "token", 1)


def test_launch_processes_four_rank_ring():
    stats = launch_processes(
        4, functools.partial(_ring_main, n_hops=100), timeout=60)
    assert stats["events_sent"] == stats["events_received"] == 100
    assert stats["tasks_executed"] == 100
    assert stats["run_seconds"] > 0


def test_coordinator_port_race_bind_retry(monkeypatch):
    """Regression for the _free_port TOCTOU race: the launcher probes a
    free port, releases it, and only later does the rank-0 child bind it
    as the coordinator — another process can squat it in the gap.  Here
    the test pre-occupies exactly the probed port with a listening
    socket and releases it ~1s in; the coordinator's bind-with-retry on
    EADDRINUSE must ride out the squatter instead of crashing the
    world (which is what the old single-shot bind did)."""
    from repro.net import launch as launch_mod

    squatter = socket.socket()
    squatter.bind(("127.0.0.1", 0))
    squatter.listen(1)
    port = squatter.getsockname()[1]
    monkeypatch.setattr(launch_mod, "_free_port",
                        lambda host="127.0.0.1": port)
    releaser = chaos.Saboteur(squatter.close, delay=2.5,
                              name="port-squatter").start()
    try:
        stats = launch_processes(
            2, functools.partial(_ring_main, n_hops=20), timeout=60)
    finally:
        releaser.join()
    assert stats["events_sent"] == 20            # the run really happened


def _stuck_main(ctx, ready_path=""):
    def on_fail(c, events):
        pass

    ctx.submit(on_fail, deps=[(edat.ANY, edat.RANK_FAILED)])
    if ctx.rank == 3:
        open(ready_path, "w").close()
        time.sleep(300)          # never finishes: must be SIGKILLed


def test_process_kill_detected_by_heartbeat(tmp_path):
    """Acceptance: a kill_rank-equivalent process kill is detected by the
    failure detector; survivors get RANK_FAILED and terminate cleanly."""
    ready = str(tmp_path / "ready")
    pg = ProcessGroup(4, functools.partial(_stuck_main, ready_path=ready),
                      run_timeout=60, hb_interval=0.2, hb_timeout=1.5)
    pg.start()
    chaos.sigkill_when_ready(pg, 3, ready, timeout=60, settle=0.3)
    stats = pg.wait(60)
    codes = pg.exitcodes()
    assert codes[3] != 0                      # the victim
    assert codes[0] == codes[1] == codes[2] == 0
    assert stats["tasks_executed"] == 3       # one RANK_FAILED per survivor


def _boom_main(ctx):
    def boom(c, events):
        raise ValueError("spawned-boom")

    if ctx.rank == 1:
        ctx.submit(boom)


def test_spawned_task_error_fails_every_rank():
    with pytest.raises(RuntimeError, match="spawned-boom"):
        launch_processes(2, _boom_main, timeout=30)


# ------------------------------------------------- coalescing invariants
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_coalescing_fifo_no_loss_randomized(seed):
    """Per-(src,dst) FIFO and zero event loss must hold across randomized
    batch boundaries (tiny max_batch_bytes forces frame splits; a nonzero
    flush_interval makes the writer batch aggressively) and across forced
    partial ``drain()``s on the receiver — for every encode path: deferred
    (immutable ints), snapshot (mutable dicts), and owned/zero-copy
    (numpy with ref semantics)."""
    import random
    rng = random.Random(seed)
    import numpy as np
    kw = dict(flush_interval=rng.choice([0.0, 0.001]),
              max_batch_bytes=rng.choice([128, 4096, 1 << 20]))
    ta, tb = _pair(**kw)
    N = 400
    try:
        i = 0
        while i < N:
            burst = min(rng.randrange(1, 12), N - i)
            msgs = []
            for k in range(i, i + burst):
                style = rng.randrange(3)
                if style == 0:       # deferred path (immutable payload)
                    m = _ev(0, 1, "seq", k)
                elif style == 1:     # snapshot path (mutable payload)
                    m = _ev(0, 1, "seq", {"i": k})
                else:                # owned path (zero-copy oob numpy)
                    m = _ev(0, 1, "seq", np.array([k], np.int64))
                    m.owned = True
                msgs.append(m)
            if rng.random() < 0.5:
                for m in msgs:
                    assert ta.send(m)
            else:
                assert ta.send_many(msgs) == len(msgs)
            i += burst
        got = []
        deadline = time.monotonic() + 30
        while len(got) < N and time.monotonic() < deadline:
            if rng.random() < 0.5:
                out = tb.drain(1, max_n=rng.randrange(1, 7))  # forced partial
                if not out:
                    time.sleep(0.002)
            else:
                out = tb.recv_many(1, timeout=0.2)
            for m in out:
                d = m.payload.data
                if isinstance(d, dict):
                    got.append(d["i"])
                elif isinstance(d, int):
                    got.append(d)
                else:
                    got.append(int(d[0]))
        assert got == list(range(N)), f"loss/reorder with {kw}"
        assert ta.sent_vector() == [0, N]
        assert tb.recv_vector() == [N, 0]
    finally:
        ta.close()
        tb.close()


def test_coalescing_snapshot_at_fire_mutable_payload():
    """A mutable payload mutated right after send() must arrive with its
    fire-time value: the coalescing layer snapshots (pickles) non-owned
    payloads synchronously inside send, not in the writer thread."""
    import numpy as np
    ta, tb = _pair(flush_interval=0.05)  # writer waits: mutation races it
    try:
        buf = np.array([1, 2, 3])
        assert ta.send(_ev(0, 1, "snap", {"buf": buf}))
        buf[:] = 99  # post-fire mutation must not be observable
        deadline = time.monotonic() + 10
        got = []
        while not got and time.monotonic() < deadline:
            got = tb.recv_many(1, timeout=0.5)
        assert list(got[0].payload.data["buf"]) == [1, 2, 3]
    finally:
        ta.close()
        tb.close()


def test_coalescing_owned_numpy_arrives_writable():
    """Owned (ref) numpy payloads travel zero-copy and must reconstruct
    as writable arrays on the receiving side."""
    import numpy as np
    ta, tb = _pair()
    try:
        m = _ev(0, 1, "z", np.arange(1000, dtype=np.float32))
        m.owned = True
        assert ta.send(m)
        deadline = time.monotonic() + 10
        got = []
        while not got and time.monotonic() < deadline:
            got = tb.recv_many(1, timeout=0.5)
        arr = got[0].payload.data
        np.testing.assert_array_equal(arr,
                                      np.arange(1000, dtype=np.float32))
        arr[:] = 0.0  # raises if the zero-copy view came back read-only
    finally:
        ta.close()
        tb.close()


def test_transport_flush_drains_queue():
    ta, tb = _pair(flush_interval=0.02)
    try:
        ta.send_many([_ev(0, 1, "f", i) for i in range(50)])
        assert ta.flush(timeout=10.0)
        deadline = time.monotonic() + 5
        got = []
        while len(got) < 50 and time.monotonic() < deadline:
            got += tb.recv_many(1, timeout=0.5)
        assert len(got) == 50
    finally:
        ta.close()
        tb.close()


@pytest.mark.parametrize("progress", ["thread", "worker"])
def test_distributed_coalesced_stream_both_modes(progress):
    """End-to-end dual-Runtime run over the coalescing transport in both
    progress modes: a mixed stream (fire + fire_batch, plain + ref numpy
    payloads) keeps FIFO order and loses nothing."""
    import numpy as np
    N = 60
    got = []

    def sink(ctx, events):
        d = events[0].data
        got.append(int(d["i"]) if isinstance(d, dict) else int(d[0]))

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit_persistent(sink, deps=[(1, "s")])
        else:
            i = 0
            while i < N:
                if i % 3 == 0:
                    ctx.fire(0, "s", np.array([i], np.int64), ref=True)
                    i += 1
                else:
                    n = min(3, N - i)
                    ctx.fire_batch([(0, "s", {"i": i + k})
                                    for k in range(n)])
                    i += n

    res = _dual_runtime_run(main, progress=progress)
    assert [r[0] for r in res] == ["ok", "ok"]
    assert got == list(range(N))


# --------------------------------- drop accounting when a peer dies mid-run
def test_drop_queue_exactly_once_under_enqueue_race():
    """Events parked on a dead peer's coalescing queue are counted dropped
    exactly once — whether the death verdict drained them, or the enqueue
    lost the race and observed the queue's dead flag."""
    ta, tb = _pair(coalesce=True, flush_interval=5.0)
    try:
        for i in range(5):
            assert ta.send(_ev(0, 1, "q", i))
        # all 5 sit unwritten (the writer waits out flush_interval)
        ta._declare_proc_dead(1)
        assert ta.dropped == 5                  # drained queue, counted once
        # an enqueue that lost the race against the verdict accounts its
        # own items instead of parking them on the dead queue
        ta._enqueue(1, [_ev(0, 1, "q", 99)])
        assert ta.dropped == 6
        t0 = time.monotonic()
        assert ta.flush(timeout=5.0) is True    # nothing left to drain
        assert time.monotonic() - t0 < 1.0
    finally:
        ta.close()
        tb.close()


def test_flush_unblocks_when_peer_dies_mid_drain():
    ta, tb = _pair(coalesce=True, flush_interval=5.0)
    try:
        for i in range(3):
            assert ta.send(_ev(0, 1, "q", i))
        res = {}

        def fl():
            res["ok"] = ta.flush(timeout=10.0)

        th = threading.Thread(target=fl)
        th.start()
        time.sleep(0.2)                 # flush is now waiting on the queue
        ta._declare_proc_dead(1)
        th.join(3.0)
        assert not th.is_alive(), "flush hung on a dead peer's queue"
        assert ta.dropped == 3          # the waited-on events were counted
    finally:
        ta.close()
        tb.close()


def _flood_main(ctx, ready_path=""):
    if ctx.rank == 0:
        def sink(c, events):
            if not os.path.exists(ready_path):
                open(ready_path, "w").close()
        ctx.submit_persistent(sink, deps=[(1, "flood")])
        ctx.submit(lambda c, e: None, deps=[(edat.ANY, edat.RANK_FAILED)])
    else:
        payload = b"x" * 512
        for _ in range(20000):
            ctx.fire(0, "flood", payload)


def test_kill_mid_flood_terminates_with_balanced_drops(tmp_path):
    """Chaos: SIGKILL the producer while its coalescing queue is loaded.
    The round must still reach global termination well inside the run
    deadline — which it only can if every in-flight event was counted
    either received or dropped (the Mattern condition), i.e. nothing was
    double-counted or lost by the queue-drop path."""
    from repro import edat as _edat
    ready = str(tmp_path / "ready")
    with _edat.Session(2, transport="socket", timeout=120,
                       hb_interval=0.2, hb_timeout=1.5, unconsumed="ignore",
                       flush_interval=0.005, max_batch_bytes=32768) as s:
        s.start(functools.partial(_flood_main, ready_path=ready))
        deadline = time.monotonic() + 60
        while not os.path.exists(ready) and time.monotonic() < deadline:
            time.sleep(0.01)
        assert os.path.exists(ready), "flood never reached the sink"
        s.kill(1)
        t0 = time.monotonic()
        stats = s.wait(timeout=60, check=False)
        assert time.monotonic() - t0 < 45      # terminated, not timed out
        assert s.exitcodes()[1] not in (None, 0)
        assert stats.get("events_received", 0) > 0
