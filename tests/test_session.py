"""v2 API acceptance: one Session entry point, typed channels, task
handles, driver-side futures — and the v1 deprecation shims.

* parity matrix: one small program through ``edat.run`` across
  {inproc, socket} x {1 proc, 2 procs} produces identical results;
* typed channels: a fire to an undeclared eid (when the program declares
  channels) fails fast with KeyError; a payload-type mismatch fails with
  TypeError at fire time; raw string eids keep working (anonymous
  channels);
* ``ctx.submit`` returns a TaskHandle; ``Session.call`` returns a Future
  resolved by an event fired at task return;
* the facade exports the collectives and timers (no deep imports);
* deprecation shims (``Runtime.run``, ``distributed_bfs``,
  ``distributed_insitu``, ``distributed_train``) warn exactly once per
  call site with unchanged behaviour.
"""
import functools
import os
import time
import warnings

import numpy as np
import pytest

from repro import edat

pytestmark = pytest.mark.timeout(300)


# ---------------------------------------------------------------- programs
class RingSum:
    """Tiny deterministic program: every rank fires (rank+1)^2 on the
    typed ``val`` channel; rank 0 gathers the sum.  Module-level and
    picklable, so the same object runs on every transport."""

    channels = (edat.Channel("val", payload=int),
                edat.Channel("sum", payload=int))

    def __init__(self, n: int):
        self.n = n
        self.total = None
        self.per_rank = {}

    def start(self, ctx):
        if ctx.rank == 0:
            ctx.submit(self._gather,
                       deps=[(r, "val") for r in range(ctx.n_ranks)],
                       name="gather")
        ctx.fire(0, "val", (ctx.rank + 1) ** 2)

    def _gather(self, ctx, events):
        for e in events:
            self.per_rank[e.source] = e.data
        self.total = sum(e.data for e in events)

    def result(self):
        return {"total": self.total,
                "per_rank": dict(sorted(self.per_rank.items()))}


class TypoProgram(RingSum):
    def start(self, ctx):
        ctx.fire(0, "vall", 1)       # not a declared channel


class BadPayloadProgram(RingSum):
    def start(self, ctx):
        ctx.fire(0, "val", "not-an-int")


def make_ringsum(n):
    return RingSum(n)


# ------------------------------------------------------------ parity matrix
@pytest.mark.parametrize("transport,procs", [("inproc", None), ("inproc", 1),
                                             ("socket", 1), ("socket", 2)])
def test_run_parity_matrix(transport, procs):
    """The same program through edat.run on every transport/placement
    combination yields identical results (inproc has no process packing,
    so its cells are procs=None/1)."""
    res = edat.run(edat.deferred(make_ringsum, 4), ranks=4, procs=procs,
                   transport=transport, timeout=120)
    assert res == {"total": 30, "per_rank": {0: 1, 1: 4, 2: 9, 3: 16}}


def test_procs_with_inproc_fails_fast():
    """Forgetting transport='socket' must not silently run as threads."""
    with pytest.raises(ValueError, match="socket"):
        edat.Session(4, procs=2)


def test_falsy_program_still_runs():
    """A program object that is falsy (e.g. subclasses a container) must
    not be mistaken for 'no program'."""
    class DictProgram(dict):
        def start(self, ctx):
            ctx.submit(lambda c, e: self.__setitem__("ran", True))

        def result(self):
            return dict(self)

    res = edat.run(DictProgram(), ranks=1)
    assert res == {"ran": True}


# ------------------------------------------------------------ typed channels
def test_channel_is_str_and_interned():
    ch = edat.Channel("grad", payload=dict)
    assert isinstance(ch, str) and ch == "grad"
    assert hash(ch) == hash("grad")      # routes exactly like the raw eid


def test_channel_reserved_prefix_rejected():
    with pytest.raises(ValueError):
        edat.Channel("__internal")


def test_channel_payload_validation_direct():
    ch = edat.Channel("grad", payload=np.ndarray)
    ch.validate(np.zeros(3))             # ok
    ch.validate(None)                    # events without payload are fine
    with pytest.raises(TypeError):
        ch.validate([1, 2, 3])


def test_fire_undeclared_eid_raises_keyerror():
    """A typo'd eid fails fast (KeyError surfaced through the run's
    EdatTaskError) instead of silently never matching."""
    with pytest.raises(edat.EdatTaskError, match="declared channel"):
        edat.run(TypoProgram(1), ranks=1)


def test_fire_payload_type_mismatch_raises():
    with pytest.raises(edat.EdatTaskError, match="expects payload"):
        edat.run(BadPayloadProgram(1), ranks=1)


def test_raw_string_eids_still_work_without_declaration():
    """Anonymous channels: plain mains with raw string eids run with no
    enforcement, exactly as in v1."""
    got = []

    def sink(ctx, events):
        got.append(events[0].data)

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(sink, deps=[(1, "anything")])
        else:
            ctx.fire(0, "anything", 7)

    stats = edat.run(main, ranks=2)
    assert got == [7] and stats["events_sent"] == 1


# ------------------------------------------------- task handles and futures
def test_submit_returns_removable_task_handle():
    removed = []

    def never(ctx, events):          # pragma: no cover - must not run
        raise AssertionError("removed task executed")

    def main(ctx):
        h = ctx.submit_persistent(never, deps=[(edat.SELF, "x")],
                                  name="doomed")
        assert isinstance(h, edat.TaskHandle)
        assert h.persistent and h.name == "doomed"
        removed.append(h.remove())
        anon = ctx.submit(lambda c, e: None)
        assert anon.remove() is False        # unnamed: nothing to remove

    edat.run(main, ranks=1)
    assert removed == [True]


def test_session_call_future_resolves_from_task_return():
    with edat.Session(ranks=2) as s:
        fut = s.call(1, lambda ctx, events: ctx.rank * 100 + events[0].data,
                     deps=[(0, "seed")])

        def main(ctx):
            if ctx.rank == 0:
                ctx.fire(1, "seed", 7)

        s.run(main)
        assert fut.done() and fut.result() == 107


def test_future_result_drives_the_round():
    """Future.result() on a not-yet-run session triggers a calls-only
    round (blocking driver-side composition)."""
    with edat.Session(ranks=2) as s:
        fut = s.call(1, lambda ctx, events: 41 + 1)
        assert not fut.done()
        assert fut.result() == 42


# ----------------------------------------------------------- facade exports
def test_facade_exports_patterns_and_timers():
    """The collectives and timers are importable from the facade — no
    more deep repro.core.patterns imports."""
    for name in ("barrier", "wait_barrier", "allreduce", "tree_reduce",
                 "fire_after", "TimerHandle", "TaskHandle", "Channel",
                 "Session", "Program", "deferred"):
        assert hasattr(edat, name), name

    sums = []

    def main(ctx):
        edat.allreduce(ctx, "s", ctx.rank + 1, lambda a, b: a + b,
                       lambda c, acc: sums.append((c.rank, acc)))
        h = edat.fire_after(ctx, 0.01, edat.SELF, "tick")
        assert isinstance(h, edat.TimerHandle)
        ctx.submit(lambda c, e: None, deps=[(edat.SELF, "tick")])

    edat.run(main, ranks=2, workers_per_rank=2)
    assert sorted(sums) == [(0, 3), (1, 3)]


# -------------------------------------------------------- deprecation shims
def test_runtime_run_warns_once_per_call_site_and_behaves():
    def main(ctx):
        ctx.submit(lambda c, e: None, deps=[(edat.SELF, "e")])
        ctx.fire(edat.SELF, "e", 1)

    results = []
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for _ in range(2):               # same call site, twice
            rt = edat.Runtime(1)
            results.append(rt.run(main))
    depr = [x for x in w if issubclass(x.category, DeprecationWarning)
            and "Runtime.run" in str(x.message)]
    assert len(depr) == 1, [str(x.message) for x in w]
    assert all(r["events_sent"] == 1 for r in results)  # behaviour intact


def test_distributed_bfs_shim_warns_and_matches_reference():
    from repro.graph import (ReferenceBFS, build_csr, distributed_bfs,
                             kronecker_edges)
    with pytest.warns(DeprecationWarning, match="distributed_bfs"):
        # n_procs is a v1 launcher kwarg: the shim must keep accepting it
        parent, info = distributed_bfs(2, 7, 8, seed=5, n_procs=1)
    edges = kronecker_edges(7, 8, 5)
    ref = ReferenceBFS(build_csr(edges, 1 << 7, 2)).run(info["root"])
    assert np.array_equal(parent, ref)
    assert info["traversed"] > 0 and info["teps"] > 0


def test_distributed_insitu_shim_warns_and_behaves():
    from repro.analytics import InsituCfg, distributed_insitu
    cfg = InsituCfg(n_analytics=1, items_per_producer=8, field_elems=64,
                    n_fields=2)
    with pytest.warns(DeprecationWarning, match="distributed_insitu"):
        res = distributed_insitu(cfg)
    assert res["results"] == cfg.items_per_producer
    assert res["raw_items"] == cfg.items_per_producer


def test_trainer_program_adopts_session_rank_count():
    """The README v2 idiom: TrainerCfg left at its default n_ranks must
    adopt the session's actual rank count at attach (the session is
    authoritative, as it was for the v1 distributed_train helper)."""
    from repro.runtime_dist.trainer import _demo_cfgs, trainer_program
    model_cfg, data_cfg, opt_cfg, tcfg = _demo_cfgs(2, 1, None)
    assert tcfg.n_ranks == 2
    tr = trainer_program(model_cfg, data_cfg, opt_cfg, tcfg)
    with edat.Session(3, unconsumed="ignore", timeout=240,
                      workers_per_rank=tcfg.workers_per_rank) as s:
        s.run(tr)
        res = s.gather()
    assert tr.cfg.n_ranks == 3
    assert sorted(res["final_params"]) == [0, 1, 2]
    assert all(m["n_grads"] == 3 for m in res["history"])


def test_distributed_train_shim_warns_and_behaves(tmp_path):
    from repro.runtime_dist import TrainerCfg, distributed_train
    from repro.data import DataCfg
    from repro.models import ModelCfg
    from repro.optim import OptCfg
    tiny = ModelCfg(name="tiny", family="dense", n_layers=1, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    vocab=64, dtype="float32", remat="none",
                    max_target_length=32)
    data = DataCfg(vocab=64, seq=16, global_batch=4, seed=7)
    opt = OptCfg(name="adamw", peak_lr=3e-2, warmup=2, total_steps=50,
                 clip_norm=1.0)
    with pytest.warns(DeprecationWarning, match="distributed_train"):
        res = distributed_train(
            2, tiny, data, opt,
            TrainerCfg(steps=2, n_ranks=2, collect_timeout=60.0),
            n_procs=1, timeout=240.0, out_dir=str(tmp_path / "out"))
    assert max(m["step"] for m in res["history"]) >= 2
    assert sorted(res["final_params"]) == [0, 1]
    # the deprecated path still persists the old on-disk layout,
    # including the per-rank final step
    assert (tmp_path / "out" / "history.json").exists()
    with np.load(tmp_path / "out" / "final_rank0.npz") as z:
        assert int(z["step"]) >= 2 and len(z.files) > 1


# --------------------------------------------- future timeout / dead ranks
def _slow_call(ctx, events):
    time.sleep(2.0)
    return 42


def _ready_then_hang(ctx, events, path=None):
    open(path, "w").close()            # handshake: the driver may kill now
    time.sleep(60)
    return "unreachable"               # pragma: no cover - rank is killed


@pytest.mark.timeout(120)
def test_future_result_timeout_is_retryable():
    """result(timeout) on a still-running socket round raises TimeoutError
    without tearing the round down: a later result() call succeeds."""
    with edat.Session(ranks=2, transport="socket", timeout=60) as s:
        fut = s.call(1, _slow_call)
        s.start(None)                  # calls-only round, non-blocking
        t0 = time.monotonic()
        with pytest.raises(TimeoutError, match="retry"):
            fut.result(timeout=0.3)
        assert time.monotonic() - t0 < 1.5   # soft join, no SIGKILL wait
        assert fut.result(timeout=60) == 42  # round finished; same future


@pytest.mark.timeout(120)
def test_future_result_names_dead_rank(tmp_path):
    """When the callee rank's process dies before the call's task returns,
    result() raises RankDiedError naming the rank — not a bare timeout."""
    ready = str(tmp_path / "ready")
    with edat.Session(ranks=2, transport="socket", timeout=60,
                      hb_interval=0.2, hb_timeout=1.5) as s:
        fut = s.call(1, functools.partial(_ready_then_hang, path=ready))
        s.start(None)
        deadline = time.monotonic() + 30
        while not os.path.exists(ready) and time.monotonic() < deadline:
            time.sleep(0.02)
        assert os.path.exists(ready), "callee task never started"
        s.kill(1)
        s.wait(check=False)            # survivors terminate the round
        with pytest.raises(edat.RankDiedError, match="rank 1"):
            fut.result()
