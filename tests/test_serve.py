"""Acceptance for ``repro.serve``: event-driven LM serving.

* loadgen units: deterministic schedules, unique ids, honest summaries;
* engine units: the two bugfixes at the KV-cache layer — a dead slot's
  position stays pinned, and ``attach`` fully overwrites a reused slot;
* regression (duplicate decode chains): a 2-client burst must satisfy
  ``tick_execs == engine steps`` *exactly*.  Pre-fix code fired a new
  self-sustaining ``decode_tick`` chain per admission; the extra chains
  surface as tick executions that find no live slot and step nothing,
  breaking the equality;
* regression (stale KV on slot reuse): with fewer slots than requests,
  every served token stream must match a fresh sequential server
  token-for-token.  Pre-fix code spliced nothing on admit (a reused slot
  decoded against its previous occupant's attention state) and advanced
  dead slots' positions unboundedly;
* parity matrix: the same load through ``Session(ranks=3)`` on inproc
  and socket/2-procs produces the sequential baseline's exact greedy
  tokens;
* live backpressure: an offered rate the slots cannot sustain trips the
  event-carried ``backpressure`` channel and the
  ``admission-backpressure`` insights rule;
* chaos: SIGKILL one client mid-load — the server purges the dead
  client's queue, drains its live slots, and the round terminates
  cleanly with no leaked slots.
"""
import os
import sys
import time

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
import _chaos as chaos                                    # noqa: E402

from repro import edat                                    # noqa: E402
from repro.configs import ARCHS, reduce_cfg               # noqa: E402
from repro.serve import (DEFAULT_MAX_LEN, LoadSpec,       # noqa: E402
                         SequentialEngine, ServeEngine, all_requests,
                         client_schedule, percentile, run_sequential,
                         run_serve, serve_program, summarize)

pytestmark = pytest.mark.timeout(600)

ARCH = "gemma3-1b"
MAX_LEN = 48


@pytest.fixture(scope="module")
def cfg():
    return reduce_cfg(ARCHS[ARCH].cfg)


# ---------------------------------------------------------------- loadgen
def test_schedule_deterministic_unique_sorted():
    spec = LoadSpec(rps=10, requests=13, seed=3)
    a = client_schedule(spec, 0, 3, vocab=512)
    b = client_schedule(spec, 0, 3, vocab=512)
    assert a == b                               # regenerable exactly
    assert spec.split(3) == [5, 4, 4]
    merged = all_requests(spec, 3, vocab=512)
    assert len(merged) == 13
    assert len({r["id"] for r in merged}) == 13
    assert [r["t"] for r in merged] == sorted(r["t"] for r in merged)
    for r in merged:
        assert len(r["prompt"]) in spec.prompt_lens
        assert spec.max_new_lo <= r["max_new"] <= spec.max_new_hi
        assert all(0 <= t < 512 for t in r["prompt"])


def test_clients_draw_different_streams():
    spec = LoadSpec(rps=10, requests=8, seed=0)
    a = client_schedule(spec, 0, 2, vocab=512)
    b = client_schedule(spec, 1, 2, vocab=512)
    assert [r["prompt"] for r in a] != [r["prompt"] for r in b]


def test_percentile_nearest_rank():
    assert percentile([], 99) == 0.0
    xs = list(range(1, 101))
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 100.0
    assert percentile(xs, 50) == 51.0           # nearest-rank on 0..99


def test_summarize_measures_from_schedule_time():
    recs = [{"t_sched": 0.0, "t_first": 0.5, "t_done": 1.5, "n_out": 11}]
    s = summarize(recs, 2.0)
    assert s["requests"] == 1 and s["tokens"] == 11
    assert s["ttft_p50_ms"] == pytest.approx(500.0)
    assert s["per_token_p50_ms"] == pytest.approx(100.0)
    assert s["tokens_per_s"] == pytest.approx(5.5)


# ----------------------------------------------------------------- engine
def test_engine_dead_slot_pos_pinned(cfg):
    """The unbounded-position bug: stepping the batch must not advance a
    slot that has no live request, or an idle slot walks its cache write
    pointer to max_len and corrupts the next occupant."""
    eng = ServeEngine(cfg, slots=2, max_len=MAX_LEN)
    prompt = list(range(1, 9))
    first, pc = eng.prefill(prompt)
    eng.attach(0, len(prompt), first, pc)
    assert int(eng.pos[1, 0]) == 0
    for _ in range(5):
        eng.step([0])
    assert int(eng.pos[0, 0]) == len(prompt) + 5
    assert int(eng.pos[1, 0]) == 0              # dead slot pinned


def test_engine_slot_reuse_matches_fresh(cfg):
    """The stale-KV bug: serve request A in slot 0, then admit B into the
    same slot — B's tokens must equal a fresh engine's, i.e. ``attach``
    really resets every cache leaf of the slot."""
    eng = ServeEngine(cfg, slots=1, max_len=MAX_LEN)
    rng = np.random.default_rng(7)

    def serve(e, prompt, n):
        first, pc = e.prefill(prompt)
        e.attach(0, len(prompt), first, pc)
        out = [first]
        for _ in range(n - 1):
            out.append(int(e.step([0])[0]))
        return out

    pa = rng.integers(0, cfg.vocab, size=8).tolist()
    pb = rng.integers(0, cfg.vocab, size=12).tolist()
    serve(eng, pa, 10)                          # occupy + dirty slot 0
    reused = serve(eng, pb, 10)                 # reuse the slot
    fresh = serve(ServeEngine(cfg, slots=1, max_len=MAX_LEN), pb, 10)
    assert reused == fresh


# ----------------------------------------------------- program regressions
def test_single_decode_chain_under_burst():
    """Duplicate-chain regression: every ``decode_tick`` execution must
    step the batch (``tick_execs == steps`` exactly).  Without the
    ``_ticking`` guard each admission starts another chain; once the
    batch drains, the surplus chains' ticks execute against an empty
    batch and the equality breaks."""
    load = LoadSpec(rps=1000.0, requests=8, prompt_lens=(4, 8),
                    max_new_lo=4, max_new_hi=8, seed=1)
    out = run_serve(arch=ARCH, clients=2, slots=4, max_len=MAX_LEN,
                    load=load, transport="inproc")
    res = out["result"]
    assert res["served"] == 8
    assert res["slots_leaked"] == 0 and res["queue_left"] == 0
    assert res["tick_execs"] == res["steps"], (
        "extra no-op decode_tick executions: more than one chain ran")
    # 8 requests of <= 8 tokens through 4 slots: if every tick does
    # batch work, far fewer ticks than serving one token per tick
    assert res["steps"] <= 2 * 8 * 8


def _seq_tokens(cfg, load, clients):
    reqs = all_requests(load, clients, cfg.vocab)
    recs = run_sequential(cfg, reqs, max_len=MAX_LEN, realtime=False)
    return {r["id"]: r["tokens"] for r in recs}


@pytest.mark.parametrize("transport,procs", [("inproc", None),
                                             ("socket", 2)])
def test_tokens_match_sequential_baseline(cfg, transport, procs):
    """Parity matrix (stale-KV regression at the session level): 2 slots
    for 7 requests forces slot reuse; every response must carry exactly
    the greedy tokens a fresh one-at-a-time server produces, on both
    transports."""
    load = LoadSpec(rps=50.0, requests=7, prompt_lens=(4, 8, 12),
                    max_new_lo=3, max_new_hi=8, seed=2)
    out = run_serve(arch=ARCH, clients=2, slots=2, max_len=MAX_LEN,
                    load=load, transport=transport, procs=procs)
    res = out["result"]
    assert res["served"] == 7 and res["slots_leaked"] == 0
    got = {r["id"]: r["tokens"] for r in res["records"]}
    assert got == _seq_tokens(cfg, load, 2)


def test_backpressure_throttles_and_insights_flag_it():
    """One slot against an offered rate it cannot sustain (long outputs,
    arrivals faster than drains): the admission queue must cross its
    bound, fire ``backpressure`` to the clients — who must measurably
    gate their schedule on it — and the run's own counters must trip the
    ``admission-backpressure`` insights rule."""
    from repro.insights import analyze
    load = LoadSpec(rps=20.0, requests=16, prompt_lens=(4,),
                    max_new_lo=24, max_new_hi=32, seed=0)
    out = run_serve(arch=ARCH, clients=2, slots=1, max_len=MAX_LEN,
                    load=load, queue_bound=2, transport="inproc")
    res = out["result"]
    assert res["served"] == 16 and res["slots_leaked"] == 0
    assert res["bp_signals"] >= 1
    throttled = sum(r["throttled_s"] for r in res["records"])
    assert throttled > 0                 # clients genuinely gated
    rules = [f.rule for f in analyze(out["stats"])]
    assert "admission-backpressure" in rules


# ------------------------------------------------------------------- chaos
def test_client_sigkill_drains_cleanly(tmp_path):
    """SIGKILL one of two client processes once the server has admitted
    its first request.  The server's RANK_FAILED task purges the dead
    client's queue; its live slots drain; the survivor's whole schedule
    is served; the round terminates with no leaked slots."""
    ready = str(tmp_path / "ready")
    load = LoadSpec(rps=10.0, requests=12, prompt_lens=(4, 8),
                    max_new_lo=4, max_new_hi=8, seed=4)
    with edat.Session(3, procs=3, transport="socket", timeout=300,
                      workers_per_rank=2, unconsumed="ignore",
                      hb_interval=0.2, hb_timeout=1.5) as s:
        s.start(edat.deferred(serve_program, arch=ARCH, slots=2,
                              max_len=MAX_LEN, load=load,
                              ready_file=ready, ready_after=1))
        chaos.sigkill_when_ready(s, 2, ready, timeout=120, settle=0.2)
        s.wait(240, check=False)
        codes = s.exitcodes()
        res = s.gather()
    assert codes[2] not in (None, 0)            # the victim died by kill
    assert codes[0] == 0 and codes[1] == 0      # server + survivor: clean
    assert res["dead"] == [2]
    assert res["slots_leaked"] == 0 and res["queue_left"] == 0
    # the surviving client (rank 1 == loadgen client 0) got everything
    cfg = reduce_cfg(ARCHS[ARCH].cfg)
    survivor_ids = {r["id"] for r in client_schedule(load, 0, 2,
                                                     cfg.vocab)}
    served_ids = {r["id"] for r in res["records"]}
    assert survivor_ids <= served_ids


def test_server_sigkill_clients_surface_rankdied(tmp_path):
    """SIGKILL the *server* process (rank 0 — also the termination
    coordinator) once it has admitted its first request.  The clients
    cannot finish — nobody will ever broadcast terminate — but they must
    not hang either: each client runtime raises ``RankDiedError`` naming
    rank 0, and the launcher treats that as an orderly child outcome
    (a ``rankdied`` report, exit code 0)."""
    ready = str(tmp_path / "ready")
    load = LoadSpec(rps=10.0, requests=12, prompt_lens=(4, 8),
                    max_new_lo=4, max_new_hi=8, seed=4)
    with edat.Session(3, procs=3, transport="socket", timeout=300,
                      workers_per_rank=2, unconsumed="ignore",
                      hb_interval=0.2, hb_timeout=1.5) as s:
        s.start(edat.deferred(serve_program, arch=ARCH, slots=2,
                              max_len=MAX_LEN, load=load,
                              ready_file=ready, ready_after=1))
        chaos.sigkill_when_ready(s, 0, ready, timeout=120, settle=0.2)
        s.wait(240, check=False)
        codes = s.exitcodes()
        res = s.gather()
        reports = s._last_pg.child_reports
    assert codes[0] not in (None, 0)            # the server died by kill
    assert codes[1] == 0 and codes[2] == 0      # clients: orderly exit
    assert res is None                          # rank 0 never finalized
    died = sorted(r for r in reports if r[0] == "rankdied")
    assert [r[1] for r in died] == [1, 2]       # both clients reported
    for r in died:
        assert "rank 0" in r[2] and "termination coordinator" in r[2]
