"""Property/fuzz tests for the trainer's QuorumCollector.

The collector is the numerical heart of the elastic trainer: whatever
order gradient events arrive in (loopback vs socket, stragglers, leftover
pre-recovery traffic), the applied update must equal the reference
weighted mean

    (sum(fresh) + d * sum(stale)) / (n_fresh + d * n_stale)

Seeded-random fuzz always runs; the hypothesis properties engage when
hypothesis is installed (same optional pattern as test_net_frames.py).
"""
import random

import numpy as np
import pytest

from _hypothesis_optional import given, settings, st
from repro.runtime_dist import QuorumCollector

RNG_TREE_KEYS = ("w", "b", "emb")


def _tree(rng, scale=1.0):
    """A small parameter-tree-shaped pytree of float32 arrays."""
    return {k: np.asarray(rng.standard_normal((3, 2)) * scale, np.float32)
            for k in RNG_TREE_KEYS}


def _reference_mean(fresh, stale, discount):
    """Independent computation of the invariant (no tree.map, no fold
    order): element-wise over each leaf."""
    weight = len(fresh) + discount * len(stale)
    out = {}
    for k in RNG_TREE_KEYS:
        acc = np.zeros((3, 2), np.float64)
        for g in fresh.values():
            acc += g[k].astype(np.float64)
        for g in stale:
            acc += discount * g[k].astype(np.float64)
        out[k] = acc / weight
    return out


def _payload(rank, step, epoch, grads):
    return {"rank": rank, "step": step, "epoch": epoch, "grads": grads}


def _check_reduce(coll, fresh, stale, discount, rtol=1e-5):
    gavg, n_got, n_stale = coll.reduce()
    assert n_got == len(fresh) and n_stale == len(stale)
    ref = _reference_mean(fresh, stale, discount)
    for k in RNG_TREE_KEYS:
        np.testing.assert_allclose(np.asarray(gavg[k]), ref[k], rtol=rtol,
                                   atol=1e-6)


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_arrival_orders_match_reference_mean(seed):
    """Random fresh/stale/garbage payloads offered in a random order:
    the reduction equals the reference weighted mean, and garbage
    (other epochs, future steps) is rejected."""
    rng = np.random.default_rng(seed)
    pyrng = random.Random(seed)
    n_ranks = pyrng.randrange(2, 7)
    step = pyrng.randrange(1, 50)
    epoch = pyrng.randrange(0, 3)
    discount = pyrng.choice([0.0, 0.25, 0.5, 1.0])

    fresh = {r: _tree(rng) for r in range(n_ranks)}
    stale = [_tree(rng) for _ in range(pyrng.randrange(0, 4))]
    # (payload, should_be_accepted)
    payloads = [(_payload(r, step, epoch, g), True)
                for r, g in fresh.items()]
    payloads += [(_payload(pyrng.randrange(n_ranks), step - 1 - i, epoch, g),
                  True) for i, g in enumerate(stale)]
    payloads += [
        (_payload(0, step, epoch + 1, _tree(rng)), False),  # wrong epoch
        (_payload(1, step, epoch - 1, _tree(rng)), False),  # pre-recovery
        (_payload(2, step + 1, epoch, _tree(rng)), False),  # future step
    ]
    pyrng.shuffle(payloads)

    coll = QuorumCollector(step=step, epoch=epoch, need=n_ranks,
                           stale_discount=discount)
    for p, expect in payloads:
        assert coll.offer(p) == expect, p
    assert coll.complete
    _check_reduce(coll, fresh, stale, discount)


@pytest.mark.parametrize("n_ranks,quorum", [(4, 1.0), (5, 0.5), (3, 0.34),
                                            (6, 0.01)])
def test_k_of_n_quorum_boundary(n_ranks, quorum):
    """complete flips exactly at K = max(1, ceil(quorum * N)) fresh
    gradients; stale gradients never count toward the quorum."""
    rng = np.random.default_rng(0)
    need = max(1, int(np.ceil(quorum * n_ranks)))
    coll = QuorumCollector(step=5, epoch=0, need=need, stale_discount=0.5)
    coll.offer(_payload(0, 4, 0, _tree(rng)))          # stale: no credit
    assert not coll.complete
    for i in range(need):
        assert not coll.complete
        coll.offer(_payload(i, 5, 0, _tree(rng)))
    assert coll.complete
    # a duplicate from the same rank must not inflate the count
    n_before = len(coll.got)
    coll.offer(_payload(0, 5, 0, _tree(rng)))
    assert len(coll.got) == n_before


def test_stale_discount_weighting_explicit():
    """Hand-checked bounded-staleness case: 2 fresh + 1 stale at
    discount 0.5 -> (a + b + 0.5*c) / 2.5."""
    ones = {k: np.ones((3, 2), np.float32) for k in RNG_TREE_KEYS}
    twos = {k: 2 * np.ones((3, 2), np.float32) for k in RNG_TREE_KEYS}
    eights = {k: 8 * np.ones((3, 2), np.float32) for k in RNG_TREE_KEYS}
    coll = QuorumCollector(step=3, epoch=1, need=2, stale_discount=0.5)
    coll.offer(_payload(1, 2, 1, eights))              # late: discounted
    coll.offer(_payload(0, 3, 1, ones))
    coll.offer(_payload(2, 3, 1, twos))
    gavg, n_got, n_stale = coll.reduce()
    assert (n_got, n_stale) == (2, 1)
    expect = (1.0 + 2.0 + 0.5 * 8.0) / 2.5
    for k in RNG_TREE_KEYS:
        np.testing.assert_allclose(np.asarray(gavg[k]), expect, rtol=1e-6)


def test_ensure_own_only_fills_missing():
    rng = np.random.default_rng(1)
    mine, theirs = _tree(rng), _tree(rng)
    coll = QuorumCollector(step=0, epoch=0, need=1, stale_discount=0.5)
    coll.ensure_own(0, mine)
    assert coll.got[0] is mine
    coll2 = QuorumCollector(step=0, epoch=0, need=1, stale_discount=0.5)
    coll2.offer(_payload(0, 0, 0, theirs))
    coll2.ensure_own(0, mine)                          # loopback won: no-op
    assert coll2.got[0] is theirs


def test_reduce_deterministic_across_arrival_orders():
    """Same payload set, two shuffles -> bit-identical reduction (fresh
    gradients fold in rank order, not arrival order) — the property the
    distributed-vs-in-proc equivalence test leans on."""
    rng = np.random.default_rng(3)
    fresh = {r: _tree(rng) for r in range(5)}
    stale = [(s, r, _tree(rng)) for s, r in ((1, 0), (2, 3), (1, 4))]
    payloads = ([_payload(r, 3, 0, g) for r, g in fresh.items()]
                + [_payload(r, s, 0, g) for s, r, g in stale])
    results = []
    for order in (payloads, list(reversed(payloads))):
        coll = QuorumCollector(step=3, epoch=0, need=5, stale_discount=0.5)
        for p in order:
            coll.offer(p)
        results.append(coll.reduce()[0])
    for k in RNG_TREE_KEYS:
        a = np.asarray(results[0][k])
        b = np.asarray(results[1][k])
        assert np.array_equal(a, b), "fold order leaked into the mean"


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_hypothesis_permutation_invariance(data):
    """Property: for any fresh/stale multiset and any arrival
    permutation, reduce() equals the reference weighted mean."""
    n_ranks = data.draw(st.integers(2, 6), label="n_ranks")
    n_stale = data.draw(st.integers(0, 3), label="n_stale")
    discount = data.draw(st.sampled_from([0.0, 0.25, 0.5, 1.0]),
                         label="discount")
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    rng = np.random.default_rng(seed)
    fresh = {r: _tree(rng) for r in range(n_ranks)}
    stale = [_tree(rng) for _ in range(n_stale)]
    payloads = [_payload(r, 7, 2, g) for r, g in fresh.items()]
    payloads += [_payload(0, 6, 2, g) for g in stale]
    payloads = data.draw(st.permutations(payloads), label="arrival")
    coll = QuorumCollector(step=7, epoch=2, need=n_ranks,
                           stale_discount=discount)
    for p in payloads:
        assert coll.offer(p)
    _check_reduce(coll, fresh, stale, discount)
