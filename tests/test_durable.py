"""repro.durable: the task log, automated replay, and elastic join.

Layered like the subsystem itself:

* log backends (:class:`MemoryLog` / :class:`SqliteLog`) — idempotent
  appends, the pending diff, replay-target override semantics;
* :class:`BatchLogger` — the off-hot-path writer thread;
* in-proc automated replay — ``Runtime(durable=True)`` +
  ``kill_rank``: the dead rank's unconsumed events land on survivors
  and the program converges to the uninterrupted result;
* cross-process chaos — the :mod:`repro.durable.demo` work queue,
  SIGKILLed mid-run, recovered both by survivor-only replay and by an
  elastically-joined replacement process;
* the ``tests/_chaos.py`` elastic-join helpers.
"""
from __future__ import annotations

import os
import threading
import time

import pytest

from repro.core.event import ANY, RANK_FAILED
from repro.durable.log import (BatchLogger, COMPLETED, FIRED, MemoryLog,
                               REPLAYED, SqliteLog, open_log)

from tests._chaos import Saboteur, launch_replacement, wait_for_join

pytestmark = pytest.mark.timeout(180)


# ------------------------------------------------------------ log backends
def _mk_log(kind, tmp_path):
    if kind == "memory":
        return MemoryLog()
    return SqliteLog(str(tmp_path / "log.sqlite"))


@pytest.fixture(params=["memory", "sqlite"])
def log(request, tmp_path):
    lg = _mk_log(request.param, tmp_path)
    yield lg
    lg.close()


def test_log_append_idempotent(log):
    rec = ("k1", FIRED, "ch", 0, 1, b"x")
    log.append_many([rec])
    log.append_many([rec])            # at-least-once logging double-appends
    assert log.count(FIRED) == 1


def test_log_pending_is_fired_minus_completed(log):
    log.append_many([("k1", FIRED, "ch", 0, 1, b"a"),
                     ("k2", FIRED, "ch", 0, 2, b"b"),
                     ("k1", COMPLETED, "ch", 0, 1, None)])
    pend = log.pending()
    assert [r[0] for r in pend] == ["k2"]
    assert pend[0][5] == b"b"
    assert log.pending(rank=1) == []          # k1 completed, k2 is 0->2
    assert [r[0] for r in log.pending(rank=2)] == ["k2"]
    # the source rank also matches the filter (its death strands the fire)
    assert [r[0] for r in log.pending(rank=0)] == ["k2"]


def test_log_replayed_overrides_target_keeps_blob(log):
    log.append_many([("k1", FIRED, "ch", 0, 2, b"payload")])
    # the coordinator logs the re-fire with a None blob (the payload is
    # already in the fired record) and the new destination
    log.append_many([("k1", REPLAYED, "ch", 0, 3, None)])
    pend = log.pending()
    assert len(pend) == 1
    key, kind, eid, src, dst, blob = pend[0]
    assert (key, dst, blob) == ("k1", 3, b"payload")
    # a second replay re-targets again: latest wins
    log.append_many([("k1", REPLAYED, "ch", 0, 1, None)])
    assert log.pending()[0][4] == 1
    # completion (on the replayed target) clears it
    log.append_many([("k1", COMPLETED, "ch", 0, 1, None)])
    assert log.pending() == []


def test_log_eid_targets(log):
    log.append_many([("k1", FIRED, "a", 0, 1, None),
                     ("k2", FIRED, "a", 0, 2, None),
                     ("k3", FIRED, "b", 0, 3, None),
                     ("k3", REPLAYED, "b", 0, 1, None)])
    t = log.eid_targets()
    assert t["a"] == {1, 2}
    assert t["b"] == {1, 3}


def test_sqlite_log_shared_across_connections(tmp_path):
    path = str(tmp_path / "shared.sqlite")
    a, b = SqliteLog(path), SqliteLog(path)
    try:
        a.append_many([("k1", FIRED, "ch", 0, 1, b"x")])
        b.append_many([("k1", COMPLETED, "ch", 0, 1, None),
                       ("k2", FIRED, "ch", 0, 1, b"y")])
        assert a.count(FIRED) == 2
        assert [r[0] for r in a.pending()] == ["k2"]
    finally:
        a.close()
        b.close()


def test_open_log_factory(tmp_path):
    mem = open_log(None)
    assert mem.kind == "memory"
    sq = open_log(str(tmp_path / "f.sqlite"))
    assert sq.kind == "sqlite"
    sq.close()


# ------------------------------------------------------------- BatchLogger
def test_batch_logger_lands_everything():
    lg = BatchLogger(MemoryLog())
    n = 500
    for i in range(n):
        lg.append((f"k{i}", FIRED, "ch", 0, 1, None))
    assert lg.flush(10.0)
    assert lg.log.count(FIRED) == n
    assert lg.appends == n
    # the writer drains whole runs per backend call: far fewer batches
    # than records (exact count is scheduling-dependent)
    assert 1 <= lg.batches <= n
    lg.close()


def test_batch_logger_append_many_and_close():
    lg = BatchLogger(MemoryLog())
    lg.append_many([(f"k{i}", FIRED, "ch", 0, 1, None) for i in range(32)])
    lg.close()                        # close implies flush
    assert lg.log.count(FIRED) == 32


def test_batch_logger_concurrent_appenders():
    lg = BatchLogger(MemoryLog())
    def pump(tag):
        for i in range(200):
            lg.append((f"{tag}/{i}", FIRED, "ch", 0, 1, None))
    ts = [threading.Thread(target=pump, args=(t,)) for t in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert lg.flush(10.0)
    assert lg.log.count(FIRED) == 800
    lg.close()


# ------------------------------------------------- in-proc automated replay
class _Queue:
    """Minimal durable work fan-out (the demo's WorkQueue, in-proc)."""

    def __init__(self, items):
        self.items = items
        self.results = {}
        self.mu = threading.Lock()

    def __call__(self, ctx):
        ctx.submit_persistent(lambda c, e: None,
                              deps=[(ANY, RANK_FAILED)], name="sink")
        if ctx.rank == 0:
            ctx.submit_persistent(self._collect, deps=[(ANY, "done")],
                                  name="collect")
            for i in range(self.items):
                ctx.fire(1 + i % (ctx.n_ranks - 1), "work",
                         {"id": i, "x": i})
        else:
            ctx.submit_persistent(self._work, deps=[(ANY, "work")],
                                  name="work")

    def _work(self, ctx, events):
        d = events[0].data
        if ctx.rank == 2:             # dawdle: widens the kill window
            time.sleep(0.15)
        ctx.fire(0, "done", {"id": d["id"], "val": d["x"] * d["x"] + 1})

    def _collect(self, ctx, events):
        d = events[0].data
        with self.mu:                 # at-least-once: dedup by item id
            self.results.setdefault(d["id"], d["val"])


def test_inproc_replay_on_kill_rank():
    """kill_rank mid-run: the durable coordinator re-fires the dead
    rank's unconsumed events onto survivors and the run converges to the
    uninterrupted result with nothing pending in the log."""
    from repro import edat
    n = 24
    prog = _Queue(n)
    with edat.Session(4, workers_per_rank=1, unconsumed="ignore",
                      durable=True, timeout=60.0) as s:
        rt = s.runtime
        dur = rt._durable
        assert dur is not None and dur.log.kind == "memory"
        # survivors complete within milliseconds while rank 2 dawdles
        # 0.15s per item: the kill reliably lands with most of rank 2's
        # queue unconsumed
        sab = Saboteur(
            lambda: rt.kill_rank(2),
            pred=lambda: dur.log.count(COMPLETED) >= 2,
            name="kill-rank-2").start()
        s.run(prog)
        sab.join()
        assert prog.results == {i: i * i + 1 for i in range(n)}
        dur.logger.flush()
        assert dur.log.pending() == []
        assert dur.log.count(REPLAYED) >= 1
        # per-channel replay accounting names the channel and dead rank
        assert any(r["dead_rank"] == 2 and r["channel"] in ("work", "done")
                   for r in dur.replays)


def test_inproc_durable_disabled_by_default():
    """No durable kwarg: the runtime never builds DurableState and events
    carry no idempotency key."""
    from repro import edat
    seen = {}
    def main(ctx):
        if ctx.rank == 0:
            ctx.fire(1, "ping", 7)
        else:
            def t(c, evs):
                seen["dkey"] = "_dkey" in evs[0].__dict__
            ctx.submit(t, deps=[(0, "ping")])
    with edat.Session(2, workers_per_rank=1, timeout=30.0) as s:
        rt = s.runtime
        s.run(main)
        assert rt._durable is None
    assert seen == {"dkey": False}


def test_per_channel_durable_optin():
    """Channel(..., durable=True) activates durable mode lazily for just
    that channel: its fires are journaled, others are not."""
    from repro import edat
    dur_ch = edat.Channel("optin.work", durable=True)
    plain = edat.Channel("optin.plain")
    got = []
    def main(ctx):
        ctx.declare_channels([dur_ch, plain])
        if ctx.rank == 0:
            ctx.fire(1, dur_ch, {"i": 1})
            ctx.fire(1, plain, {"i": 2})
        else:
            ctx.submit_persistent(lambda c, e: got.append(e[0].data["i"]),
                                  deps=[(ANY, dur_ch)], name="w")
            ctx.submit_persistent(lambda c, e: got.append(e[0].data["i"]),
                                  deps=[(ANY, plain)], name="p")
    with edat.Session(2, workers_per_rank=1, unconsumed="ignore",
                      timeout=30.0) as s:
        rt = s.runtime
        s.run(main)
        dur = rt._durable
        assert dur is not None
        dur.logger.flush()
        assert dur.log.count(FIRED) == 1      # only the durable channel
    assert sorted(got) == [1, 2]


# -------------------------------------------------- cross-process chaos
def _report_msg(report):
    return (f"result={report['result']} expected={report['expected']} "
            f"pending={report['pending']} replayed={report['replayed']} "
            f"exitcodes={report['exitcodes']} workdir={report['workdir']}")


@pytest.mark.slow
def test_chaos_survivor_replay(tmp_path):
    """SIGKILL the process hosting the dawdling rank; survivors absorb
    the replayed backlog (no replacement) and the result matches the
    uninterrupted run exactly."""
    from repro.durable.demo import run_chaos
    report = run_chaos(ranks=4, procs=2, items=32, kill=2, replace=False,
                       kill_after=0.3, timeout=90.0,
                       workdir=str(tmp_path), verbose=False)
    assert report["ok"], _report_msg(report)
    assert report["replayed"] >= 1
    assert not report["rejoined"]


@pytest.mark.slow
def test_chaos_elastic_join(tmp_path):
    """Same kill, but a replacement process is launched mid-run and
    elastically joins: it re-hosts the dead ranks, drains the replayed
    backlog, and the world converges with zero leaked tasks."""
    from repro.durable.demo import run_chaos
    report = run_chaos(ranks=4, procs=2, items=32, kill=2, replace=True,
                       kill_after=0.3, timeout=90.0,
                       workdir=str(tmp_path), verbose=False)
    assert report["ok"], _report_msg(report)
    assert report["replayed"] >= 1
    assert report["rejoined"], "replacement never completed its splice"
    # the replacement exits 0 like everyone else
    assert all(c == 0 for c in report["exitcodes"].values()), \
        report["exitcodes"]


@pytest.mark.slow
def test_chaos_helpers_drive_elastic_join(tmp_path):
    """The tests/_chaos.py helpers end-to-end: gate the kill on real
    progress, launch_replacement + wait_for_join splice a new process
    into the running world, and the run converges."""
    from repro.durable.demo import (WorkQueue, expected,
                                    wait_for_completions)
    from repro.net.launch import ProcessGroup
    import pickle

    items, kill = 32, 2
    db = str(tmp_path / "durable.sqlite")
    out = str(tmp_path / "result.pkl")
    prog = WorkQueue(items, stall_rank=kill, stall_s=0.05, out_path=out)
    pg = ProcessGroup(4, prog, n_procs=2, run_timeout=90.0, elastic=True,
                      hb_interval=0.1, hb_timeout=1.0, workers_per_rank=1,
                      unconsumed="ignore",
                      durable={"path": db, "join_timeout": 15.0})
    pg.start()
    assert wait_for_completions(db, rank=kill, timeout=45.0)
    time.sleep(0.3)
    pg.kill(kill)
    ready = launch_replacement(pg, kill, str(tmp_path))
    wait_for_join(ready, timeout=45.0)
    pg.wait(check=False)
    assert all(c == 0 for c in pg.exitcodes().values()), pg.exitcodes()
    with open(out, "rb") as f:
        got = pickle.load(f)
    assert got == expected(items)
    lg = SqliteLog(db)
    try:
        assert lg.pending() == []
    finally:
        lg.close()


def test_respawn_requires_elastic():
    from repro.net.launch import ProcessGroup
    pg = ProcessGroup(2, lambda ctx: None, n_procs=1, run_timeout=30.0)
    with pytest.raises(RuntimeError, match="elastic"):
        pg.respawn(0)


def test_wait_for_join_times_out(tmp_path):
    with pytest.raises(TimeoutError):
        wait_for_join(str(tmp_path / "never"), timeout=0.3)
