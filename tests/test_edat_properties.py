"""Property-based tests (hypothesis) for EDAT runtime invariants.

Invariants checked on randomly generated well-formed programs:
 1. every fired transitory event is consumed exactly once;
 2. every transitory task with satisfiable deps executes exactly once;
 3. per-(src,dst) FIFO delivery order holds under arbitrary interleavings;
 4. the runtime always terminates (no spurious deadlock) for well-formed
    programs.
"""
import threading
from collections import defaultdict

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import edat


@st.composite
def programs(draw):
    """A random well-formed EDAT program: a bipartite (fires, tasks) spec
    where every fired event is consumed by exactly one task slot."""
    n_ranks = draw(st.integers(2, 4))
    n_events = draw(st.integers(1, 24))
    fires = []   # (src, dst, eid, value)
    slots = defaultdict(int)  # (dst, src, eid) -> count
    for i in range(n_events):
        src = draw(st.integers(0, n_ranks - 1))
        dst = draw(st.integers(0, n_ranks - 1))
        eid = f"e{draw(st.integers(0, 5))}"
        fires.append((src, dst, eid, i))
        slots[(dst, src, eid)] += 1
    # build tasks on each dst consuming exactly the fired multiset
    tasks = defaultdict(list)  # rank -> list of dep-lists
    for (dst, src, eid), count in slots.items():
        remaining = count
        while remaining:
            take = draw(st.integers(1, remaining))
            tasks[dst].append([(src, eid)] * take)
            remaining -= take
    # optionally merge some dep-lists into multi-dep tasks
    for r in list(tasks):
        if len(tasks[r]) >= 2 and draw(st.booleans()):
            a = tasks[r].pop()
            tasks[r][0].extend(a)
    return n_ranks, fires, dict(tasks)


@given(programs())
@settings(max_examples=25, deadline=None)
def test_exactly_once_and_termination(prog):
    n_ranks, fires, tasks = prog
    executed = []
    consumed = []
    mu = threading.Lock()

    def mk_task():
        def t(ctx, events):
            with mu:
                executed.append(1)
                consumed.extend(e.data for e in events)
        return t

    def main(ctx):
        for dep_list in tasks.get(ctx.rank, []):
            ctx.submit(mk_task(), deps=dep_list)
        for (src, dst, eid, val) in fires:
            if src == ctx.rank:
                ctx.fire(dst, eid, val)

    with edat.Session(n_ranks, workers_per_rank=2, timeout=60) as s:
        stats = s.run(main)
    total_tasks = sum(len(v) for v in tasks.values())
    assert len(executed) == total_tasks                      # (2)
    assert sorted(consumed) == sorted(v for *_x, v in fires)  # (1)
    assert stats["unconsumed_events"] == 0
    assert stats["events_sent"] == stats["events_received"]   # (4) clean


@given(st.integers(2, 4), st.integers(5, 60), st.booleans())
@settings(max_examples=15, deadline=None)
def test_fifo_per_src_dst(n_ranks, n_msgs, worker_poll):
    """(3): per-(src,dst) delivery order under both progress modes.

    One worker per rank so observed execution order equals delivery order
    (with >1 worker, concurrent instances may legally complete out of order —
    the paper's guarantee is about delivery, §II.B)."""
    workers = 1
    got = defaultdict(list)
    mu = threading.Lock()

    def sink(ctx, events):
        e = events[0]
        src, i = e.data
        with mu:
            got[(src, ctx.rank)].append(i)

    def main(ctx):
        ctx.submit_persistent(sink, deps=[(edat.ANY, "m")])
        for i in range(n_msgs):
            ctx.fire((ctx.rank + 1) % ctx.n_ranks, "m", (ctx.rank, i))

    with edat.Session(n_ranks, workers_per_rank=workers,
                      progress="worker" if worker_poll else "thread",
                      timeout=60) as s:
        s.run(main)
    for (src, dst), seq in got.items():
        assert seq == sorted(seq), f"FIFO violated {src}->{dst}"
    assert sum(len(v) for v in got.values()) == n_ranks * n_msgs
