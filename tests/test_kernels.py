"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp
oracle (assert_allclose), plus gradient checks through the custom_vjp."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa
from repro.kernels.rglru import ops as rg
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd.ref import ssd_reference


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


# ----------------------------------------------------------- flash attention
FA_CASES = [
    # (S, H, KH, D, window, softcap, dtype)
    (256, 4, 4, 64, None, None, jnp.float32),
    (256, 4, 1, 64, None, None, jnp.float32),     # MQA
    (512, 8, 2, 64, None, None, jnp.bfloat16),    # GQA bf16
    (512, 4, 4, 128, 128, None, jnp.float32),     # sliding window
    (256, 4, 2, 128, None, 50.0, jnp.float32),    # softcap (gemma2)
    (384, 6, 6, 64, None, None, jnp.float32),     # non-128 block tail (S=384)
    (512, 2, 1, 256, 256, None, jnp.bfloat16),    # gemma3-like hd 256
]


@pytest.mark.parametrize("S,H,KH,D,window,softcap,dtype", FA_CASES)
def test_flash_attention_matches_ref(S, H, KH, D, window, softcap, dtype):
    B = 2
    q = rand(0, (B, S, H, D), dtype)
    k = rand(1, (B, S, KH, D), dtype)
    v = rand(2, (B, S, KH, D), dtype)
    out = fa.flash_attention(q, k, v, scale=D ** -0.5, window=window,
                             softcap=softcap)
    ref = fa.attention_ref(q, k, v, scale=D ** -0.5, window=window,
                           softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grad_matches_ref():
    B, S, H, KH, D = 1, 256, 2, 1, 64
    q = rand(0, (B, S, H, D), jnp.float32)
    k = rand(1, (B, S, KH, D), jnp.float32)
    v = rand(2, (B, S, KH, D), jnp.float32)

    def f_k(q, k, v):
        return jnp.sum(fa.flash_attention(q, k, v, scale=D ** -0.5) ** 2)

    def f_r(q, k, v):
        return jnp.sum(fa.attention_ref(q, k, v, scale=D ** -0.5) ** 2)

    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------- SSD
SSD_CASES = [
    # (T, H, G, N, P, chunk, dtype)
    (256, 4, 1, 32, 32, 64, jnp.float32),
    (256, 8, 2, 64, 64, 128, jnp.float32),
    (128, 2, 2, 16, 64, 32, jnp.float32),
    (256, 4, 1, 128, 64, 128, jnp.bfloat16),      # mamba2-370m shapes
]


@pytest.mark.parametrize("T,H,G,N,P,chunk,dtype", SSD_CASES)
def test_ssd_matches_ref(T, H, G, N, P, chunk, dtype):
    B = 2
    x = rand(0, (B, T, H, P), dtype)
    dt = jax.nn.softplus(rand(1, (B, T, H), jnp.float32))
    a_log = rand(2, (H,), jnp.float32) * 0.5
    b = rand(3, (B, T, G, N), dtype)
    c = rand(4, (B, T, G, N), dtype)
    out = ssd_ops.ssd(x, dt, a_log, b, c, chunk=chunk)
    ref = ssd_reference(x.astype(jnp.float32), dt, a_log,
                        b.astype(jnp.float32), c.astype(jnp.float32),
                        chunk=chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32).reshape(out.shape),
                               rtol=tol, atol=tol)


def test_ssd_chunk_invariance():
    """The chunked algorithm must be exact: chunk size cannot change y."""
    B, T, H, G, N, P = 1, 128, 2, 1, 16, 16
    x = rand(0, (B, T, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(1, (B, T, H), jnp.float32))
    a_log = rand(2, (H,), jnp.float32) * 0.5
    b = rand(3, (B, T, G, N), jnp.float32)
    c = rand(4, (B, T, G, N), jnp.float32)
    y32 = ssd_reference(x, dt, a_log, b, c, chunk=32)
    y128 = ssd_reference(x, dt, a_log, b, c, chunk=128)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128),
                               rtol=1e-4, atol=1e-4)


def test_ssd_grad_flows():
    B, T, H, G, N, P = 1, 64, 2, 1, 16, 16
    x = rand(0, (B, T, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(1, (B, T, H), jnp.float32))
    a_log = rand(2, (H,), jnp.float32) * 0.5
    b = rand(3, (B, T, G, N), jnp.float32)
    c = rand(4, (B, T, G, N), jnp.float32)

    g = jax.grad(lambda x: jnp.sum(
        ssd_ops.ssd(x, dt, a_log, b, c, chunk=32) ** 2))(x)
    gr = jax.grad(lambda x: jnp.sum(
        ssd_reference(x, dt, a_log, b, c, chunk=32) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------- RG-LRU
RG_CASES = [
    (128, 128), (256, 256), (128, 512), (512, 128),
]


@pytest.mark.parametrize("T,W", RG_CASES)
def test_rglru_matches_ref(T, W):
    B = 2
    x = rand(0, (B, T, W), jnp.float32)
    r = jax.nn.sigmoid(rand(1, (B, T, W), jnp.float32))
    i = jax.nn.sigmoid(rand(2, (B, T, W), jnp.float32))
    lam = jnp.abs(rand(3, (W,), jnp.float32)) + 0.2
    out = rg.rglru(x, r, i, lam)
    ref = rglru_ref(x, r, i, lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_grad_flows():
    B, T, W = 1, 128, 128
    x = rand(0, (B, T, W), jnp.float32)
    r = jax.nn.sigmoid(rand(1, (B, T, W), jnp.float32))
    i = jax.nn.sigmoid(rand(2, (B, T, W), jnp.float32))
    lam = jnp.abs(rand(3, (W,), jnp.float32)) + 0.2
    g = jax.grad(lambda x: jnp.sum(rg.rglru(x, r, i, lam) ** 2))(x)
    gr = jax.grad(lambda x: jnp.sum(rglru_ref(x, r, i, lam) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=1e-3, atol=1e-3)


# -------------------------------------------- model-level pallas dispatch
def test_model_pallas_path_matches_ref_path():
    """A reduced gemma2 (attention) forward under attn_impl=pallas must match
    attn_impl=ref."""
    from repro.configs import ARCHS, reduce_cfg
    from repro.models import build_model

    cfg = reduce_cfg(ARCHS["gemma2-2b"].cfg).replace(
        window=128, max_target_length=512)
    model_ref = build_model(cfg.replace(attn_impl="ref"))
    model_pl = build_model(cfg.replace(attn_impl="pallas"))
    params = model_ref.init(jax.random.PRNGKey(0))
    B, S = 2, 256   # >= 256 so the pallas path engages
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    l_ref, _ = model_ref.loss(params, batch)
    l_pl, _ = model_pl.loss(params, batch)
    np.testing.assert_allclose(float(l_ref), float(l_pl), rtol=1e-4)
