"""Dry-run machinery smoke test: lower + compile one cell per step-kind on
a small fake-device mesh in a subprocess (XLA device count must be set
before jax initialises, hence the isolation)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    from repro.launch.cells import build_cell
    from repro.launch.hlo_analysis import analyze

    out = {}
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    for arch, shape in [("gemma3-1b", "train_4k"),
                        ("granite-moe-1b-a400m", "decode_32k"),
                        ("whisper-tiny", "prefill_32k")]:
        cell = build_cell(arch, shape, mesh)
        with mesh:
            comp = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings
                           ).lower(*cell.args).compile()
        a = analyze(comp.as_text())
        out[f"{arch}|{shape}"] = {"flops": a["flops"],
                                  "coll": a["collective_wire_total"]}
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_cells_compile_on_mini_mesh():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=1200,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULT:"):])
    assert len(res) == 3
    for k, v in res.items():
        assert v["flops"] > 0, k


def test_mesh_factory_shapes():
    # pure metadata checks (no device allocation beyond host CPU)
    from repro.launch.mesh import make_production_mesh
    # cannot build 256-device mesh on 1 CPU: only verify the callable spec
    import inspect
    sig = inspect.signature(make_production_mesh)
    assert "multi_pod" in sig.parameters


def test_hlo_analyzer_on_synthetic_module():
    from repro.launch.hlo_analysis import analyze
    hlo = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    a = analyze(hlo)
    # 5 iterations x 2*8*8*8 flops
    assert a["dot_flops"] == 5 * 2 * 8 * 8 * 8
