"""EventRouter unit tests + end-to-end checks that paper semantics are
identical through the indexed delivery path.

The router replaces the seed's O(consumers) linear scan; these tests pin
down the three behaviours the index must preserve (paper §II.A/B, §IV.A):
registration precedence, ANY-source arrival ordering, and persistent-frame
refill.
"""
import time

import pytest

from repro import edat
from repro.core.event import ANY, Dep, Event
from repro.core.router import EventRouter
from repro.core.scheduler import TaskConsumer


def _consumer(deps, reg_order, persistent=False, name=None):
    c = TaskConsumer(lambda ctx, evs: None, deps, name, persistent)
    c.reg_order = reg_order
    return c


def _ev(source, eid, data=None):
    return Event(data=data, source=source, eid=eid)


# ------------------------------------------------------------------- unit
def test_router_exact_routing():
    r = EventRouter()
    a = _consumer([Dep(0, "x")], 0)
    b = _consumer([Dep(1, "x")], 1)
    r.register(a)
    r.register(b)
    assert r.offer(_ev(1, "x")) is b
    assert r.offer(_ev(0, "x")) is a
    assert r.offer(_ev(2, "x")) is None       # no consumer for source 2
    assert r.offer(_ev(0, "y")) is None       # no consumer for eid y


def test_router_precedence_exact_vs_wildcard_merge():
    """Candidates from the exact table and the ANY side-table are offered
    strictly by registration order (paper §II.B precedence)."""
    r = EventRouter()
    wild = _consumer([Dep(ANY, "e")], 0)
    exact = _consumer([Dep(1, "e")], 1)
    r.register(wild)
    r.register(exact)
    # earlier-registered wildcard wins over the later exact match
    assert r.offer(_ev(1, "e")) is wild

    r2 = EventRouter()
    exact2 = _consumer([Dep(1, "e")], 0)
    wild2 = _consumer([Dep(ANY, "e")], 1)
    r2.register(exact2)
    r2.register(wild2)
    assert r2.offer(_ev(1, "e")) is exact2


def test_router_skips_full_consumers():
    """A consumer whose matching slots are already filled declines; the
    event falls through to the next candidate in precedence order."""
    r = EventRouter()
    a = _consumer([Dep(0, "e")], 0)
    b = _consumer([Dep(0, "e")], 1)
    r.register(a)
    r.register(b)
    assert r.offer(_ev(0, "e")) is a
    assert r.offer(_ev(0, "e")) is b          # a's only slot is now full
    assert r.offer(_ev(0, "e")) is None       # both full -> store


def test_router_unregister():
    r = EventRouter()
    a = _consumer([Dep(0, "e"), Dep(ANY, "w"), Dep(0, "e")], 0)
    r.register(a)
    assert r.stats() == {"exact_keys": 1, "wildcard_eids": 1}
    r.unregister(a)
    assert r.stats() == {"exact_keys": 0, "wildcard_eids": 0}
    assert r.offer(_ev(0, "e")) is None
    r.unregister(a)  # idempotent


def test_router_persistent_frame_refill():
    """A persistent consumer accepts unboundedly many events by opening new
    frames (paper §IV.A) — the router keeps offering to the same entry."""
    r = EventRouter()
    p = _consumer([Dep(0, "e")], 0, persistent=True)
    r.register(p)
    for _ in range(5):
        assert r.offer(_ev(0, "e")) is p
    # 5 accepted -> frames queued for dispatch
    popped = 0
    while p.pop_ready() is not None:
        popped += 1
    assert popped == 5


# ------------------------------------------------------------ end-to-end
def run(n_ranks, main, workers=2, timeout=30.0, **kw):
    with edat.Session(n_ranks, workers_per_rank=workers, timeout=timeout,
                      **kw) as s:
        stats = s.run(main)
    return s, stats


def test_precedence_identical_through_indexed_path():
    """Mixed ANY + exact consumers on one eid: consumption strictly follows
    submission order regardless of match kind (paper §II.B)."""
    got = []

    def mk(tag):
        def t(ctx, events):
            got.append((tag, events[0].data))
        return t

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(mk("any-first"), deps=[(edat.ANY, "e")])
            ctx.submit(mk("exact-second"), deps=[(1, "e")])
            ctx.submit(mk("any-third"), deps=[(edat.ANY, "e")])

    def main2(ctx):
        main(ctx)
        if ctx.rank == 1:
            time.sleep(0.1)  # let rank 0 register all three consumers
            for i in range(3):
                ctx.fire(0, "e", i)

    run(2, main2)
    assert sorted(got) == [("any-first", 0), ("any-third", 2),
                           ("exact-second", 1)]
    # precedence: consumption order == submission order
    by_data = dict((d, t) for t, d in got)
    assert [by_data[i] for i in range(3)] == ["any-first", "exact-second",
                                              "any-third"]


def test_any_dep_takes_oldest_stored_arrival():
    """ANY-source retrieval from the store honours arrival order across
    different sources (store eid side-index)."""
    got = []

    def t(ctx, events):
        got.append(events[0].source)

    def main(ctx):
        if ctx.rank == 0:
            time.sleep(0.15)  # both events are stored before submission
            ctx.submit(t, deps=[(edat.ANY, "e")])
            ctx.submit(t, deps=[(edat.ANY, "e")])
        elif ctx.rank == 1:
            ctx.fire(0, "e")
        elif ctx.rank == 2:
            time.sleep(0.08)  # strictly later arrival than rank 1's event
            ctx.fire(0, "e")

    run(3, main)
    assert got == [1, 2]


def test_many_distinct_eids_route_correctly():
    """1000 persistent consumers with distinct eids each receive exactly
    their own events (the indexed fan-out the router exists for)."""
    N = 1000
    got = {}

    def mk(i):
        def t(ctx, events):
            got.setdefault(i, []).append(events[0].data)
        return t

    def main(ctx):
        if ctx.rank == 0:
            for i in range(N):
                ctx.submit_persistent(mk(i), deps=[(1, f"e{i}")], name=f"p{i}")
        else:
            ctx.fire_batch([(0, f"e{i}", i) for i in range(N)])
            ctx.fire_batch([(0, f"e{i}", i + N) for i in range(N)])

    run(2, main, timeout=60)
    assert len(got) == N
    for i in range(N):
        assert got[i] == [i, i + N]   # per-(src,dst) FIFO within each eid


def test_persistent_frames_refill_through_store_and_router():
    """Frame pairing (paper §IV.A) is FIFO whether events arrive via the
    router (consumer registered first) or via the store (events first)."""
    got = []

    def t(ctx, events):
        got.append((events[0].data, events[1].data))

    def main(ctx):
        if ctx.rank == 0:
            # events stored first: a0 a1, then submission, then live b0 b1
            ctx.fire(edat.SELF, "a", 0)
            ctx.fire(edat.SELF, "a", 1)
            time.sleep(0.1)
            ctx.submit_persistent(t, deps=[(edat.SELF, "a"),
                                           (edat.SELF, "b")])
            ctx.fire(edat.SELF, "b", 10)
            ctx.fire(edat.SELF, "b", 11)

    run(1, main)
    assert sorted(got) == [(0, 10), (1, 11)]


def test_waiter_routes_through_index():
    """wait() registers in the same router; wake is notification-driven."""
    got = {}

    def waiter(ctx, events):
        t0 = time.monotonic()
        evs = ctx.wait([(edat.ANY, "wake")])
        got["latency"] = time.monotonic() - t0
        got["data"] = evs[0].data

    def main(ctx):
        if ctx.rank == 0:
            ctx.submit(waiter)
        else:
            time.sleep(0.3)
            ctx.fire(0, "wake", 42)

    run(2, main)
    assert got["data"] == 42
    # woken by notification: no 50 ms poll quantum on top of the 0.3 s fire
    assert got["latency"] < 0.45
