"""Integration tests for the event-driven distributed trainer:
sync DP equivalence, loss decrease, async quorum, int8 gradient events,
async checkpointing + restart, node-failure recovery (elastic).
Fault injection goes through the shared tests/_chaos.py harness."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _chaos as chaos
from repro.data import DataCfg
from repro.models import ModelCfg, build_model
from repro.optim import OptCfg
from repro.runtime_dist import EventDrivenTrainer, TrainerCfg

TINY = ModelCfg(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
    dtype="float32", remat="none", max_target_length=64,
)
DATA = DataCfg(vocab=128, seq=32, global_batch=12, seed=7)
OPT = OptCfg(name="adamw", peak_lr=3e-2, warmup=5, total_steps=200,
             clip_norm=1.0)


def make_trainer(**kw):
    model = build_model(TINY)
    opt = kw.pop("opt", OPT)
    tc = TrainerCfg(steps=kw.pop("steps", 12), n_ranks=kw.pop("n_ranks", 2),
                    **kw)
    return EventDrivenTrainer(model, DATA, opt, tc)


def test_sync_dp_replicas_stay_identical_and_loss_decreases():
    tr = make_trainer(steps=25, n_ranks=2)
    out = tr.run()
    hist = out["history"]
    assert len(hist) >= 25
    first = np.mean([m["loss"] for m in hist if m["step"] <= 3])
    last = np.mean([m["loss"] for m in hist if m["step"] >= 23])
    assert last < first - 0.2, (first, last)
    p0, p1 = out["final_params"]
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sync_dp_matches_single_rank_half_batch():
    """2-rank sync DP with grad averaging == 1 rank on the full batch.

    Uses SGD-momentum: updates are linear in gradients, so the fp32
    shard-averaging noise (~1e-7) stays ~1e-7.  (Adam's m/sqrt(v) is
    sign-like for near-zero gradient components and amplifies that noise
    to +-lr, which would make bitwise comparison meaningless.)"""
    sgd = OptCfg(name="sgdm", peak_lr=1e-2, warmup=5, total_steps=200)
    out2 = make_trainer(steps=6, n_ranks=2, opt=sgd).run()
    out1 = make_trainer(steps=6, n_ranks=1, opt=sgd).run()
    for a, b in zip(jax.tree.leaves(out2["final_params"][0]),
                    jax.tree.leaves(out1["final_params"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_async_quorum_makes_progress():
    tr = make_trainer(steps=20, n_ranks=3, quorum=0.5, collect_timeout=2.0)
    out = tr.run()
    hist = out["history"]
    assert max(m["step"] for m in hist) >= 20
    first = np.mean([m["loss"] for m in hist if m["step"] <= 3])
    last = np.mean([m["loss"] for m in hist if m["step"] >= 18])
    assert last < first


def test_int8_gradient_compression_converges():
    tr = make_trainer(steps=25, n_ranks=2, compress="int8")
    out = tr.run()
    hist = out["history"]
    first = np.mean([m["loss"] for m in hist if m["step"] <= 3])
    last = np.mean([m["loss"] for m in hist if m["step"] >= 23])
    assert last < first - 0.15, (first, last)


def test_async_checkpoint_and_restart(tmp_path):
    ckdir = str(tmp_path / "ck")
    tr = make_trainer(steps=10, n_ranks=2, ckpt_dir=ckdir, ckpt_every=5)
    out = tr.run()
    assert out["ckpt_writes"] >= 2
    from repro.checkpoint import latest_step
    assert latest_step(ckdir) == 10

    # restart from the checkpoint and keep training: loss continues down
    tr2 = make_trainer(steps=16, n_ranks=2, ckpt_dir=ckdir, ckpt_every=100,
                       start_step=10)
    out2 = tr2.run()
    assert max(m["step"] for m in out2["history"]) >= 16
    # bit-exact resume: a fresh run to 16 equals ckpt-resume to 16
    tr3 = make_trainer(steps=16, n_ranks=2)
    out3 = tr3.run()
    for a, b in zip(jax.tree.leaves(out2["final_params"][0]),
                    jax.tree.leaves(out3["final_params"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_node_failure_recovery_elastic(tmp_path):
    """Kill a rank mid-run: survivors roll back to the last checkpoint,
    re-shard data, and finish training."""
    from repro.checkpoint import latest_step

    ckdir = str(tmp_path / "ck")
    tr = make_trainer(steps=30, n_ranks=3, ckpt_dir=ckdir, ckpt_every=5,
                      collect_timeout=1.0)

    # kill only once a real (non-initial) checkpoint exists — the rollback
    # anchor the survivors need, without racing the first JIT
    sab = chaos.Saboteur(lambda: tr.runtime.kill_rank(2),
                         pred=lambda: (latest_step(ckdir) or 0) >= 5,
                         delay=0.3).start()
    out = tr.run(timeout=240)
    sab.join()
    hist = out["history"]
    assert max(m["step"] for m in hist) >= 30
    # survivors end in agreement
    p0, p1 = out["final_params"][0], out["final_params"][1]
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    # late metrics should show 2-rank quorums after the failure
    late = [m for m in hist if m["step"] >= 28]
    assert all(m["n_grads"] <= 2 for m in late)


def test_heartbeat_suspects_hung_rank(tmp_path):
    """A rank that hangs (but is not dead) stops heartbeating; the timer-
    driven monitor suspects it, survivors roll back and re-shard, and the
    suspect fences itself on waking (fail-stop enforcement)."""
    ckdir = str(tmp_path / "ck")
    tr = make_trainer(steps=24, n_ranks=3, ckpt_dir=ckdir, ckpt_every=4,
                      collect_timeout=0.8, hb_interval=0.25, hb_timeout=1.2,
                      stall=chaos.stall_spec(2, at_step=6, seconds=4.0))
    out = tr.run(timeout=240)
    hist = out["history"]
    assert max(m["step"] for m in hist) >= 24
    # after the suspicion, quorums are 2-rank
    late = [m for m in hist if m["step"] >= 22]
    assert late and all(m["n_grads"] <= 2 for m in late)
    assert all(m["rank"] != 2 for m in late)   # the suspect stayed fenced
    # survivors agree
    p0, p1 = out["final_params"][0], out["final_params"][1]
    import jax
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_duplicate_recover_suppressed(tmp_path, monkeypatch):
    """Regression (the residual test_node_failure_recovery_elastic flake):
    a rank removed by the heartbeat-*suspect* path and then additionally
    reported via RANK_FAILED (kill) must trigger exactly ONE coordinated
    recovery.  _on_rank_failed used to re-fire "recover" for a rank that
    was already out of ``alive``, racing the restarted step chain with a
    second rollback."""
    import collections
    import time

    ckdir = str(tmp_path / "ck")
    # rank 2 hangs at step 4 (muting its own heartbeat pump, like a real
    # hang) long enough that the monitor *must* suspect it; survivors are
    # throttled by the collect timeout meanwhile, so the run is still in
    # flight when the saboteur delivers the second (RANK_FAILED) verdict
    tr = make_trainer(steps=40, n_ranks=3, ckpt_dir=ckdir, ckpt_every=2,
                      collect_timeout=0.5, hb_interval=0.25, hb_timeout=1.2,
                      stall=chaos.stall_spec(2, at_step=4, seconds=6.0))
    recovers = collections.Counter()
    suspects = collections.Counter()
    orig = EventDrivenTrainer._on_recover
    orig_suspect = EventDrivenTrainer._on_suspect

    def counting(self, ctx, events):
        recovers[ctx.rank] += 1
        return orig(self, ctx, events)

    def counting_suspect(self, ctx, events):
        suspects[ctx.rank] += 1
        return orig_suspect(self, ctx, events)

    monkeypatch.setattr(EventDrivenTrainer, "_on_recover", counting)
    monkeypatch.setattr(EventDrivenTrainer, "_on_suspect", counting_suspect)

    def sabotage():
        chaos.wait_for_history(tr)       # alive starts empty during init
        chaos.wait_for(lambda: 2 not in tr.states[0].alive, 120,
                       desc="suspect verdict on rank 2")
        time.sleep(0.5)                  # let the recover broadcast land
        tr.runtime.kill_rank(2)          # RANK_FAILED path fires as well

    sab = chaos.Saboteur(sabotage).start()
    out = tr.run(timeout=240)
    sab.join()
    hist = out["history"]
    assert max(m["step"] for m in hist) >= 40
    # the suspicion path must really have run first (else the test is
    # vacuous: a plain kill exercises only the RANK_FAILED path)
    assert suspects[0] >= 1, dict(suspects)
    # exactly one recovery per survivor (the duplicate bug made this 2)
    assert recovers[0] == 1 and recovers[1] == 1, dict(recovers)
    # survivors end in agreement
    p0, p1 = out["final_params"][0], out["final_params"][1]
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
