"""Collective-pattern helpers: barrier, allreduce, tree_reduce."""
import threading

import pytest

from repro import edat
from repro.core import patterns


def run(n, main, **kw):
    with edat.Session(n, workers_per_rank=2, timeout=60, **kw) as s:
        s.run(main)
    return s


def test_barrier_runs_once_per_rank():
    hits = []

    def main(ctx):
        patterns.barrier(ctx, "b1", lambda c, e: hits.append(c.rank))

    run(3, main)
    assert sorted(hits) == [0, 1, 2]


def test_wait_barrier_orders():
    import time
    stamps = {}

    def t(ctx, events):
        time.sleep(0.02 * ctx.rank)
        patterns.wait_barrier(ctx, "x")
        stamps[ctx.rank] = time.monotonic()

    def main(ctx):
        ctx.submit(t)

    run(3, main)
    assert max(stamps.values()) - min(stamps.values()) < 0.5


def test_allreduce_sum():
    out = {}
    mu = threading.Lock()

    def main(ctx):
        patterns.allreduce(
            ctx, "s", ctx.rank + 1, lambda a, b: a + b,
            lambda c, v: out.__setitem__(c.rank, v))

    run(4, main)
    assert out == {0: 10, 1: 10, 2: 10, 3: 10}


@pytest.mark.parametrize("n,root", [(1, 0), (2, 0), (3, 1), (4, 0), (5, 3),
                                    (8, 7)])
def test_tree_reduce(n, root):
    out = {}

    def main(ctx):
        patterns.tree_reduce(
            ctx, "t", ctx.rank + 1, lambda a, b: a + b,
            lambda c, v: out.__setitem__(c.rank, v), root=root)

    run(n, main, unconsumed="error")
    assert out == {root: n * (n + 1) // 2}
